//! Bench H1/H2: the paper's headline latency claims.
//!
//! * H1 — "2.36× lower latency … 24.42× lower LUT utilization" vs LogicNets
//!   (modeled hardware latency = pipeline edges × period from the VU9P
//!   timing model, identical methodology for both designs).
//! * H2 — "9.25× lower latency" vs Google's AQP-style arithmetic datapath
//!   (analytical hls4ml-class cost model, DESIGN.md §4).
//!
//! Also measures the *software* engines on this host (bit-parallel logic
//! simulator, PJRT numeric engine) — not hardware numbers, but the serving
//! reality of this repo.

use std::time::Instant;

use nullanet_tiny::baseline::{build_logicnets, AqpModel};
use nullanet_tiny::data::Dataset;
use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::logic::sim::CompiledNetlist;
use nullanet_tiny::nn::eval::{codes_to_bits, quantize_input};
use nullanet_tiny::nn::model::{Arch, Model};
use nullanet_tiny::runtime::PjrtEngine;
use nullanet_tiny::util::bench::{format_ns, Bench};

fn main() {
    let dir = "artifacts";
    if Dataset::load(&format!("{dir}/jsc_test.bin")).is_err() {
        eprintln!("latency bench needs `make artifacts`");
        return;
    }
    let test = Dataset::load(&format!("{dir}/jsc_test.bin")).unwrap();
    let tm = TimingModel::vu9p();
    let aqp = AqpModel::default();

    println!("== modeled hardware latency (VU9P timing model) ==\n");
    println!("| Arch | ours ns | LogicNets ns | dec. | AQP ns | dec. | paper H1/H2 |");
    println!("|------|---------|--------------|------|--------|------|-------------|");
    for arch in Arch::all() {
        let name = arch.name();
        let ours_model = Model::load(&format!("{dir}/{name}.model.json")).unwrap();
        let base_model =
            Model::load(&format!("{dir}/{name}.logicnets.model.json")).unwrap();
        let r = run_flow(&ours_model, &FlowConfig::default(), None).unwrap();
        let b = build_logicnets(&base_model, 6).unwrap();
        let so = r.circuit.stats();
        let sb = b.circuit.stats();
        let ours_ns = tm.latency_ns(so.latency_cycles, so.max_stage_depth);
        let base_ns = tm.latency_ns(sb.latency_cycles, sb.max_stage_depth);
        let aqp_ns = aqp.latency_ns(&ours_model);
        println!(
            "| {} | {:7.2} | {:12.2} | {:.2}x | {:6.1} | {:.2}x | 2.36x / 9.25x |",
            name.to_uppercase(),
            ours_ns,
            base_ns,
            base_ns / ours_ns,
            aqp_ns,
            aqp_ns / ours_ns,
        );
    }

    // ---- software engine latency on this host ----
    println!("\n== software engines on this host (JSC-S) ==\n");
    let model = Model::load(&format!("{dir}/jsc-s.model.json")).unwrap();
    let r = run_flow(&model, &FlowConfig::default(), None).unwrap();
    let sim = CompiledNetlist::compile(&r.circuit.netlist);
    let in_b = model.input_quant.bits;

    let mut bench = Bench::new();
    // single-sample logic inference (bit encode + one 64-lane pass)
    let x0 = &test.xs[0];
    bench.run("logic-sim single inference", || {
        let bits = codes_to_bits(&quantize_input(&model, x0), in_b);
        sim.run_batch(&[bits]).pop().unwrap()
    });
    // batched logic inference (64 samples / word pass)
    let batch: Vec<Vec<bool>> = test.xs[..64]
        .iter()
        .map(|x| codes_to_bits(&quantize_input(&model, x), in_b))
        .collect();
    let s = bench.run("logic-sim 64-batch", || sim.run_batch(&batch));
    println!(
        "  → logic-sim throughput: {:.0} inferences/s (batched)",
        64.0 * 1e9 / s.median_ns
    );

    if let Ok(engine) =
        PjrtEngine::load(&format!("{dir}/jsc-s.hlo.txt"), 64, model.input_features, 5)
    {
        let xs64: Vec<Vec<f64>> = test.xs[..64].to_vec();
        let s = bench.run("pjrt 64-batch", || engine.infer(&xs64).unwrap());
        println!(
            "  → pjrt throughput: {:.0} inferences/s (batched)",
            64.0 * 1e9 / s.median_ns
        );
        // end-to-end compare latency
        let t = Instant::now();
        let n = 4096.min(test.len());
        let _ = engine.classify_all(&test.xs[..n], 5).unwrap();
        println!(
            "  → pjrt full test sweep: {} samples in {}",
            n,
            format_ns(t.elapsed().as_nanos() as f64)
        );
    }
}
