//! Bench A3: ablations over the flow's design choices — ESPRESSO on/off,
//! retiming on/off, depth- vs area-oriented mapping — plus microbenchmarks
//! of the two-level minimizer and the LUT mapper themselves.

use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::logic::espresso::minimize_tt;
use nullanet_tiny::logic::mapper::{map_aig, MapConfig};
use nullanet_tiny::logic::truthtable::TruthTable;
use nullanet_tiny::nn::model::{random_model, Model};
use nullanet_tiny::util::bench::Bench;
use nullanet_tiny::util::prng::Xoshiro256;

fn main() {
    // ---- flow-level ablations (A3) ----
    let model = Model::load("artifacts/jsc-s.model.json")
        .unwrap_or_else(|_| random_model("abl", 16, &[64, 32, 5], 3, 2, 7));
    println!("A3 ablations on {}:\n", model.summary());
    println!("| espresso | retime | area-map | LUTs | FFs | depth | fmax MHz | cubes |");
    println!("|----------|--------|----------|------|-----|-------|----------|-------|");
    let tm = TimingModel::vu9p();
    for esp in [true, false] {
        for ret in [true, false] {
            for area in [true, false] {
                let cfg = FlowConfig {
                    use_espresso: esp,
                    retime: ret,
                    map_for_area: area,
                    verify: false,
                    ..Default::default()
                };
                let r = run_flow(&model, &cfg, None).unwrap();
                let s = r.circuit.stats();
                println!(
                    "| {:>8} | {:>6} | {:>8} | {:4} | {:3} | {:5} | {:8.0} | {:5} |",
                    esp, ret, area, s.luts, s.ffs, s.max_stage_depth,
                    tm.fmax_mhz(s.max_stage_depth),
                    r.total_cubes_after,
                );
            }
        }
    }

    // ---- microbenchmarks ----
    println!("\nmicrobenchmarks:");
    let mut bench = Bench::new();
    let mut rng = Xoshiro256::new(0xBEEF);

    // ESPRESSO on an 8-input threshold-like function (the JSC-M neuron size).
    let weights: Vec<f64> = (0..8).map(|_| rng.next_gaussian()).collect();
    let tt8 = TruthTable::from_fn(8, |m| {
        let s: f64 = (0..8)
            .map(|i| if (m >> i) & 1 == 1 { weights[i] } else { 0.0 })
            .sum();
        s > 0.0
    });
    let dc8 = TruthTable::zeros(8);
    bench.run("espresso 8-in threshold fn", || minimize_tt(&tt8, &dc8));

    // ESPRESSO on a random (hard) 8-input function.
    let rtt = TruthTable::from_fn(8, |_| rng.bernoulli(0.5));
    bench.run("espresso 8-in random fn", || minimize_tt(&rtt, &dc8));

    // ISOP alone (the seed generator).
    bench.run("isop 12-in threshold fn", || {
        let tt = TruthTable::from_fn(12, |m| (m.count_ones() as i32 - 6) > 0);
        TruthTable::isop(&tt, &TruthTable::zeros(12))
    });

    // Mapper on a mid-size AIG.
    use nullanet_tiny::logic::aig::{Aig, Lit};
    let mut g = Aig::new();
    let ins: Vec<Lit> = (0..24).map(|_| g.add_input()).collect();
    let mut pool = ins.clone();
    let mut r2 = Xoshiro256::new(3);
    for _ in 0..400 {
        let a = pool[r2.below(pool.len() as u64) as usize];
        let b = pool[r2.below(pool.len() as u64) as usize];
        let l = match r2.below(3) {
            0 => g.and(a, b),
            1 => g.or(a, b),
            _ => g.xor(a, b),
        };
        pool.push(l);
    }
    for &l in pool.iter().rev().take(8) {
        g.add_output(l);
    }
    let g = g.sweep();
    bench.run("map 400-op AIG to 6-LUTs", || map_aig(&g, &MapConfig::default()));
}
