//! Bench T1: regenerate the paper's Table I — accuracy, LUTs, FFs, fmax
//! for JSC-S/M/L with comparison factors vs the LogicNets baseline — and
//! time each flow.
//!
//! ```bash
//! make artifacts && cargo bench --bench table1
//! ```
//!
//! Paper values for reference (their testbed; shapes, not absolutes, are
//! the reproduction target — see EXPERIMENTS.md).

use std::time::Instant;

use nullanet_tiny::baseline::build_logicnets;
use nullanet_tiny::data::Dataset;
use nullanet_tiny::flow::{circuit_accuracy, run_flow, FlowConfig};
use nullanet_tiny::fpga::report::{format_table, Comparison, ResultRow};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::nn::model::{Arch, Model};

fn main() {
    let dir = "artifacts";
    let test = match Dataset::load(&format!("{dir}/jsc_test.bin")) {
        Ok(t) => t,
        Err(_) => {
            eprintln!("table1 bench needs `make artifacts` (test set missing)");
            return;
        }
    };
    let tm = TimingModel::vu9p();
    let mut rows = Vec::new();
    println!("Table I regeneration — synthesizing all architectures…\n");
    for arch in Arch::all() {
        let name = arch.name();
        let ours_model = Model::load(&format!("{dir}/{name}.model.json")).unwrap();
        let base_model =
            Model::load(&format!("{dir}/{name}.logicnets.model.json")).unwrap();
        let t = Instant::now();
        let r = run_flow(&ours_model, &FlowConfig::default(), None).unwrap();
        let flow_s = t.elapsed().as_secs_f64();
        let ours_acc = circuit_accuracy(&ours_model, &r.circuit, &test.xs, &test.ys);
        let t = Instant::now();
        let base = build_logicnets(&base_model, 6).unwrap();
        let base_s = t.elapsed().as_secs_f64();
        let base_acc = circuit_accuracy(&base_model, &base.circuit, &test.xs, &test.ys);
        println!(
            "{name}: flow {flow_s:.1}s (espresso {} → {} cubes), baseline {base_s:.1}s",
            r.total_cubes_before, r.total_cubes_after
        );
        rows.push(Comparison {
            ours: ResultRow::from_stats(&name.to_uppercase(), ours_acc, r.circuit.stats(), &tm),
            baseline: ResultRow::from_stats(
                &name.to_uppercase(),
                base_acc,
                base.circuit.stats(),
                &tm,
            ),
        });
    }
    println!("\n{}", format_table(&rows));
    println!("paper Table I (their Vivado/VU9P testbed):");
    println!("  JSC-S 69.65% (+1.85) |    39 LUTs (5.50x) |  75 FFs (3.30x) | 2079 MHz (1.30x)");
    println!("  JSC-M 72.22% (+1.73) |  1553 LUTs (9.30x) | 151 FFs (2.90x) |  841 MHz (1.40x)");
    println!("  JSC-L 73.35% (+1.55) | 11752 LUTs (3.20x) | 565 FFs (1.40x) |  436 MHz (1.02x)");
}
