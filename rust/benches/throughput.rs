//! Bench P1 (§Perf): end-to-end throughput of every moving part —
//! per-neuron synthesis rate, bit-parallel simulation rate (seed per-sample
//! path vs the packed engine at every block width W ∈ {1, 2, 4, 8}, with
//! and without the compile-time netlist optimizer), coordinator round-trip
//! under batching, and thread-pool scaling. `nullanet bench` runs the
//! fixed-seed subset of these and writes machine-readable `BENCH_5.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use nullanet_tiny::coordinator::{BatchPolicy, Policy, RouterBuilder};
use nullanet_tiny::flow::{run_flow, FlowConfig};
use nullanet_tiny::logic::sim::{CompiledNetlist, ShardRunner};
use nullanet_tiny::nn::eval::{codes_to_bits, quantize_input};
use nullanet_tiny::nn::model::{random_model, Model};
use nullanet_tiny::util::bench::Bench;
use nullanet_tiny::util::bitvec::PackedBatch;
use nullanet_tiny::util::prng::Xoshiro256;
use nullanet_tiny::util::threadpool::ThreadPool;

fn main() {
    let model = Model::load("artifacts/jsc-s.model.json")
        .unwrap_or_else(|_| random_model("tp", 16, &[64, 32, 5], 3, 2, 7));
    let mut bench = Bench::new();

    // ---- flow throughput ----
    let t = Instant::now();
    let cfg = FlowConfig { verify: false, ..Default::default() };
    let r = run_flow(&model, &cfg, None).unwrap();
    let flow_s = t.elapsed().as_secs_f64();
    println!(
        "flow: {} neurons in {:.2}s = {:.0} neurons/s (enumerate+espresso+map+retime)\n",
        r.neurons,
        flow_s,
        r.neurons as f64 / flow_s
    );

    // ---- simulator throughput: seed path vs packed engine ----
    let sim = Arc::new(CompiledNetlist::compile(&r.circuit.netlist));
    let mut rng = Xoshiro256::new(1);
    let batch: Vec<Vec<bool>> = (0..4096)
        .map(|_| {
            let x: Vec<f64> = (0..model.input_features).map(|_| 2.0 * rng.next_gaussian()).collect();
            codes_to_bits(&quantize_input(&model, &x), model.input_quant.bits)
        })
        .collect();
    let mut packed = PackedBatch::with_capacity(r.circuit.netlist.num_inputs, batch.len());
    for s in &batch {
        packed.push_sample_bools(s);
    }
    let packed = Arc::new(packed);

    let s_seed = bench.run("logic-sim 4096-batch (seed run_batch)", || sim.run_batch(&batch));
    println!("  → {:.2} M inferences/s\n", 4096.0 * 1e3 / s_seed.median_ns);

    // Block-width sweep: the W=1 unoptimized kernel is the pre-PR baseline;
    // the optimizer + wider blocks are this PR's tentpole.
    let sim_raw = Arc::new(CompiledNetlist::compile_unoptimized(&r.circuit.netlist));
    let groups = packed.num_groups();
    let no = sim.num_outputs();
    let mut out = vec![0u64; groups * no];
    let mut scratch_raw = sim_raw.make_scratch();
    let s_base = bench.run("packed kernel W=1, unoptimized (baseline)", || {
        sim_raw.run_groups_capped(&packed, 0, groups, &mut scratch_raw, &mut out, 1)
    });
    let mut scratch = sim.make_scratch();
    for width in [1usize, 2, 4, 8] {
        let s = bench.run(&format!("packed kernel W={width}, optimized"), || {
            sim.run_groups_capped(&packed, 0, groups, &mut scratch, &mut out, width)
        });
        println!(
            "  → W={width}: {:.2} M inf/s ({:.2}× W=1-unoptimized, {:.2}× seed)\n",
            4096.0 * 1e3 / s.median_ns,
            s_base.median_ns / s.median_ns,
            s_seed.median_ns / s.median_ns,
        );
    }

    let s_one = bench.run("packed engine 4096-batch, 1 worker", || {
        sim.run_packed(&packed, &mut scratch)
    });
    // Persistent ShardRunner (the serving engine's zero-allocation path).
    let pool4 = ThreadPool::new(4);
    let mut runner = ShardRunner::new(&sim);
    let s_four = bench.run("packed engine 4096-batch, 4 workers", || {
        runner.run(&sim, &pool4, &packed);
    });
    println!(
        "  → packed: {:.2} M inf/s (1 worker, {:.2}× seed), {:.2} M inf/s \
         (4 workers, {:.2}× seed)\n",
        4096.0 * 1e3 / s_one.median_ns,
        s_seed.median_ns / s_one.median_ns,
        4096.0 * 1e3 / s_four.median_ns,
        s_seed.median_ns / s_four.median_ns,
    );

    // word-level lower bound: one 64-lane pass
    let words: Vec<u64> = (0..r.circuit.netlist.num_inputs).map(|_| rng.next_u64()).collect();
    let mut out = vec![0u64; r.circuit.netlist.outputs.len()];
    let s = bench.run("logic-sim one 64-lane pass", || {
        sim.run_words(&mut scratch, &words, &mut out);
        out[0]
    });
    println!(
        "  → word-pass bound: {:.2} M inferences/s ({} LUTs/pass)\n",
        64.0 * 1e3 / s.median_ns,
        r.circuit.netlist.num_luts()
    );

    // ---- coordinator round trip ----
    let router = Arc::new(
        RouterBuilder::new(model.clone())
            .circuit(r.circuit.netlist.clone())
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_micros(50),
                ..Default::default()
            })
            .workers(4)
            .build()
            .expect("router"),
    );
    let n = 20_000usize;
    let t = Instant::now();
    let feats = model.input_features;
    let mut joins = Vec::new();
    for c in 0..4u64 {
        let rr = Arc::clone(&router);
        joins.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::new(c);
            for _ in 0..n / 4 {
                let x: Vec<f64> = (0..feats).map(|_| 2.0 * rng.next_gaussian()).collect();
                let _ = rr.submit(x).recv_timeout(Duration::from_secs(30)).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t.elapsed().as_secs_f64();
    println!(
        "coordinator: {} requests in {:.2}s = {:.0} req/s (4 closed-loop clients)",
        n,
        wall,
        n as f64 / wall
    );
    println!("  {}\n", router.metrics().report());

    // ---- thread-pool scaling on synthesis jobs ----
    for jobs in [1usize, 2, 4] {
        let m2 = random_model("scale", 16, &[64, 32, 5], 3, 2, 11);
        let pool = ThreadPool::new(jobs);
        let work: Vec<(usize, usize)> = (0..m2.layers.len())
            .flat_map(|l| (0..m2.layers[l].out_width).map(move |n| (l, n)))
            .collect();
        let m2 = Arc::new(m2);
        let t = Instant::now();
        let mm = Arc::clone(&m2);
        let _ = pool.par_map(work, move |(l, n)| {
            nullanet_tiny::flow::synth::synthesize_neuron(&mm, l, n, None, true)
        });
        println!(
            "synthesis with {jobs} worker(s): {:.2}s",
            t.elapsed().as_secs_f64()
        );
    }
}
