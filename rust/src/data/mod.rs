//! Datasets: the binary interchange format shared with the Python trainer
//! and an in-process synthetic JSC-like generator for self-contained tests.

pub mod dataset;
pub mod synth;

pub use dataset::Dataset;
