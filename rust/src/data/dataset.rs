//! Binary dataset interchange (`artifacts/jsc_{train,test}.bin`).
//!
//! Layout (little endian):
//!
//! ```text
//! magic   4 bytes  "NNTD"
//! version u32      1
//! samples u32
//! features u32
//! classes u32
//! data    samples × features × f32   (row major)
//! labels  samples × u8
//! ```
//!
//! Written by `python/compile/data.py`; read here. The format is
//! deliberately trivial — no compression, no alignment games — so both
//! sides stay ~50 lines and bugs have nowhere to hide.

use std::io::{Read, Write};

/// Dataset I/O error (dependency-free; carries the full message).
#[derive(Debug, Clone)]
pub struct DataError(pub String);

impl std::fmt::Display for DataError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for DataError {}

impl From<std::io::Error> for DataError {
    fn from(e: std::io::Error) -> Self {
        DataError(e.to_string())
    }
}

/// Result alias for dataset I/O.
pub type Result<T> = std::result::Result<T, DataError>;

fn fail<T>(msg: impl Into<String>) -> Result<T> {
    Err(DataError(msg.into()))
}

/// Magic prefix of the file format.
pub const MAGIC: &[u8; 4] = b"NNTD";
/// Current version.
pub const VERSION: u32 = 1;

/// An in-memory labelled dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    /// Feature vectors (`xs[i].len() == num_features` for all i).
    pub xs: Vec<Vec<f64>>,
    /// Class labels in `[0, num_classes)`.
    pub ys: Vec<usize>,
    /// Feature dimensionality.
    pub num_features: usize,
    /// Number of classes.
    pub num_classes: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True if there are no samples.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Validate shapes and label ranges.
    pub fn validate(&self) -> Result<()> {
        if self.xs.len() != self.ys.len() {
            return fail("xs/ys length mismatch");
        }
        for (i, x) in self.xs.iter().enumerate() {
            if x.len() != self.num_features {
                return fail(format!(
                    "sample {i} has {} features, expected {}",
                    x.len(),
                    self.num_features
                ));
            }
        }
        if let Some(&y) = self.ys.iter().find(|&&y| y >= self.num_classes) {
            return fail(format!("label {y} out of range (classes={})", self.num_classes));
        }
        Ok(())
    }

    /// Load from the binary format.
    pub fn load(path: &str) -> Result<Dataset> {
        let mut f =
            std::fs::File::open(path).map_err(|e| DataError(format!("open {path}: {e}")))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_bytes(&buf).map_err(|e| DataError(format!("parse {path}: {e}")))
    }

    /// Parse from bytes.
    pub fn from_bytes(buf: &[u8]) -> Result<Dataset> {
        if buf.len() < 20 {
            return fail("truncated header");
        }
        if &buf[0..4] != MAGIC {
            return fail("bad magic (not an NNTD file)");
        }
        let rd_u32 =
            |o: usize| -> u32 { u32::from_le_bytes(buf[o..o + 4].try_into().unwrap()) };
        let version = rd_u32(4);
        if version != VERSION {
            return fail(format!("unsupported version {version}"));
        }
        let samples = rd_u32(8) as usize;
        let features = rd_u32(12) as usize;
        let classes = rd_u32(16) as usize;
        let data_bytes = samples
            .checked_mul(features)
            .and_then(|n| n.checked_mul(4))
            .ok_or_else(|| DataError("size overflow".into()))?;
        let need = 20 + data_bytes + samples;
        if buf.len() != need {
            return fail(format!("file size {} != expected {need}", buf.len()));
        }
        let mut xs = Vec::with_capacity(samples);
        let mut off = 20;
        for _ in 0..samples {
            let mut row = Vec::with_capacity(features);
            for _ in 0..features {
                let v = f32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
                row.push(v as f64);
                off += 4;
            }
            xs.push(row);
        }
        let ys: Vec<usize> = buf[off..off + samples].iter().map(|&b| b as usize).collect();
        let d = Dataset { xs, ys, num_features: features, num_classes: classes };
        d.validate()?;
        Ok(d)
    }

    /// Serialize to the binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20 + self.len() * (self.num_features * 4 + 1));
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.num_features as u32).to_le_bytes());
        out.extend_from_slice(&(self.num_classes as u32).to_le_bytes());
        for x in &self.xs {
            for &v in x {
                out.extend_from_slice(&(v as f32).to_le_bytes());
            }
        }
        for &y in &self.ys {
            out.push(y as u8);
        }
        out
    }

    /// Write to a file.
    pub fn save(&self, path: &str) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .map_err(|e| DataError(format!("create {path}: {e}")))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Split off the first `n` samples (head, tail).
    pub fn split(&self, n: usize) -> (Dataset, Dataset) {
        let n = n.min(self.len());
        let head = Dataset {
            xs: self.xs[..n].to_vec(),
            ys: self.ys[..n].to_vec(),
            num_features: self.num_features,
            num_classes: self.num_classes,
        };
        let tail = Dataset {
            xs: self.xs[n..].to_vec(),
            ys: self.ys[n..].to_vec(),
            num_features: self.num_features,
            num_classes: self.num_classes,
        };
        (head, tail)
    }

    /// Per-feature mean and std (std floored at 1e-9).
    pub fn feature_stats(&self) -> (Vec<f64>, Vec<f64>) {
        let n = self.len().max(1) as f64;
        let mut mean = vec![0.0; self.num_features];
        for x in &self.xs {
            for (m, v) in mean.iter_mut().zip(x) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0; self.num_features];
        for x in &self.xs {
            for ((s, v), m) in var.iter_mut().zip(x).zip(&mean) {
                *s += (v - m) * (v - m);
            }
        }
        let std = var.iter().map(|&s| (s / n).sqrt().max(1e-9)).collect();
        (mean, std)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            xs: vec![vec![1.0, -2.0], vec![0.5, 3.25], vec![-1.0, 0.0]],
            ys: vec![0, 2, 1],
            num_features: 2,
            num_classes: 3,
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let d = tiny();
        let b = d.to_bytes();
        let back = Dataset::from_bytes(&b).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn roundtrip_file() {
        let d = tiny();
        let path = "/tmp/nnt_dataset_test.bin";
        d.save(path).unwrap();
        let back = Dataset::load(path).unwrap();
        assert_eq!(back, d);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_corrupt() {
        let d = tiny();
        let mut b = d.to_bytes();
        b[0] = b'X';
        assert!(Dataset::from_bytes(&b).is_err(), "bad magic");
        let mut b2 = d.to_bytes();
        b2.pop();
        assert!(Dataset::from_bytes(&b2).is_err(), "truncated");
        let mut b3 = d.to_bytes();
        b3[4] = 9; // version
        assert!(Dataset::from_bytes(&b3).is_err(), "bad version");
        let mut b4 = d.to_bytes();
        let lbl = b4.len() - 1;
        b4[lbl] = 7; // label out of range
        assert!(Dataset::from_bytes(&b4).is_err());
    }

    #[test]
    fn split_and_stats() {
        let d = tiny();
        let (h, t) = d.split(2);
        assert_eq!(h.len(), 2);
        assert_eq!(t.len(), 1);
        assert_eq!(t.ys, vec![1]);
        let (mean, std) = d.feature_stats();
        assert!((mean[0] - (1.0 + 0.5 - 1.0) / 3.0).abs() < 1e-12);
        assert!(std.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn f32_precision_is_the_contract() {
        // Values are stored as f32: exact roundtrip for f32-representable,
        // lossy otherwise (documented contract with the Python side).
        let d = Dataset {
            xs: vec![vec![0.1f32 as f64]],
            ys: vec![0],
            num_features: 1,
            num_classes: 1,
        };
        let back = Dataset::from_bytes(&d.to_bytes()).unwrap();
        assert_eq!(back.xs[0][0], 0.1f32 as f64);
    }
}
