//! Synthetic jet-substructure-like dataset generator (Rust mirror).
//!
//! The real JSC dataset [37] (16 high-level jet features, 5 classes) is not
//! available offline; DESIGN.md §4 documents the substitution. This
//! generator produces a 5-class Gaussian mixture over 16 correlated,
//! nonlinearly-warped features with class overlap tuned so a small float MLP
//! reaches ~75% accuracy — the same difficulty band as the real task, which
//! is what the QAT/FCP/logic pipeline actually exercises. The Python trainer
//! has its own generator (`python/compile/data.py`) used for the shipped
//! artifacts; this Rust twin exists so tests, examples, and benches are
//! self-contained. Both are deterministic in their seeds.

use crate::data::dataset::Dataset;
use crate::util::prng::Xoshiro256;

/// JSC-like dimensions.
pub const NUM_FEATURES: usize = 16;
/// JSC has 5 jet classes (g, q, W, Z, t).
pub const NUM_CLASSES: usize = 5;

/// Generate `n` samples with the given seed.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);

    // Class-conditional means: spread on a few latent directions, then mixed
    // through a fixed random linear map to correlate features.
    let mut class_means = Vec::with_capacity(NUM_CLASSES);
    for _ in 0..NUM_CLASSES {
        let m: Vec<f64> = (0..6).map(|_| 1.6 * rng.next_gaussian()).collect();
        class_means.push(m);
    }
    // Mixing matrix 16×6 (fixed per seed).
    let mix: Vec<Vec<f64>> = (0..NUM_FEATURES)
        .map(|_| (0..6).map(|_| rng.next_gaussian() * 0.8).collect())
        .collect();
    // Per-class latent scales (anisotropy).
    let scales: Vec<Vec<f64>> = (0..NUM_CLASSES)
        .map(|_| (0..6).map(|_| 0.6 + 0.8 * rng.next_f64()).collect())
        .collect();

    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let y = rng.below(NUM_CLASSES as u64) as usize;
        // latent draw
        let z: Vec<f64> = (0..6)
            .map(|k| class_means[y][k] + scales[y][k] * rng.next_gaussian())
            .collect();
        // observed features: linear mix + physics-flavoured warps + noise
        let mut x = Vec::with_capacity(NUM_FEATURES);
        for (i, row) in mix.iter().enumerate() {
            let lin: f64 = row.iter().zip(&z).map(|(a, b)| a * b).sum();
            let warped = match i % 4 {
                0 => lin,                         // linear (multiplicities)
                1 => lin.tanh() * 2.0,            // saturating (correlations)
                2 => (lin.abs() + 0.1).ln(),      // heavy-tailed (masses)
                _ => lin + 0.3 * lin * lin * lin.signum() * 0.1, // mild skew
            };
            x.push(warped + 0.35 * rng.next_gaussian());
        }
        xs.push(x);
        ys.push(y);
    }
    Dataset { xs, ys, num_features: NUM_FEATURES, num_classes: NUM_CLASSES }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = generate(100, 42);
        let b = generate(100, 42);
        assert_eq!(a, b);
        let c = generate(100, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn shapes_and_labels() {
        let d = generate(500, 1);
        d.validate().unwrap();
        assert_eq!(d.num_features, 16);
        assert_eq!(d.num_classes, 5);
        assert_eq!(d.len(), 500);
        // all classes present
        for c in 0..5 {
            assert!(d.ys.iter().any(|&y| y == c), "class {c} missing");
        }
    }

    #[test]
    fn classes_are_separable_but_overlapping() {
        // A nearest-class-mean classifier on standardized features should
        // land in a "hard but learnable" band — far above chance (20%),
        // below ~95% (task must not be trivial).
        let d = generate(4000, 7);
        let (mean, std) = d.feature_stats();
        let norm = |x: &[f64]| -> Vec<f64> {
            x.iter()
                .enumerate()
                .map(|(i, v)| (v - mean[i]) / std[i])
                .collect()
        };
        // class means on first 3000, eval on rest
        let (train, test) = d.split(3000);
        let mut cmeans = vec![vec![0.0; 16]; 5];
        let mut counts = vec![0usize; 5];
        for (x, &y) in train.xs.iter().zip(&train.ys) {
            let z = norm(x);
            for (m, v) in cmeans[y].iter_mut().zip(&z) {
                *m += v;
            }
            counts[y] += 1;
        }
        for (m, &c) in cmeans.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut correct = 0;
        for (x, &y) in test.xs.iter().zip(&test.ys) {
            let z = norm(x);
            let pred = (0..5)
                .min_by(|&a, &b| {
                    let da: f64 =
                        cmeans[a].iter().zip(&z).map(|(m, v)| (m - v) * (m - v)).sum();
                    let db: f64 =
                        cmeans[b].iter().zip(&z).map(|(m, v)| (m - v) * (m - v)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == y {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.45, "too hard: nearest-mean acc {acc}");
        assert!(acc < 0.97, "too easy: nearest-mean acc {acc}");
    }

    #[test]
    fn features_have_finite_moments() {
        let d = generate(1000, 3);
        let (mean, std) = d.feature_stats();
        assert!(mean.iter().all(|m| m.is_finite()));
        assert!(std.iter().all(|s| s.is_finite() && *s > 0.0));
    }
}
