//! PJRT numeric inference engine (loads the AOT HLO artifacts).
//!
//! The Rust side of the L2→L3 bridge: `artifacts/<arch>.hlo.txt` (HLO text —
//! see `python/compile/aot.py` for why text, not serialized protos) is
//! parsed, compiled once by the XLA CPU backend, and executed from the
//! request path with zero Python anywhere.
//!
//! The XLA/PJRT bindings (`xla` crate) are not available in the offline
//! build environment, so the **default build compiles a stub** whose
//! [`PjrtEngine::load`] fails with a clean error; every test and serving
//! path that needs the numeric engine is gated on the artifact files and
//! skips gracefully. The real backend lives behind the `xla` cargo feature
//! (declare the `xla` dependency when enabling it) and is source-identical
//! to the stub's API, so nothing upstream changes.

use std::fmt;

/// Runtime-layer error (keeps the crate dependency-free by default).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias for the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// True when this build carries a usable PJRT backend (the `xla` feature);
/// false in the default stub build. `RouterBuilder` preflights on this so a
/// numeric routing policy fails at `build()` with a typed error rather than
/// on the dispatcher thread.
pub const fn backend_available() -> bool {
    cfg!(feature = "xla")
}

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(RuntimeError(msg.into()))
}

/// A compiled XLA executable plus its I/O signature (stub flavour: carries
/// the signature but can never be constructed without the `xla` feature).
#[cfg(not(feature = "xla"))]
pub struct PjrtEngine {
    batch: usize,
    in_features: usize,
    out_width: usize,
    platform: String,
}

#[cfg(not(feature = "xla"))]
impl PjrtEngine {
    /// Load and compile an HLO-text artifact. Always fails in the default
    /// build: the XLA backend is not compiled in.
    pub fn load(path: &str, batch: usize, in_features: usize, out_width: usize) -> Result<Self> {
        let _ = (batch, in_features, out_width);
        err(format!(
            "PJRT backend unavailable: built without the `xla` feature \
             (cannot load {path})"
        ))
    }

    /// Platform description.
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Batch size of the compiled executable.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Output width (last-layer neurons).
    pub fn out_width(&self) -> usize {
        self.out_width
    }

    /// Run one padded batch: `xs` holds ≤ batch feature vectors; returns one
    /// output vector per input sample.
    pub fn infer(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f32>>> {
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        if xs.len() > self.batch {
            return err(format!("batch {} exceeds compiled size {}", xs.len(), self.batch));
        }
        for (i, x) in xs.iter().enumerate() {
            if x.len() != self.in_features {
                return err(format!(
                    "sample {i} has {} features, expected {}",
                    x.len(),
                    self.in_features
                ));
            }
        }
        err("PJRT backend unavailable: built without the `xla` feature")
    }
}

#[cfg(feature = "xla")]
pub use xla_backend::PjrtEngine;

/// The real XLA-backed engine. Only compiled with `--features xla`, which
/// additionally requires the `xla` crate as a dependency.
#[cfg(feature = "xla")]
mod xla_backend {
    use super::{err, Result, RuntimeError};

    pub struct PjrtEngine {
        exe: xla::PjRtLoadedExecutable,
        batch: usize,
        in_features: usize,
        out_width: usize,
        platform: String,
    }

    impl PjrtEngine {
        pub fn load(
            path: &str,
            batch: usize,
            in_features: usize,
            out_width: usize,
        ) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError(format!("create PJRT CPU client: {e:?}")))?;
            let platform = format!(
                "{} ({} devices)",
                client.platform_name(),
                client.device_count()
            );
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| RuntimeError(format!("parse HLO text {path}: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| RuntimeError(format!("XLA compile: {e:?}")))?;
            Ok(PjrtEngine { exe, batch, in_features, out_width, platform })
        }

        pub fn platform(&self) -> &str {
            &self.platform
        }

        pub fn batch(&self) -> usize {
            self.batch
        }

        pub fn out_width(&self) -> usize {
            self.out_width
        }

        pub fn infer(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f32>>> {
            if xs.is_empty() {
                return Ok(Vec::new());
            }
            if xs.len() > self.batch {
                return err(format!(
                    "batch {} exceeds compiled size {}",
                    xs.len(),
                    self.batch
                ));
            }
            let mut flat = vec![0f32; self.batch * self.in_features];
            for (i, x) in xs.iter().enumerate() {
                if x.len() != self.in_features {
                    return err(format!(
                        "sample {i} has {} features, expected {}",
                        x.len(),
                        self.in_features
                    ));
                }
                for (j, &v) in x.iter().enumerate() {
                    flat[i * self.in_features + j] = v as f32;
                }
            }
            let lit = xla::Literal::vec1(&flat)
                .reshape(&[self.batch as i64, self.in_features as i64])
                .map_err(|e| RuntimeError(format!("reshape input literal: {e:?}")))?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[lit])
                .map_err(|e| RuntimeError(format!("execute: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| RuntimeError(format!("fetch result: {e:?}")))?;
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
            let out = result
                .to_tuple1()
                .map_err(|e| RuntimeError(format!("unwrap tuple: {e:?}")))?;
            let values = out
                .to_vec::<f32>()
                .map_err(|e| RuntimeError(format!("read f32s: {e:?}")))?;
            if values.len() != self.batch * self.out_width {
                return err(format!(
                    "output size {} != batch {} × width {}",
                    values.len(),
                    self.batch,
                    self.out_width
                ));
            }
            Ok(xs
                .iter()
                .enumerate()
                .map(|(i, _)| values[i * self.out_width..(i + 1) * self.out_width].to_vec())
                .collect())
        }
    }
}

impl PjrtEngine {
    /// Classify: argmax over the first `num_classes` outputs.
    pub fn classify(&self, xs: &[Vec<f64>], num_classes: usize) -> Result<Vec<usize>> {
        let outs = self.infer(xs)?;
        // First-max tie-breaking, matching `nn::eval::classify_codes` (the
        // quantized outputs live on a coarse grid, so ties are common).
        Ok(outs
            .iter()
            .map(|o| {
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (i, &v) in o.iter().take(num_classes).enumerate() {
                    if v > best_v {
                        best_v = v;
                        best = i;
                    }
                }
                best
            })
            .collect())
    }

    /// Classify an arbitrary-size set by chunking into compiled batches.
    pub fn classify_all(&self, xs: &[Vec<f64>], num_classes: usize) -> Result<Vec<usize>> {
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(self.batch()) {
            out.extend(self.classify(chunk, num_classes)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(not(feature = "xla"))]
    fn stub_load_is_a_clean_error() {
        let e = match PjrtEngine::load("artifacts/anything.hlo.txt", 64, 16, 5) {
            Err(e) => e,
            Ok(_) => panic!("stub build must not load artifacts"),
        };
        let msg = e.to_string();
        assert!(msg.contains("PJRT backend unavailable"), "{msg}");
        assert!(msg.contains("artifacts/anything.hlo.txt"), "{msg}");
    }
}
