//! PJRT numeric inference engine (loads the AOT HLO artifacts).
//!
//! The Rust side of the L2→L3 bridge: `artifacts/<arch>.hlo.txt` (HLO text —
//! see `python/compile/aot.py` for why text, not serialized protos) is
//! parsed, compiled once by the XLA CPU backend, and executed from the
//! request path with zero Python anywhere. The exported computation is the
//! full quantized inference function — standardize → input quant → masked
//! dense layers (the Pallas kernel's HLO) → activation quantizers — over a
//! fixed batch of [`Self::batch`] samples; smaller batches are padded.

use anyhow::{bail, Context, Result};

/// A compiled XLA executable plus its I/O signature.
pub struct PjrtEngine {
    exe: xla::PjRtLoadedExecutable,
    /// Batch size baked into the artifact (64 in the default export).
    batch: usize,
    /// Input feature count.
    in_features: usize,
    /// Output width (last-layer neurons).
    out_width: usize,
    /// Human-readable platform string.
    platform: String,
}

impl PjrtEngine {
    /// Load and compile an HLO-text artifact.
    pub fn load(path: &str, batch: usize, in_features: usize, out_width: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let platform = format!(
            "{} ({} devices)",
            client.platform_name(),
            client.device_count()
        );
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("XLA compile")?;
        Ok(PjrtEngine { exe, batch, in_features, out_width, platform })
    }

    /// Platform description.
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Batch size of the compiled executable.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Run one padded batch: `xs` holds ≤ batch feature vectors; returns one
    /// output vector per input sample.
    pub fn infer(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f32>>> {
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        if xs.len() > self.batch {
            bail!("batch {} exceeds compiled size {}", xs.len(), self.batch);
        }
        let mut flat = vec![0f32; self.batch * self.in_features];
        for (i, x) in xs.iter().enumerate() {
            if x.len() != self.in_features {
                bail!("sample {i} has {} features, expected {}", x.len(), self.in_features);
            }
            for (j, &v) in x.iter().enumerate() {
                flat[i * self.in_features + j] = v as f32;
            }
        }
        let lit = xla::Literal::vec1(&flat)
            .reshape(&[self.batch as i64, self.in_features as i64])
            .context("reshape input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit]).context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrap tuple")?;
        let values = out.to_vec::<f32>().context("read f32s")?;
        if values.len() != self.batch * self.out_width {
            bail!(
                "output size {} != batch {} × width {}",
                values.len(),
                self.batch,
                self.out_width
            );
        }
        Ok(xs
            .iter()
            .enumerate()
            .map(|(i, _)| values[i * self.out_width..(i + 1) * self.out_width].to_vec())
            .collect())
    }

    /// Classify: argmax over the first `num_classes` outputs.
    pub fn classify(&self, xs: &[Vec<f64>], num_classes: usize) -> Result<Vec<usize>> {
        let outs = self.infer(xs)?;
        // First-max tie-breaking, matching `nn::eval::classify_codes` (the
        // quantized outputs live on a coarse grid, so ties are common).
        Ok(outs
            .iter()
            .map(|o| {
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for (i, &v) in o.iter().take(num_classes).enumerate() {
                    if v > best_v {
                        best_v = v;
                        best = i;
                    }
                }
                best
            })
            .collect())
    }

    /// Classify an arbitrary-size set by chunking into compiled batches.
    pub fn classify_all(&self, xs: &[Vec<f64>], num_classes: usize) -> Result<Vec<usize>> {
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(self.batch) {
            out.extend(self.classify(chunk, num_classes)?);
        }
        Ok(out)
    }
}
