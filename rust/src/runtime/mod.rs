//! Runtime: loads and executes the AOT-compiled XLA artifacts via PJRT.
//!
//! Python never runs on the request path — `make artifacts` lowers the JAX
//! model (with its Pallas kernel) to HLO text once; [`pjrt::PjrtEngine`]
//! compiles and serves it from Rust.

pub mod pjrt;

pub use pjrt::{backend_available, PjrtEngine, RuntimeError};
