//! `nullanet` — the NullaNet Tiny command-line interface.
//!
//! ```text
//! nullanet flow    --arch jsc-s [--no-espresso] [--no-retime] [--jobs N]
//! nullanet table1  [--test-set artifacts/jsc_test.bin] [--quick]
//! nullanet verify  --arch jsc-s [--samples 2000]
//! nullanet serve   --arch jsc-s --addr 127.0.0.1:7878 --engine logic|pjrt|compare [--workers N]
//! nullanet emit    --arch jsc-s --format blif|verilog --out file
//! nullanet info    --arch jsc-s
//! ```
//!
//! Models and datasets come from `artifacts/` (built by `make artifacts`).

use std::process::ExitCode;
use std::sync::Arc;

use nullanet_tiny::baseline::{build_logicnets, AqpModel};
use nullanet_tiny::coordinator::{BatchPolicy, PjrtSpec, Policy, Router};
use nullanet_tiny::data::Dataset;
use nullanet_tiny::flow::{circuit_accuracy, run_flow, FlowConfig};
use nullanet_tiny::fpga::report::{format_table, Comparison, ResultRow};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::nn::model::{Arch, Model};
use nullanet_tiny::util::cli::Args;

fn main() -> ExitCode {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.command.as_deref() {
        Some("flow") => cmd_flow(&args),
        Some("table1") => cmd_table1(&args),
        Some("verify") => cmd_verify(&args),
        Some("serve") => cmd_serve(&args),
        Some("emit") => cmd_emit(&args),
        Some("info") => cmd_info(&args),
        Some(other) => Err(format!("unknown command '{other}'; see README")),
        None => {
            println!("usage: nullanet <flow|table1|verify|serve|emit|info> [options]");
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Resolve `--arch`/`--model` into a loaded model.
fn load_model(args: &Args) -> Result<Model, String> {
    if let Some(path) = args.get_opt("model") {
        return Model::load(path);
    }
    let arch = args.get_str("arch", "jsc-s");
    Arch::parse(&arch).ok_or_else(|| format!("unknown arch '{arch}'"))?;
    let dir = args.get_str("artifacts", "artifacts");
    Model::load(&format!("{dir}/{arch}.model.json"))
}

fn flow_config(args: &Args) -> Result<FlowConfig, String> {
    Ok(FlowConfig {
        use_espresso: !args.get_bool("no-espresso"),
        retime: !args.get_bool("no-retime"),
        dc_from_data: args.get_bool("dc-from-data"),
        jobs: args.get_usize("jobs", FlowConfig::default().jobs)?,
        map_for_area: args.get_bool("map-for-area"),
        verify: !args.get_bool("no-verify"),
        ..Default::default()
    })
}

fn cmd_flow(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "arch", "model", "artifacts", "no-espresso", "no-retime", "dc-from-data",
        "jobs", "map-for-area", "no-verify", "test-set",
    ])?;
    let model = load_model(args)?;
    println!("model: {}", model.summary());
    let cfg = flow_config(args)?;
    let dir = args.get_str("artifacts", "artifacts");
    let train = if cfg.dc_from_data {
        Some(Dataset::load(&format!("{dir}/jsc_train.bin")).map_err(|e| e.to_string())?)
    } else {
        None
    };
    let xs_ref = train.as_ref().map(|d| d.xs.as_slice());
    let r = run_flow(&model, &cfg, xs_ref).map_err(|e| e.to_string())?;
    println!("{}", r.timer.report("flow stages"));
    let stats = r.circuit.stats();
    let tm = TimingModel::vu9p();
    println!(
        "LUTs {}  FFs {}  stage-depth {}  fmax {:.0} MHz  latency {:.2} ns  \
         (cubes {} → {})",
        stats.luts,
        stats.ffs,
        stats.max_stage_depth,
        tm.fmax_mhz(stats.max_stage_depth),
        tm.latency_ns(stats.latency_cycles, stats.max_stage_depth),
        r.total_cubes_before,
        r.total_cubes_after,
    );
    let test_path = args.get_str("test-set", &format!("{dir}/jsc_test.bin"));
    if std::path::Path::new(&test_path).exists() {
        let test = Dataset::load(&test_path).map_err(|e| e.to_string())?;
        let acc = circuit_accuracy(&model, &r.circuit, &test.xs, &test.ys);
        println!("logic-circuit test accuracy: {:.2}%", acc * 100.0);
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<(), String> {
    args.check_known(&["artifacts", "jobs", "test-set", "quick"])?;
    let dir = args.get_str("artifacts", "artifacts");
    let test = Dataset::load(&args.get_str("test-set", &format!("{dir}/jsc_test.bin")))
        .map_err(|e| e.to_string())?;
    let jobs = args.get_usize("jobs", FlowConfig::default().jobs)?;
    let tm = TimingModel::vu9p();
    let mut rows = Vec::new();
    let archs: &[Arch] = if args.get_bool("quick") {
        &[Arch::JscS]
    } else {
        &[Arch::JscS, Arch::JscM, Arch::JscL]
    };
    for arch in archs {
        let name = arch.name();
        let ours_model = Model::load(&format!("{dir}/{name}.model.json"))?;
        let base_model = Model::load(&format!("{dir}/{name}.logicnets.model.json"))?;
        let cfg = FlowConfig { jobs, ..Default::default() };
        let r = run_flow(&ours_model, &cfg, None).map_err(|e| e.to_string())?;
        let ours_acc = circuit_accuracy(&ours_model, &r.circuit, &test.xs, &test.ys);
        let base = build_logicnets(&base_model, 6)?;
        let base_acc = circuit_accuracy(&base_model, &base.circuit, &test.xs, &test.ys);
        rows.push(Comparison {
            ours: ResultRow::from_stats(
                &name.to_uppercase(),
                ours_acc,
                r.circuit.stats(),
                &tm,
            ),
            baseline: ResultRow::from_stats(
                &name.to_uppercase(),
                base_acc,
                base.circuit.stats(),
                &tm,
            ),
        });
    }
    println!("\nTable I — NullaNet Tiny vs LogicNets (measured on this build)\n");
    print!("{}", format_table(&rows));
    // Headline claims (H1/H2).
    if let Some(m) = rows.iter().find(|c| c.ours.arch == "JSC-M") {
        let aqp = AqpModel::default();
        let ours_model = Model::load(&format!("{dir}/jsc-m.model.json"))?;
        let aqp_ns = aqp.latency_ns(&ours_model);
        println!(
            "\nheadlines: latency vs LogicNets {:.2}x lower; LUTs {:.2}x lower; \
             vs Google AQP {:.2}x lower ({:.1} ns vs {:.1} ns)",
            m.latency_decrease(),
            m.lut_decrease(),
            aqp_ns / m.ours.latency_ns,
            m.ours.latency_ns,
            aqp_ns,
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<(), String> {
    args.check_known(&["arch", "model", "artifacts", "samples", "jobs"])?;
    let model = load_model(args)?;
    let cfg = FlowConfig {
        jobs: args.get_usize("jobs", FlowConfig::default().jobs)?,
        ..Default::default()
    };
    let r = run_flow(&model, &cfg, None).map_err(|e| e.to_string())?;
    let n = args.get_usize("samples", 2000)?;
    nullanet_tiny::flow::build::verify_circuit(&model, &r.circuit, n, 0xBEEF)
        .map_err(|e| e.to_string())?;
    println!(
        "OK: circuit ≡ quantized NN on {n} random samples \
         (plus per-cover exhaustive checks during the flow)"
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "arch", "model", "artifacts", "addr", "engine", "max-batch", "max-wait-us",
        "jobs", "workers",
    ])?;
    let model = load_model(args)?;
    let cfg = FlowConfig {
        jobs: args.get_usize("jobs", FlowConfig::default().jobs)?,
        ..Default::default()
    };
    println!("synthesizing logic for {} …", model.summary());
    let r = run_flow(&model, &cfg, None).map_err(|e| e.to_string())?;
    let policy = Policy::parse(&args.get_str("engine", "logic"))
        .ok_or("bad --engine (logic|pjrt|compare)")?;
    let pjrt = if policy != Policy::Logic {
        let dir = args.get_str("artifacts", "artifacts");
        let arch = args.get_str("arch", "jsc-s");
        let out_w = model.layers.last().unwrap().out_width;
        Some(PjrtSpec {
            hlo_path: format!("{dir}/{arch}.hlo.txt"),
            batch: 64,
            in_features: model.input_features,
            out_width: out_w,
        })
    } else {
        None
    };
    let bp = BatchPolicy {
        max_batch: args.get_usize("max-batch", 64)?,
        max_wait: std::time::Duration::from_micros(
            args.get_usize("max-wait-us", 200)? as u64
        ),
    };
    // Logic-engine shard workers: batches spanning several 64-sample lane
    // groups are evaluated in parallel on one shared compiled netlist.
    let default_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4);
    let workers = args.get_usize("workers", default_workers)?;
    let router =
        Arc::new(Router::start(model, r.circuit.netlist, pjrt, policy, bp, workers));
    let addr = args.get_str("addr", "127.0.0.1:7878");
    println!("serving on {addr} (policy {policy:?}; send {{\"cmd\":\"shutdown\"}} to stop)");
    nullanet_tiny::coordinator::server::serve(Arc::clone(&router), &addr, None)
        .map_err(|e| e.to_string())?;
    println!("{}", router.metrics().report());
    Ok(())
}

fn cmd_emit(args: &Args) -> Result<(), String> {
    args.check_known(&["arch", "model", "artifacts", "format", "out", "jobs"])?;
    let model = load_model(args)?;
    let cfg = FlowConfig {
        jobs: args.get_usize("jobs", FlowConfig::default().jobs)?,
        ..Default::default()
    };
    let r = run_flow(&model, &cfg, None).map_err(|e| e.to_string())?;
    let name = model.name.replace('-', "_");
    let text = match args.get_str("format", "blif").as_str() {
        "blif" => nullanet_tiny::logic::blif::pipelined_to_blif(&r.circuit, &name),
        "verilog" => nullanet_tiny::logic::verilog::pipelined_to_verilog(&r.circuit, &name),
        f => return Err(format!("unknown format '{f}'")),
    };
    match args.get_opt("out") {
        Some(path) => {
            std::fs::write(path, text).map_err(|e| e.to_string())?;
            println!("wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<(), String> {
    args.check_known(&["arch", "model", "artifacts"])?;
    let model = load_model(args)?;
    println!("{}", model.summary());
    for (l, layer) in model.layers.iter().enumerate() {
        let in_bits = model.in_quant_of_layer(l).bits;
        println!(
            "  layer {l}: {}→{}  fanin ≤{}  neuron fn {} in / {} out bits  \
             (enumeration 2^{})",
            layer.in_width,
            layer.out_width,
            layer.max_fanin(),
            layer.max_fanin() * in_bits,
            layer.act.bits,
            layer.max_fanin() * in_bits,
        );
    }
    Ok(())
}
