//! `nullanet` — the NullaNet Tiny command-line interface.
//!
//! ```text
//! nullanet flow      --arch jsc-s [--no-espresso] [--no-retime] [--jobs N]
//! nullanet compile   --arch jsc-s [--out artifacts/jsc-s.circuit.json]
//! nullanet table1    [--test-set artifacts/jsc_test.bin] [--quick]
//! nullanet verify    --arch jsc-s [--samples 2000] [--circuit file.circuit.json]
//! nullanet serve     --arch jsc-s --addr 127.0.0.1:7878
//!                    --engine logic|pjrt|compare|native
//!                    [--circuit file.circuit.json] [--workers N]
//!                    [--event-loop] [--max-queue-depth N] [--deadline-ms N]
//! nullanet serve     --models artifacts/circuits [--default-model name]
//!                    [--engine logic|native] [--addr …] [--max-batch N]
//!                    [--max-wait-us N] [--workers N]
//!                    [--event-loop] [--max-queue-depth N] [--deadline-ms N]
//! nullanet codegen   --arch jsc-s [--circuit file.circuit.json] [--out file.so]
//! nullanet bench     [--out BENCH_9.json] [--batch N] [--quick] [--jobs N]
//! nullanet bench     --serve [--out BENCH_8.json] [--conns N] [--reqs N] [--quick]
//! nullanet emit      --arch jsc-s --format blif|verilog --out file
//! nullanet info      --arch jsc-s
//! nullanet check     bundle.json [...]        (structural lint)
//! nullanet check     --cec a.json b.json      (SAT equivalence proof)
//! nullanet check     --locks                  (serving-stack lock-order analysis)
//! nullanet check     --faults                 (fault-injection point inventory)
//! nullanet gen-model --features 6 --widths 5,4 --fanin 2 --act-bits 1 --out m.json
//! ```
//!
//! Models and datasets come from `artifacts/` (built by `make artifacts`).
//! `compile` persists the synthesized circuit as a fingerprint-bound
//! artifact; `--circuit` on `serve`/`emit`/`verify` loads it back instead
//! of re-running synthesis. See the root `README.md` for the full workflow
//! and the JSON wire protocol.

use std::process::ExitCode;
use std::sync::Arc;

use nullanet_tiny::baseline::{build_logicnets, AqpModel};
use nullanet_tiny::coordinator::{
    BatchPolicy, ModelRegistry, PjrtSpec, Policy, RegistryConfig, RouterBuilder,
};
use nullanet_tiny::data::Dataset;
use nullanet_tiny::error::NnError;
use nullanet_tiny::flow::{artifact, circuit_accuracy, run_flow, FlowConfig};
use nullanet_tiny::fpga::report::{format_opt_stats, format_table, Comparison, ResultRow};
use nullanet_tiny::fpga::timing::TimingModel;
use nullanet_tiny::logic::cec::{check_netlists, CecResult};
use nullanet_tiny::logic::check::CheckError;
use nullanet_tiny::logic::netlist::PipelinedCircuit;
use nullanet_tiny::logic::sim::{CompiledNetlist, ShardRunner};
use nullanet_tiny::nn::eval::{codes_to_bitvec, quantize_input};
use nullanet_tiny::nn::model::{random_model, Arch, Model};
use nullanet_tiny::util::bench::{Bench, BenchStats};
use nullanet_tiny::util::bitvec::PackedBatch;
use nullanet_tiny::util::cli::Args;
use nullanet_tiny::util::json::Json;
use nullanet_tiny::util::prng::Xoshiro256;
use nullanet_tiny::util::threadpool::ThreadPool;

fn main() -> ExitCode {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.command.as_deref() {
        Some("flow") => cmd_flow(&args),
        Some("compile") => cmd_compile(&args),
        Some("table1") => cmd_table1(&args),
        Some("verify") => cmd_verify(&args),
        Some("serve") => cmd_serve(&args),
        Some("codegen") => cmd_codegen(&args),
        Some("bench") => cmd_bench(&args),
        Some("emit") => cmd_emit(&args),
        Some("info") => cmd_info(&args),
        Some("check") => cmd_check(&args),
        Some("gen-model") => cmd_gen_model(&args),
        Some(other) => {
            Err(NnError::Config(format!("unknown command '{other}'; see README.md")))
        }
        None => {
            println!(
                "usage: nullanet <flow|compile|table1|verify|serve|codegen|bench|emit|\
                 info|check|gen-model> [options]"
            );
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Lift a CLI-layer `String` error into the typed crate error.
fn conf<T>(r: Result<T, String>) -> Result<T, NnError> {
    r.map_err(NnError::Config)
}

/// Resolve `--arch`/`--model` into a loaded model.
fn load_model(args: &Args) -> Result<Model, NnError> {
    if let Some(path) = args.get_opt("model") {
        return Model::load(path).map_err(NnError::Data);
    }
    let arch = args.get_str("arch", "jsc-s");
    Arch::parse(&arch).ok_or_else(|| NnError::Config(format!("unknown arch '{arch}'")))?;
    let dir = args.get_str("artifacts", "artifacts");
    Model::load(&format!("{dir}/{arch}.model.json")).map_err(NnError::Data)
}

fn flow_config(args: &Args) -> Result<FlowConfig, NnError> {
    Ok(FlowConfig {
        use_espresso: !args.get_bool("no-espresso"),
        retime: !args.get_bool("no-retime"),
        dc_from_data: args.get_bool("dc-from-data"),
        jobs: conf(args.get_usize("jobs", FlowConfig::default().jobs))?,
        map_for_area: args.get_bool("map-for-area"),
        verify: !args.get_bool("no-verify"),
        ..Default::default()
    })
}

/// Load the training set when `--dc-from-data` is active (the flow derives
/// don't-cares from observed activations).
fn load_dc_traces(args: &Args, cfg: &FlowConfig) -> Result<Option<Dataset>, NnError> {
    if !cfg.dc_from_data {
        return Ok(None);
    }
    let dir = args.get_str("artifacts", "artifacts");
    Ok(Some(Dataset::load(&format!("{dir}/jsc_train.bin"))?))
}

/// Resolve the circuit for `serve`/`emit`/`verify`: load a compiled,
/// fingerprint-checked artifact when `--circuit` is given (no synthesis),
/// otherwise run the full flow.
fn load_or_synthesize(args: &Args, model: &Model) -> Result<PipelinedCircuit, NnError> {
    if let Some(path) = args.get_opt("circuit") {
        let circuit = artifact::load_circuit(path, model)?;
        println!(
            "loaded compiled circuit {path} ({} LUTs, {} stages)",
            circuit.netlist.num_luts(),
            circuit.num_stages
        );
        return Ok(circuit);
    }
    println!("synthesizing logic for {} …", model.summary());
    let cfg = flow_config(args)?;
    Ok(run_flow(model, &cfg, None)?.circuit)
}

fn cmd_flow(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&[
        "arch", "model", "artifacts", "no-espresso", "no-retime", "dc-from-data",
        "jobs", "map-for-area", "no-verify", "test-set",
    ]))?;
    let model = load_model(args)?;
    println!("model: {}", model.summary());
    let cfg = flow_config(args)?;
    let dir = args.get_str("artifacts", "artifacts");
    let train = load_dc_traces(args, &cfg)?;
    let xs_ref = train.as_ref().map(|d| d.xs.as_slice());
    let r = run_flow(&model, &cfg, xs_ref)?;
    println!("{}", r.timer.report("flow stages"));
    let stats = r.circuit.stats();
    let tm = TimingModel::vu9p();
    println!(
        "LUTs {}  FFs {}  stage-depth {}  fmax {:.0} MHz  latency {:.2} ns  \
         (cubes {} → {})",
        stats.luts,
        stats.ffs,
        stats.max_stage_depth,
        tm.fmax_mhz(stats.max_stage_depth),
        tm.latency_ns(stats.latency_cycles, stats.max_stage_depth),
        r.total_cubes_before,
        r.total_cubes_after,
    );
    println!("{}", format_opt_stats(&r.opt));
    let test_path = args.get_str("test-set", &format!("{dir}/jsc_test.bin"));
    if std::path::Path::new(&test_path).exists() {
        let test = Dataset::load(&test_path)?;
        let acc = circuit_accuracy(&model, &r.circuit, &test.xs, &test.ys);
        println!("logic-circuit test accuracy: {:.2}%", acc * 100.0);
    }
    Ok(())
}

/// Synthesize once, persist the circuit as a reloadable artifact.
fn cmd_compile(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&[
        "arch", "model", "artifacts", "out", "no-espresso", "no-retime",
        "dc-from-data", "jobs", "map-for-area", "no-verify",
    ]))?;
    let model = load_model(args)?;
    println!("model: {}", model.summary());
    let cfg = flow_config(args)?;
    let dir = args.get_str("artifacts", "artifacts");
    let train = load_dc_traces(args, &cfg)?;
    let xs_ref = train.as_ref().map(|d| d.xs.as_slice());
    let r = run_flow(&model, &cfg, xs_ref)?;
    let out = args.get_str("out", &format!("{dir}/{}.circuit.json", model.name));
    artifact::save_circuit(&out, &r.circuit, &model)?;
    let stats = r.circuit.stats();
    println!(
        "wrote {out}: {} LUTs, {} FFs, {} stages (fingerprint {})",
        stats.luts,
        stats.ffs,
        r.circuit.num_stages,
        artifact::model_fingerprint(&model),
    );
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&["artifacts", "jobs", "test-set", "quick"]))?;
    let dir = args.get_str("artifacts", "artifacts");
    let test = Dataset::load(&args.get_str("test-set", &format!("{dir}/jsc_test.bin")))?;
    let jobs = conf(args.get_usize("jobs", FlowConfig::default().jobs))?;
    let tm = TimingModel::vu9p();
    let mut rows = Vec::new();
    let archs: &[Arch] = if args.get_bool("quick") {
        &[Arch::JscS]
    } else {
        &[Arch::JscS, Arch::JscM, Arch::JscL]
    };
    for arch in archs {
        let name = arch.name();
        let ours_model =
            Model::load(&format!("{dir}/{name}.model.json")).map_err(NnError::Data)?;
        let base_model = Model::load(&format!("{dir}/{name}.logicnets.model.json"))
            .map_err(NnError::Data)?;
        let cfg = FlowConfig { jobs, ..Default::default() };
        let r = run_flow(&ours_model, &cfg, None)?;
        let ours_acc = circuit_accuracy(&ours_model, &r.circuit, &test.xs, &test.ys);
        let base = build_logicnets(&base_model, 6).map_err(NnError::Flow)?;
        let base_acc = circuit_accuracy(&base_model, &base.circuit, &test.xs, &test.ys);
        rows.push(Comparison {
            ours: ResultRow::from_stats(
                &name.to_uppercase(),
                ours_acc,
                r.circuit.stats(),
                &tm,
            ),
            baseline: ResultRow::from_stats(
                &name.to_uppercase(),
                base_acc,
                base.circuit.stats(),
                &tm,
            ),
        });
    }
    println!("\nTable I — NullaNet Tiny vs LogicNets (measured on this build)\n");
    print!("{}", format_table(&rows));
    // Headline claims (H1/H2).
    if let Some(m) = rows.iter().find(|c| c.ours.arch == "JSC-M") {
        let aqp = AqpModel::default();
        let ours_model =
            Model::load(&format!("{dir}/jsc-m.model.json")).map_err(NnError::Data)?;
        let aqp_ns = aqp.latency_ns(&ours_model);
        println!(
            "\nheadlines: latency vs LogicNets {:.2}x lower; LUTs {:.2}x lower; \
             vs Google AQP {:.2}x lower ({:.1} ns vs {:.1} ns)",
            m.latency_decrease(),
            m.lut_decrease(),
            aqp_ns / m.ours.latency_ns,
            m.ours.latency_ns,
            aqp_ns,
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&["arch", "model", "artifacts", "samples", "jobs", "circuit"]))?;
    let model = load_model(args)?;
    let circuit = load_or_synthesize(args, &model)?;
    let n = conf(args.get_usize("samples", 2000))?;
    nullanet_tiny::flow::build::verify_circuit(&model, &circuit, n, 0xBEEF)?;
    println!("OK: circuit ≡ quantized NN on {n} random samples");
    Ok(())
}

/// Run the chosen accept path. `--event-loop` prefers the epoll front end
/// and falls back to the blocking path (with a notice) where epoll is
/// unavailable, so the flag is safe in portable scripts.
fn run_server(
    registry: &Arc<ModelRegistry>,
    addr: &str,
    event_loop: bool,
) -> Result<(), NnError> {
    let res = if event_loop {
        match nullanet_tiny::coordinator::server::serve_event(
            Arc::clone(registry),
            addr,
            None,
        ) {
            Err(e) if e.kind() == std::io::ErrorKind::Unsupported => {
                println!("(--event-loop unsupported here; using the blocking accept loop)");
                nullanet_tiny::coordinator::server::serve(Arc::clone(registry), addr, None)
            }
            r => r,
        }
    } else {
        nullanet_tiny::coordinator::server::serve(Arc::clone(registry), addr, None)
    };
    res.map_err(|e| NnError::Config(format!("serve on {addr}: {e}")))
}

fn cmd_serve(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&[
        "arch", "model", "artifacts", "addr", "engine", "max-batch", "max-wait-us",
        "jobs", "workers", "circuit", "models", "default-model", "event-loop",
        "max-queue-depth", "deadline-ms",
    ]))?;
    let bp = BatchPolicy {
        max_batch: conf(args.get_usize("max-batch", 64))?,
        max_wait: std::time::Duration::from_micros(
            conf(args.get_usize("max-wait-us", 200))? as u64,
        ),
        // Admission control: classifies beyond this many queued samples per
        // model are rejected with a typed overload reply instead of queued.
        max_depth: conf(args.get_usize("max-queue-depth", BatchPolicy::default().max_depth))?,
    };
    let event_loop = args.get_bool("event-loop");
    // Logic-engine shard workers: batches spanning several 64-sample lane
    // groups are evaluated in parallel on one shared compiled netlist.
    let workers = conf(args.get_usize("workers", RouterBuilder::default_workers()))?;
    // Deadline-driven shedding: a request still queued when its budget
    // elapses is dropped with a typed deadline reply instead of served
    // late. This flag sets the server-wide default budget (0 = none);
    // per-request `deadline_ms` / type-6 frames always override it.
    let deadline_ms = conf(args.get_usize("deadline-ms", 0))? as u64;
    nullanet_tiny::coordinator::server::set_default_deadline_ms(
        (deadline_ms > 0).then_some(deadline_ms),
    );

    // Multi-model mode: scan a directory of self-contained circuit bundles
    // and serve every one from the registry (each under its model name,
    // each with its own batcher + engine stack). Hot-swap/load/unload then
    // happen live over the wire protocol.
    if let Some(dir) = args.get_opt("models") {
        let engine = args.get_str("engine", "logic");
        let dir_policy = match engine.as_str() {
            "logic" => Policy::Logic,
            "native" => Policy::Native,
            _ => {
                return Err(NnError::Config(
                    "--models serves compiled logic circuits (--engine logic|native); \
                     --engine pjrt/compare needs the single-model path (--arch/--model)"
                        .into(),
                ))
            }
        };
        if args.get_opt("arch").is_some()
            || args.get_opt("model").is_some()
            || args.get_opt("circuit").is_some()
        {
            return Err(NnError::Config(
                "--models replaces --arch/--model/--circuit (the bundles carry \
                 their own models)"
                    .into(),
            ));
        }
        let registry = Arc::new(ModelRegistry::new(RegistryConfig {
            batch_policy: bp,
            workers,
            policy: dir_policy,
        }));
        let loaded = registry.load_dir(dir)?;
        if loaded.is_empty() {
            return Err(NnError::Config(format!(
                "--models {dir}: no circuit bundles found (compile some with \
                 `nullanet compile`)"
            )));
        }
        if let Some(name) = args.get_opt("default-model") {
            registry.set_default(name)?;
        }
        for info in registry.infos() {
            let tag = if info.default { " (default)" } else { "" };
            println!(
                "model '{}'{tag}: {} features, engine '{}'{}",
                info.name,
                info.features,
                info.engine,
                info.source.map(|s| format!(", from {s}")).unwrap_or_default(),
            );
        }
        let addr = args.get_str("addr", "127.0.0.1:7878");
        println!(
            "serving {} models on {addr} (send {{\"cmd\":\"shutdown\"}} to stop)",
            registry.len()
        );
        run_server(&registry, &addr, event_loop)?;
        println!("{}", registry.metrics_report());
        return Ok(());
    }

    let model = load_model(args)?;
    let policy = Policy::parse(&args.get_str("engine", "logic"))
        .ok_or_else(|| NnError::Config("bad --engine (logic|pjrt|compare|native)".into()))?;
    if policy == Policy::Numeric && args.get_opt("circuit").is_some() {
        return Err(NnError::Config(
            "--circuit is unused with --engine pjrt (the numeric engine loads the \
             HLO artifact, not a logic circuit); drop it or pick logic/compare"
                .into(),
        ));
    }
    let mut builder = RouterBuilder::new(model.clone())
        .engine(policy)
        .batch_policy(bp)
        .workers(workers);
    if policy != Policy::Numeric {
        // Artifact cold-start path: `--circuit` loads the compiled netlist
        // (fingerprint-checked) instead of re-running the synthesis flow.
        let circuit = load_or_synthesize(args, &model)?;
        builder = builder.circuit(circuit.netlist);
    }
    if policy == Policy::Native {
        // Cache the generated `.so` next to the circuit bundle when one was
        // given; a synthesized-on-the-fly circuit uses the temp-dir default.
        if let Some(path) = args.get_opt("circuit") {
            builder = builder.native_cache(artifact::native_so_path(path));
        }
    }
    if matches!(policy, Policy::Numeric | Policy::Compare) {
        let dir = args.get_str("artifacts", "artifacts");
        let arch = args.get_str("arch", "jsc-s");
        let out_w = model.layers.last().map(|l| l.out_width).unwrap_or(model.num_classes);
        let spec = PjrtSpec {
            hlo_path: format!("{dir}/{arch}.hlo.txt"),
            batch: 64,
            in_features: model.input_features,
            out_width: out_w,
        };
        // Compare degrades gracefully: without a loadable numeric reference
        // the router serves logic alone. Numeric has no fallback — the spec
        // is attached unconditionally so build() reports the typed error.
        if policy == Policy::Numeric {
            builder = builder.pjrt(spec);
        } else {
            match spec.preflight() {
                Ok(()) => builder = builder.pjrt(spec),
                Err(e) => println!(
                    "(compare: numeric shadow unavailable, serving logic alone — {e})"
                ),
            }
        }
    }
    let router = builder.build()?;
    let engine_name = router.engine_name();
    // Single model behind the same registry front end: it becomes the
    // default, so clients that never send a "model" field are unaffected,
    // and live {"cmd":"load"} can still add more models beside it. The
    // registry carries the CLI batch/worker tuning so those live loads
    // build their engines with it, not with hardcoded defaults.
    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        batch_policy: bp,
        workers,
        // Live {"cmd":"load"} bundles build with the serve engine when it is
        // one the registry can construct standalone (logic/native); the
        // pjrt/compare paths need an HLO spec only the CLI single-model
        // path carries, so their live loads fall back to the interpreter.
        policy: if policy == Policy::Native { Policy::Native } else { Policy::Logic },
    }));
    registry.install(&model.name, router, None)?;
    let addr = args.get_str("addr", "127.0.0.1:7878");
    println!(
        "serving model '{}' on {addr} (policy {policy:?}, engine '{engine_name}'; \
         send {{\"cmd\":\"shutdown\"}} to stop)",
        model.name
    );
    run_server(&registry, &addr, event_loop)?;
    println!("{}", registry.metrics_report());
    Ok(())
}

/// `nullanet codegen`: lower the compiled netlist to straight-line Rust,
/// build it as a shared object with `rustc`, load it back through `dlopen`,
/// and self-check it word-exactly against the interpreter. The `.so` (with
/// its `.rs` source and rustc-version sidecar) lands at `--out`, defaulting
/// next to `--circuit` — exactly where `serve --engine native` looks for
/// it, so this command is the cache-warming step before deployment.
fn cmd_codegen(args: &Args) -> Result<(), NnError> {
    use nullanet_tiny::logic::codegen;
    use nullanet_tiny::util::bitvec::mask_group_tail;

    conf(args.check_known(&[
        "arch", "model", "artifacts", "circuit", "out", "samples", "jobs",
        "no-espresso", "no-retime", "dc-from-data", "map-for-area", "no-verify",
    ]))?;
    let model = load_model(args)?;
    let circuit = load_or_synthesize(args, &model)?;
    let sim = CompiledNetlist::compile(&circuit.netlist);
    let fp = artifact::model_fingerprint(&model);
    let so_path = match (args.get_opt("out"), args.get_opt("circuit")) {
        (Some(out), _) => out.to_string(),
        (None, Some(circuit_path)) => artifact::native_so_path(circuit_path),
        (None, None) => codegen::default_cache_path(&fp),
    };
    let (lib, outcome) = codegen::load_or_build(&sim, &fp, &so_path)
        .map_err(|e| NnError::Config(format!("codegen: {e}")))?;
    match outcome {
        codegen::CacheOutcome::Cached => {
            println!("cache hit: {so_path} is current (fingerprint {fp})")
        }
        codegen::CacheOutcome::Rebuilt(reason) => {
            println!("built {so_path} ({reason}; fingerprint {fp})")
        }
    }
    // Self-check: the loaded native library must agree word-exactly with
    // the interpreter on random packed inputs before anyone serves it.
    let samples = conf(args.get_usize("samples", 512))?;
    let ni = sim.num_inputs();
    let no = sim.num_outputs();
    let mut rng = Xoshiro256::new(0xC0DE);
    let mut packed = PackedBatch::with_capacity(ni, samples);
    for _ in 0..samples {
        let bits: Vec<bool> = (0..ni).map(|_| rng.next_u64() & 1 == 1).collect();
        packed.push_sample_bools(&bits);
    }
    let groups = packed.num_groups();
    let mut native_out = vec![0u64; groups * no];
    lib.eval_groups(packed.words(), groups, &mut native_out);
    mask_group_tail(&mut native_out, no, samples);
    let mut scratch = sim.make_scratch();
    let reference = sim.run_packed(&packed, &mut scratch);
    let mut ref_out = reference.words().to_vec();
    mask_group_tail(&mut ref_out, no, samples);
    if native_out != ref_out {
        return Err(NnError::Config(format!(
            "codegen self-check FAILED: native output diverges from the \
             interpreter on {samples} random samples ({so_path})"
        )));
    }
    println!(
        "self-check OK: native ≡ interpreter on {samples} random samples \
         ({} LUTs, {} inputs, {} outputs)",
        sim.num_luts(),
        ni,
        no,
    );
    Ok(())
}

/// One kernel measurement as a JSON row (`nullanet bench`).
fn kernel_row(width: usize, optimized: bool, s: &BenchStats, n: f64) -> Json {
    Json::obj([
        ("width", Json::int(width as i64)),
        ("optimized", Json::Bool(optimized)),
        ("ns_per_sample", Json::float(s.median_ns / n)),
        ("samples_per_sec", Json::float(n * 1e9 / s.median_ns)),
    ])
}

/// Fixed-seed packed-throughput benchmark. Writes machine-readable
/// `BENCH_9.json`: ns/sample and samples/sec for every interpreter kernel
/// width (W ∈ {1,2,4,8}), shard-worker counts, the optimizer's pre/post LUT
/// counts, the three-way interpreter vs SIMD-interpreter vs native-codegen
/// comparison, and the headline `speedup_native_vs_w4_opt` — the number the
/// `BENCH_*.json` perf trajectory is tracked by from this PR on. A shrunk
/// loopback serving sweep rides along under `"serve"` so one command covers
/// both the kernel and the wire path. Deterministic: models come from
/// fixed-seed `gen-model` specs, inputs from a fixed-seed PRNG, so no
/// trained artifacts are needed. `--quick` (CI smoke) shrinks the model
/// set, batch, and serve sweep (8 conns × 64 reqs); `NNT_BENCH_FAST=1`
/// shrinks the measurement windows.
fn cmd_bench(args: &Args) -> Result<(), NnError> {
    use nullanet_tiny::logic::codegen;

    conf(args.check_known(&["out", "batch", "quick", "jobs", "serve", "conns", "reqs"]))?;
    if args.get_bool("serve") {
        return cmd_bench_serve(args);
    }
    let quick = args.get_bool("quick");
    let out_path = args.get_str("out", "BENCH_9.json");
    let batch_n = conf(args.get_usize("batch", if quick { 256 } else { 4096 }))?;
    let jobs = conf(args.get_usize("jobs", FlowConfig::default().jobs))?;
    let models: Vec<Model> = if quick {
        vec![random_model("bench-s", 8, &[6, 4], 2, 1, 5)]
    } else {
        vec![
            random_model("bench-s", 8, &[6, 4], 2, 1, 5),
            random_model("bench-m", 16, &[32, 16, 5], 3, 2, 5),
        ]
    };
    let mut bench = Bench::new();
    let mut model_rows: Vec<Json> = Vec::new();
    let mut all_beat_baseline = true;
    for model in &models {
        println!("model {}: synthesizing…", model.summary());
        let cfg = FlowConfig { verify: false, jobs, ..Default::default() };
        let r = run_flow(model, &cfg, None)?;
        let netlist = r.circuit.netlist;
        let sim_opt = std::sync::Arc::new(CompiledNetlist::compile(&netlist));
        let sim_raw = std::sync::Arc::new(CompiledNetlist::compile_unoptimized(&netlist));
        println!("  {}", format_opt_stats(sim_opt.opt_stats()));

        // Fixed-seed inputs, quantized + packed once.
        let mut rng = Xoshiro256::new(0xBEBE);
        let mut packed = PackedBatch::with_capacity(model.input_bits(), batch_n);
        for _ in 0..batch_n {
            let x: Vec<f64> = (0..model.input_features)
                .map(|_| 2.0 * rng.next_gaussian())
                .collect();
            let codes = quantize_input(model, &x);
            packed.push_sample(&codes_to_bitvec(&codes, model.input_quant.bits));
        }
        let groups = packed.num_groups();
        let no = sim_opt.num_outputs();
        let mut out = vec![0u64; groups * no];
        let n = batch_n as f64;

        // Baseline: the pre-PR path — W=1 kernel, unoptimized netlist.
        let mut kernels: Vec<Json> = Vec::new();
        let mut scratch_raw = sim_raw.make_scratch();
        let base = bench.run(&format!("{} W=1 unoptimized", model.name), || {
            sim_raw.run_groups_capped(&packed, 0, groups, &mut scratch_raw, &mut out, 1)
        });
        kernels.push(kernel_row(1, false, &base, n));

        let mut scratch = sim_opt.make_scratch();
        let mut w4_ns = base.median_ns;
        for width in [1usize, 2, 4, 8] {
            let s = bench.run(&format!("{} W={width} optimized", model.name), || {
                sim_opt.run_groups_capped(&packed, 0, groups, &mut scratch, &mut out, width)
            });
            if width == 4 {
                w4_ns = s.median_ns;
            }
            kernels.push(kernel_row(width, true, &s, n));
        }

        let mut sharded: Vec<Json> = Vec::new();
        let shared = std::sync::Arc::new(packed);
        for workers in [1usize, 2, 4] {
            let pool = ThreadPool::new(workers);
            let mut runner = ShardRunner::new(&sim_opt);
            let s = bench.run(&format!("{} sharded x{workers}", model.name), || {
                runner.run(&sim_opt, &pool, &shared);
            });
            sharded.push(Json::obj([
                ("workers", Json::int(workers as i64)),
                ("ns_per_sample", Json::float(s.median_ns / n)),
                ("samples_per_sec", Json::float(n * 1e9 / s.median_ns)),
            ]));
        }

        // Tentpole three-way comparison: the same packed batch through the
        // rustc-built straight-line kernel. The interpreter rows above are
        // already SIMD-dispatched (the detected-ISA monomorphization), so
        // this is interpreter vs native head-to-head. Hosts without rustc
        // keep the interpreter rows and record null for the native side.
        let mut native_row = Json::Null;
        let mut native_speedup = Json::Null;
        if codegen::rustc_available() {
            let fp = artifact::model_fingerprint(model);
            match codegen::load_or_build(&sim_opt, &fp, &codegen::default_cache_path(&fp))
            {
                Ok((lib, _)) => {
                    let words = shared.words();
                    let s = bench.run(&format!("{} native codegen", model.name), || {
                        lib.eval_groups(words, groups, &mut out)
                    });
                    let sp = w4_ns / s.median_ns;
                    println!("  speedup native vs W=4 optimized: {sp:.2}x");
                    all_beat_baseline &= sp >= 1.0;
                    native_row = Json::obj([
                        ("ns_per_sample", Json::float(s.median_ns / n)),
                        ("samples_per_sec", Json::float(n * 1e9 / s.median_ns)),
                        ("isa", Json::str(format!("{:?}", sim_opt.kernel_isa()))),
                    ]);
                    native_speedup = Json::float(sp);
                }
                Err(e) => println!("  native codegen unavailable: {e}"),
            }
        } else {
            println!("  native codegen skipped (no rustc on this host)");
        }

        let speedup = base.median_ns / w4_ns;
        println!("  speedup W=4+optimizer vs W=1 unoptimized: {speedup:.2}x");
        all_beat_baseline &= speedup >= 1.0;
        let os = sim_opt.opt_stats();
        model_rows.push(Json::obj([
            ("name", Json::str(model.name.clone())),
            ("inputs", Json::int(sim_opt.num_inputs() as i64)),
            ("outputs", Json::int(no as i64)),
            ("batch", Json::int(batch_n as i64)),
            ("luts_pre_opt", Json::int(os.luts_before as i64)),
            ("luts_post_opt", Json::int(os.luts_after as i64)),
            ("kernels", Json::Arr(kernels)),
            ("sharded", Json::Arr(sharded)),
            ("native", native_row),
            ("speedup_w4_opt_vs_w1_unopt", Json::float(speedup)),
            ("speedup_native_vs_w4_opt", native_speedup),
        ]));
    }
    // Shrunk loopback serving sweep (satellite of the codegen PR): the full
    // `bench --serve` matrix at reduced volume, so BENCH_9 also tracks the
    // wire path without a second command.
    let (sv_conns, sv_reqs) = if quick { (8, 64) } else { (16, 256) };
    let serve_section = serve_sweep(sv_conns, sv_reqs)?;
    let doc = Json::obj([
        ("schema", Json::str("nullanet-bench")),
        ("version", Json::int(1)),
        ("bench_id", Json::int(9)),
        ("quick", Json::Bool(quick)),
        ("models", Json::Arr(model_rows)),
        ("serve", serve_section),
    ]);
    std::fs::write(&out_path, format!("{}\n", doc.to_pretty_string()))
        .map_err(|e| NnError::Config(format!("write {out_path}: {e}")))?;
    println!("wrote {out_path}");
    if !all_beat_baseline {
        println!(
            "warning: a W=4+optimizer kernel did not beat its W=1 unoptimized baseline"
        );
    }
    Ok(())
}

/// Nearest-rank percentile of a sorted sample set (µs).
fn pct_us(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One client connection's worth of pipelined requests. `frames[i]` is the
/// pre-encoded request (a JSON line or a binary frame); `read_reply` pulls
/// exactly one response off the stream. Keeps up to `window` requests in
/// flight and returns one latency sample (µs) per request.
fn drive_pipelined<F>(
    addr: std::net::SocketAddr,
    frames: &[Vec<u8>],
    window: usize,
    mut read_reply: F,
) -> std::io::Result<Vec<f64>>
where
    F: FnMut(&mut std::net::TcpStream, &mut Vec<u8>) -> std::io::Result<()>,
{
    use std::io::Write;
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut latencies = Vec::with_capacity(frames.len());
    let mut in_flight: std::collections::VecDeque<std::time::Instant> =
        std::collections::VecDeque::new();
    let mut buf: Vec<u8> = Vec::new();
    let mut next = 0usize;
    let mut received = 0usize;
    while received < frames.len() {
        while next < frames.len() && in_flight.len() < window {
            stream.write_all(&frames[next])?;
            in_flight.push_back(std::time::Instant::now());
            next += 1;
        }
        read_reply(&mut stream, &mut buf)?;
        let t0 = in_flight.pop_front().expect("a reply implies a request in flight");
        latencies.push(t0.elapsed().as_secs_f64() * 1e6);
        received += 1;
    }
    Ok(latencies)
}

/// Pull one newline-terminated JSON reply into `buf`, then consume it.
fn read_json_reply(
    stream: &mut std::net::TcpStream,
    buf: &mut Vec<u8>,
) -> std::io::Result<()> {
    use std::io::Read;
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            buf.drain(..=pos);
            return Ok(());
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed mid-reply",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// Pull one length-prefixed binary reply into `buf`, then consume it.
fn read_frame_reply(
    stream: &mut std::net::TcpStream,
    buf: &mut Vec<u8>,
) -> std::io::Result<()> {
    use nullanet_tiny::coordinator::frame;
    use std::io::Read;
    let mut chunk = [0u8; 4096];
    loop {
        match frame::decode(buf)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?
        {
            Some((_f, n)) => {
                buf.drain(..n);
                return Ok(());
            }
            None => {
                let n = stream.read(&mut chunk)?;
                if n == 0 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "server closed mid-frame",
                    ));
                }
                buf.extend_from_slice(&chunk[..n]);
            }
        }
    }
}

/// `bench --serve`: loopback serving benchmark. Mode 1 drives JSON lines
/// through the blocking thread-per-connection accept path (the pre-PR
/// serving stack, strict request/reply per connection); mode 2 drives
/// binary frames through the epoll event loop with `window` requests
/// pipelined per connection. Deterministic inputs (fixed-seed model and
/// PRNG); writes `BENCH_8.json` with p50/p99 latency (raw and normalized
/// per in-flight request, so the two windows compare apples-to-apples) and
/// req/s per mode plus the binary-over-JSON throughput speedup.
/// `--quick`/`NNT_BENCH_FAST=1` shrink the connection count and request
/// volume for CI smoke.
fn cmd_bench_serve(args: &Args) -> Result<(), NnError> {
    let quick = args.get_bool("quick") || std::env::var("NNT_BENCH_FAST").is_ok();
    let out_path = args.get_str("out", "BENCH_8.json");
    let conns = conf(args.get_usize("conns", if quick { 8 } else { 64 }))?;
    let reqs = conf(args.get_usize("reqs", if quick { 64 } else { 1024 }))?;
    let serve_section = serve_sweep(conns, reqs)?;
    let doc = Json::obj([
        ("schema", Json::str("nullanet-bench")),
        ("version", Json::int(1)),
        ("bench_id", Json::int(8)),
        ("quick", Json::Bool(quick)),
        ("serve", serve_section),
    ]);
    std::fs::write(&out_path, format!("{}\n", doc.to_pretty_string()))
        .map_err(|e| NnError::Config(format!("write {out_path}: {e}")))?;
    println!("wrote {out_path}");
    Ok(())
}

/// The shared loopback serving sweep behind both `bench --serve` (full
/// volume, BENCH_8) and plain `bench` (shrunk ride-along section in
/// BENCH_9). Returns the `"serve"` JSON section. Latencies are reported
/// raw and normalized per in-flight request: the JSON mode runs strict
/// request/reply (window 1) while the binary mode keeps `window` requests
/// pipelined, so raw p50s are not comparable across modes — the
/// `*_per_inflight_us` fields divide by each mode's recorded window.
fn serve_sweep(conns: usize, reqs: usize) -> Result<Json, NnError> {
    use nullanet_tiny::coordinator::frame;

    let window = 8usize;

    let model = random_model("bench-serve", 8, &[6, 4], 2, 1, 5);
    println!("model {}: synthesizing…", model.summary());
    let cfg = FlowConfig { verify: false, jobs: 2, ..Default::default() };
    let flow = run_flow(&model, &cfg, None)?;
    let netlist = flow.circuit.netlist;

    // Deterministic request mix shared by both modes.
    let mut rng = Xoshiro256::new(0xC0FFEE);
    let inputs: Vec<Vec<f64>> = (0..reqs)
        .map(|_| {
            (0..model.input_features).map(|_| 2.0 * rng.next_gaussian()).collect()
        })
        .collect();
    let json_frames: Vec<Vec<u8>> = inputs
        .iter()
        .map(|x| {
            let vals: Vec<String> = x.iter().map(|v| format!("{v:.6}")).collect();
            format!("{{\"features\": [{}]}}\n", vals.join(", ")).into_bytes()
        })
        .collect();
    let bin_frames: Vec<Vec<u8>> = inputs
        .iter()
        .map(|x| {
            let codes = quantize_input(&model, x);
            let bits = codes_to_bitvec(&codes, model.input_quant.bits);
            frame::encode_classify_req(None, bits.len() as u16, bits.words())
        })
        .collect();

    let mk_registry = |netlist: nullanet_tiny::logic::netlist::LutNetlist| {
        RouterBuilder::new(model.clone())
            .circuit(netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy {
                max_batch: 256,
                max_wait: std::time::Duration::from_micros(100),
                ..Default::default()
            })
            .workers(2)
            .build()
            .map(|router| Arc::new(ModelRegistry::with_default("bench-serve", router)))
    };

    // Each mode: spawn the server, hammer it from `conns` client threads,
    // then shut it down over the wire.
    let run_mode = |event_loop: bool,
                        frames: &[Vec<u8>],
                        win: usize,
                        json: bool|
     -> Result<(f64, f64, f64), NnError> {
        let registry = mk_registry(netlist.clone())?;
        let (tx, rx) = std::sync::mpsc::channel();
        let server = std::thread::spawn(move || {
            if event_loop {
                nullanet_tiny::coordinator::server::serve_event(
                    registry,
                    "127.0.0.1:0",
                    Some(tx),
                )
            } else {
                nullanet_tiny::coordinator::server::serve(registry, "127.0.0.1:0", Some(tx))
            }
        });
        let port = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .map_err(|_| NnError::Config("bench --serve: server did not start".into()))?;
        let addr = std::net::SocketAddr::from(([127, 0, 0, 1], port));
        let t0 = std::time::Instant::now();
        let mut workers = Vec::new();
        for _ in 0..conns {
            let frames = frames.to_vec();
            workers.push(std::thread::spawn(move || {
                if json {
                    drive_pipelined(addr, &frames, win, read_json_reply)
                } else {
                    drive_pipelined(addr, &frames, win, read_frame_reply)
                }
            }));
        }
        let mut latencies: Vec<f64> = Vec::with_capacity(conns * reqs);
        for w in workers {
            let lats = w
                .join()
                .map_err(|_| NnError::Config("bench --serve: client panicked".into()))?
                .map_err(|e| NnError::Config(format!("bench --serve client: {e}")))?;
            latencies.extend(lats);
        }
        let wall = t0.elapsed().as_secs_f64();
        // Orderly shutdown over the JSON protocol (both paths speak it).
        {
            use std::io::Write;
            let mut admin = std::net::TcpStream::connect(addr)
                .map_err(|e| NnError::Config(format!("bench --serve admin: {e}")))?;
            admin
                .write_all(b"{\"cmd\": \"shutdown\"}\n")
                .map_err(|e| NnError::Config(format!("bench --serve admin: {e}")))?;
            let mut buf = Vec::new();
            let _ = read_json_reply(&mut admin, &mut buf);
        }
        server
            .join()
            .map_err(|_| NnError::Config("bench --serve: server panicked".into()))?
            .map_err(|e| NnError::Config(format!("bench --serve server: {e}")))?;
        latencies.sort_by(f64::total_cmp);
        let rps = (conns * reqs) as f64 / wall;
        Ok((rps, pct_us(&latencies, 0.50), pct_us(&latencies, 0.99)))
    };

    println!(
        "serving bench: {conns} connections × {reqs} requests (window {window} pipelined)"
    );
    let (json_rps, json_p50, json_p99) = run_mode(false, &json_frames, 1, true)?;
    println!(
        "  json/blocking:      {json_rps:>10.0} req/s  p50 {json_p50:.1}µs  p99 {json_p99:.1}µs"
    );
    // The binary mode prefers the event loop; off Linux it degrades to the
    // blocking path so the bench still runs (recorded in the output).
    let event_capable = cfg!(target_os = "linux");
    let (bin_rps, bin_p50, bin_p99) = run_mode(event_capable, &bin_frames, window, false)?;
    let accept_path = if event_capable { "event-loop" } else { "blocking" };
    println!(
        "  binary/{accept_path}: {bin_rps:>10.0} req/s  p50 {bin_p50:.1}µs  p99 {bin_p99:.1}µs"
    );
    let speedup = bin_rps / json_rps;
    println!("  speedup binary+{accept_path} vs json+blocking: {speedup:.2}x");

    let mode_row = |mode: &str, path: &str, win: usize, rps: f64, p50: f64, p99: f64| {
        Json::obj([
            ("mode", Json::str(mode)),
            ("accept_path", Json::str(path)),
            ("window", Json::int(win as i64)),
            ("req_per_sec", Json::float(rps)),
            ("p50_us", Json::float(p50)),
            ("p99_us", Json::float(p99)),
            ("p50_per_inflight_us", Json::float(p50 / win as f64)),
            ("p99_per_inflight_us", Json::float(p99 / win as f64)),
        ])
    };
    Ok(Json::obj([
        ("connections", Json::int(conns as i64)),
        ("requests_per_conn", Json::int(reqs as i64)),
        ("modes", Json::Arr(vec![
            mode_row("json", "blocking", 1, json_rps, json_p50, json_p99),
            mode_row("binary", accept_path, window, bin_rps, bin_p50, bin_p99),
        ])),
        ("speedup_binary_vs_json", Json::float(speedup)),
    ]))
}

fn cmd_emit(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&["arch", "model", "artifacts", "format", "out", "jobs", "circuit"]))?;
    let model = load_model(args)?;
    let circuit = load_or_synthesize(args, &model)?;
    let name = model.name.replace('-', "_");
    let text = match args.get_str("format", "blif").as_str() {
        "blif" => nullanet_tiny::logic::blif::pipelined_to_blif(&circuit, &name),
        "verilog" => nullanet_tiny::logic::verilog::pipelined_to_verilog(&circuit, &name),
        f => return Err(NnError::Config(format!("unknown format '{f}'"))),
    };
    match args.get_opt("out") {
        Some(path) => {
            std::fs::write(path, text)
                .map_err(|e| NnError::Config(format!("write {path}: {e}")))?;
            println!("wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// Static checks over compiled-circuit bundles: structural lint (default),
/// a SAT-based combinational-equivalence proof between two bundles
/// (`--cec a.json b.json`), runtime lock-order analysis of the serving
/// stack (`--locks`), or the fault-injection inventory (`--faults`).
/// Exits nonzero on any failure, so CI can gate artifact pipelines on it.
fn cmd_check(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&["cec", "locks", "locks-fixture", "faults"]))?;
    if args.get_bool("locks") || args.get_bool("locks-fixture") {
        return cmd_check_locks(args.get_bool("locks-fixture"));
    }
    if args.get_bool("faults") {
        return cmd_check_faults();
    }
    if let Some(first) = args.get_opt("cec") {
        // `--cec a.json b.json` parses as option value "a.json" plus one
        // positional; a bare trailing `--cec` maps to "true" and both files
        // come from positionals.
        let mut files: Vec<String> = Vec::new();
        if first != "true" {
            files.push(first.to_string());
        }
        files.extend(args.positional.iter().cloned());
        if files.len() != 2 {
            return Err(NnError::Config(
                "check --cec needs exactly two circuit bundles".into(),
            ));
        }
        let (_, ca) = artifact::load_bundle(&files[0])?;
        let (_, cb) = artifact::load_bundle(&files[1])?;
        match check_netlists(&ca.netlist, &cb.netlist)? {
            CecResult::Equivalent => {
                println!(
                    "EQUIVALENT: {} ≡ {} (SAT proof, {} inputs, {} vs {} LUTs)",
                    files[0],
                    files[1],
                    ca.netlist.num_inputs,
                    ca.netlist.num_luts(),
                    cb.netlist.num_luts(),
                );
                Ok(())
            }
            CecResult::Inequivalent { assignment, output } => {
                let bits: String =
                    assignment.iter().map(|&b| if b { '1' } else { '0' }).collect();
                Err(NnError::Config(format!(
                    "NOT equivalent: output {output} differs under input \
                     assignment (bit 0 first) {bits}"
                )))
            }
        }
    } else {
        if args.positional.is_empty() {
            return Err(NnError::Config(
                "check needs at least one circuit bundle, or --cec a.json b.json"
                    .into(),
            ));
        }
        for path in &args.positional {
            // `load_bundle` already lints the circuit on parse; re-run the
            // compiled-stream lint on top so the instruction schedule the
            // serving engine would execute is covered too.
            let (model, circuit) = artifact::load_bundle(path)?;
            CompiledNetlist::compile(&circuit.netlist).lint()?;
            println!(
                "{path}: ok ({}, {} LUTs, {} stages)",
                model.summary(),
                circuit.netlist.num_luts(),
                circuit.num_stages,
            );
        }
        Ok(())
    }
}

/// `check --faults`: print the fault-injection point inventory and whether
/// the harness is compiled into this binary (`--cfg nnt_fault`). The chaos
/// CI job greps the output to assert it is driving a fault-armed build;
/// release binaries report the harness compiled out (every point a no-op).
fn cmd_check_faults() -> Result<(), NnError> {
    use nullanet_tiny::util::fault;
    let state = if fault::armed() { "compiled in" } else { "compiled out (no-op)" };
    println!("fault injection: {state} ({} points)", fault::POINTS.len());
    for p in fault::POINTS {
        println!("  {p}: calls={} injected={}", fault::calls(p), fault::injected(p));
    }
    Ok(())
}

/// `check --locks`: exercise the real serving stack with the lock-order
/// recorder on, then scan the acquisition graph for cycles. Every named
/// lock in the stack (registry map, router dispatcher handle, batcher
/// queue, thread-pool injector, sim scratch pool) is acquired on these
/// paths, so any opposite-order pair shows up as a cycle —
/// [`CheckError::LockOrder`], exit nonzero. `--locks-fixture` additionally
/// runs the intentional A→B/B→A fixture to prove the detector fires.
fn cmd_check_locks(with_fixture: bool) -> Result<(), NnError> {
    use nullanet_tiny::util::sync as nsync;

    fn lock_router(
        model: &Model,
        netlist: nullanet_tiny::logic::netlist::LutNetlist,
    ) -> Result<nullanet_tiny::coordinator::Router, NnError> {
        RouterBuilder::new(model.clone())
            .circuit(netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy::default())
            .workers(2)
            .build()
    }

    nsync::reset_lock_order();
    nsync::set_lock_tracking(true);
    // Drive traffic through registry → router → batcher → thread pool →
    // shard runner, then hot-swap, unload, and drain: the full set of lock
    // orderings the serving stack can produce.
    let model = random_model("lockcheck", 6, &[4, 3], 2, 1, 17);
    let flow = run_flow(&model, &FlowConfig { jobs: 2, ..Default::default() }, None)?;
    let registry = ModelRegistry::new(RegistryConfig::default());
    registry.install(
        "lockcheck",
        lock_router(&model, flow.circuit.netlist.clone())?,
        None,
    )?;
    let x: Vec<f64> = (0..6).map(|j| (j as f64 * 0.3).sin()).collect();
    for _ in 0..32 {
        let rx = registry.classify(None, &x)?;
        rx.recv_timeout(std::time::Duration::from_secs(10))
            .map_err(|_| NnError::Config("check --locks: inference timed out".into()))?;
    }
    registry.install(
        "lockcheck",
        lock_router(&model, flow.circuit.netlist.clone())?,
        None,
    )?;
    registry.unload("lockcheck")?;
    registry.shutdown_all();
    // The TCP front end owns one more named lock — the connection table
    // ("server.conns") that the shutdown wake protocol walks. Serve one
    // classify and a shutdown over loopback so its acquisition edges join
    // the graph alongside the registry/router/batcher locks.
    {
        use std::io::{BufRead, BufReader, Write};
        let srv_registry = Arc::new(ModelRegistry::new(RegistryConfig::default()));
        srv_registry.install("lockcheck", lock_router(&model, flow.circuit.netlist)?, None)?;
        let (tx, rx) = std::sync::mpsc::channel();
        let server = std::thread::spawn(move || {
            nullanet_tiny::coordinator::server::serve(srv_registry, "127.0.0.1:0", Some(tx))
        });
        let port = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .map_err(|_| NnError::Config("check --locks: server did not start".into()))?;
        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port))
            .map_err(|e| NnError::Config(format!("check --locks: connect: {e}")))?;
        let mut reader = BufReader::new(
            conn.try_clone()
                .map_err(|e| NnError::Config(format!("check --locks: clone: {e}")))?,
        );
        let vals: Vec<String> = x.iter().map(|v| format!("{v:.6}")).collect();
        let mut line = String::new();
        for req in [
            format!("{{\"features\": [{}]}}\n", vals.join(", ")),
            "{\"cmd\": \"shutdown\"}\n".to_string(),
        ] {
            conn.write_all(req.as_bytes())
                .map_err(|e| NnError::Config(format!("check --locks: send: {e}")))?;
            line.clear();
            reader
                .read_line(&mut line)
                .map_err(|e| NnError::Config(format!("check --locks: recv: {e}")))?;
        }
        server
            .join()
            .map_err(|_| NnError::Config("check --locks: server panicked".into()))?
            .map_err(|e| NnError::Config(format!("check --locks: serve: {e}")))?;
    }
    if with_fixture {
        nsync::run_deadlock_fixture();
    }
    let edges = nsync::lock_order_edges();
    nsync::set_lock_tracking(false);
    match nsync::find_lock_cycle() {
        Some(cycle) => Err(NnError::Check(CheckError::LockOrder {
            cycle: cycle.into_iter().map(str::to_string).collect(),
        })),
        None => {
            println!("lock order: clean ({} acquisition edges, no cycles)", edges.len());
            for (a, b) in edges {
                println!("  {a} -> {b}");
            }
            Ok(())
        }
    }
}

fn cmd_info(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&["arch", "model", "artifacts"]))?;
    let model = load_model(args)?;
    println!("{}", model.summary());
    for (l, layer) in model.layers.iter().enumerate() {
        let in_bits = model.in_quant_of_layer(l).bits;
        println!(
            "  layer {l}: {}→{}  fanin ≤{}  neuron fn {} in / {} out bits  \
             (enumeration 2^{})",
            layer.in_width,
            layer.out_width,
            layer.max_fanin(),
            layer.max_fanin() * in_bits,
            layer.act.bits,
            layer.max_fanin() * in_bits,
        );
    }
    Ok(())
}

/// Write a deterministic random model (CI smoke tests, local experiments
/// without the trained artifacts).
fn cmd_gen_model(args: &Args) -> Result<(), NnError> {
    conf(args.check_known(&["name", "features", "widths", "fanin", "act-bits", "seed", "out"]))?;
    let name = args.get_str("name", "tiny");
    let features = conf(args.get_usize("features", 6))?;
    let widths_s = args.get_str("widths", "5,4");
    let mut widths: Vec<usize> = Vec::new();
    for part in widths_s.split(',') {
        widths.push(part.trim().parse().map_err(|_| {
            NnError::Config(format!("--widths: expected comma-separated integers, got '{part}'"))
        })?);
    }
    let fanin = conf(args.get_usize("fanin", 2))?;
    let act_bits = conf(args.get_usize("act-bits", 1))?;
    if fanin * act_bits > 12 {
        return Err(NnError::Config(format!(
            "fanin ({fanin}) × act-bits ({act_bits}) > 12: per-neuron enumeration \
             would be infeasible"
        )));
    }
    let seed = conf(args.get_usize("seed", 7))? as u64;
    let model = random_model(&name, features, &widths, fanin, act_bits, seed);
    let out = args.get_str("out", &format!("{name}.model.json"));
    model.save(&out).map_err(NnError::Data)?;
    println!("wrote {out}: {}", model.summary());
    Ok(())
}
