//! VU9P-class timing model.
//!
//! The paper reports post-implementation fmax from Vivado on a Xilinx VU9P
//! (and notes some frequencies exceed what the device can realize — they are
//! synthesis-reported maxima). Without Vivado we model the clock period of a
//! pipeline stage with `ℓ` LUT levels as
//!
//! ```text
//! T(ℓ) = t_clk2q + ℓ·(t_lut + t_net) + t_setup
//! ```
//!
//! with UltraScale+ -3 speed-grade constants (CLB LUT delay ≈ 0.10–0.15 ns,
//! typical net ≈ 0.15–0.30 ns). The defaults below are calibrated so a
//! 1-level pipeline lands at ≈ 2.1 GHz — the band Table I's JSC-S (2,079
//! MHz) sits in — and deeper stages degrade the way the paper's M/L rows do.
//! All constants are plain fields: benches sweep them, EXPERIMENTS.md
//! records the values used.

/// Per-element delays in nanoseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimingModel {
    /// Register clock-to-Q.
    pub t_clk2q_ns: f64,
    /// One 6-LUT logic delay.
    pub t_lut_ns: f64,
    /// Average routing delay per LUT level.
    pub t_net_ns: f64,
    /// Register setup time.
    pub t_setup_ns: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        Self::vu9p()
    }
}

impl TimingModel {
    /// VU9P -3 speed grade calibration (DESIGN.md §9).
    pub fn vu9p() -> TimingModel {
        TimingModel {
            t_clk2q_ns: 0.10,
            t_lut_ns: 0.12,
            t_net_ns: 0.20,
            t_setup_ns: 0.06,
        }
    }

    /// Clock period for a stage with `levels` LUT levels.
    pub fn period_ns(&self, levels: u32) -> f64 {
        self.t_clk2q_ns + levels as f64 * (self.t_lut_ns + self.t_net_ns) + self.t_setup_ns
    }

    /// Maximum frequency in MHz for the given worst-stage depth.
    pub fn fmax_mhz(&self, worst_stage_levels: u32) -> f64 {
        1e3 / self.period_ns(worst_stage_levels.max(1))
    }

    /// End-to-end latency in nanoseconds for a pipeline of `stages` stages
    /// whose worst stage has `worst_stage_levels` levels: the pipeline runs
    /// at fmax, data needs `stages + 1` edges (input reg → … → output reg).
    pub fn latency_ns(&self, stages: u32, worst_stage_levels: u32) -> f64 {
        (stages as f64 + 1.0) * self.period_ns(worst_stage_levels.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmax_monotone_in_depth() {
        let t = TimingModel::vu9p();
        let f1 = t.fmax_mhz(1);
        let f2 = t.fmax_mhz(2);
        let f8 = t.fmax_mhz(8);
        assert!(f1 > f2 && f2 > f8);
    }

    #[test]
    fn one_level_lands_in_jsc_s_band() {
        // Table I: JSC-S reaches 2,079 MHz; a 1-level stage must land
        // within ±15% of that band.
        let f = TimingModel::vu9p().fmax_mhz(1);
        assert!((1700.0..2500.0).contains(&f), "fmax(1) = {f} MHz");
    }

    #[test]
    fn deeper_stages_land_in_m_l_band() {
        // JSC-M: 841 MHz ≈ 3 levels; JSC-L: 436 MHz ≈ 6–7 levels.
        let t = TimingModel::vu9p();
        let f3 = t.fmax_mhz(3);
        assert!((600.0..1100.0).contains(&f3), "fmax(3) = {f3} MHz");
        let f7 = t.fmax_mhz(7);
        assert!((300.0..600.0).contains(&f7), "fmax(7) = {f7} MHz");
    }

    #[test]
    fn latency_accounts_for_all_stages() {
        let t = TimingModel::vu9p();
        let l = t.latency_ns(3, 2);
        assert!((l - 4.0 * t.period_ns(2)).abs() < 1e-12);
    }

    #[test]
    fn zero_level_clamped() {
        let t = TimingModel::vu9p();
        assert_eq!(t.fmax_mhz(0), t.fmax_mhz(1));
    }
}
