//! Device area / utilization model.
//!
//! Capacity numbers for the Xilinx VU9P (the paper's target part) and
//! utilization computation for mapped circuits.

use crate::logic::netlist::CircuitStats;

/// FPGA device capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Device {
    /// Human name.
    pub name: &'static str,
    /// 6-input LUTs.
    pub luts: usize,
    /// Flip-flops.
    pub ffs: usize,
}

impl Device {
    /// Xilinx Virtex UltraScale+ VU9P.
    pub fn vu9p() -> Device {
        Device { name: "xcvu9p", luts: 1_182_240, ffs: 2_364_480 }
    }

    /// Utilization fractions (LUT, FF) of a circuit on this device.
    pub fn utilization(&self, stats: &CircuitStats) -> (f64, f64) {
        (
            stats.luts as f64 / self.luts as f64,
            stats.ffs as f64 / self.ffs as f64,
        )
    }

    /// Does the circuit fit?
    pub fn fits(&self, stats: &CircuitStats) -> bool {
        stats.luts <= self.luts && stats.ffs <= self.ffs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(luts: usize, ffs: usize) -> CircuitStats {
        CircuitStats { luts, ffs, max_stage_depth: 1, latency_cycles: 1 }
    }

    #[test]
    fn vu9p_capacity() {
        let d = Device::vu9p();
        assert!(d.luts > 1_000_000);
        assert_eq!(d.ffs, 2 * d.luts);
    }

    #[test]
    fn utilization_fractions() {
        let d = Device::vu9p();
        let (lu, fu) = d.utilization(&stats(d.luts / 2, d.ffs / 4));
        assert!((lu - 0.5).abs() < 1e-9);
        assert!((fu - 0.25).abs() < 1e-9);
    }

    #[test]
    fn fits_boundary() {
        let d = Device::vu9p();
        assert!(d.fits(&stats(d.luts, d.ffs)));
        assert!(!d.fits(&stats(d.luts + 1, 0)));
        assert!(!d.fits(&stats(0, d.ffs + 1)));
    }
}
