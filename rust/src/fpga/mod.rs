//! FPGA cost model (the Vivado place-and-route substitute, DESIGN.md §4/§9).
//!
//! * [`timing`] — VU9P-calibrated clock model: fmax from pipeline stage depth
//! * [`area`] — LUT/FF utilization against device capacity
//! * [`report`] — Table-I row assembly and formatting

pub mod area;
pub mod report;
pub mod timing;
