//! Table-I style result rows: accuracy + hardware metrics + comparison
//! factors against a baseline (the paper reports `(Inc.)`/`(Dec.)` factors
//! relative to LogicNets).

use crate::fpga::timing::TimingModel;
use crate::logic::netlist::CircuitStats;
use crate::logic::opt::OptStats;

/// One architecture's results (a Table I row).
#[derive(Clone, Debug)]
pub struct ResultRow {
    /// Architecture name ("JSC-S", …).
    pub arch: String,
    /// Classification accuracy in [0,1] (logic netlist on the test set).
    pub accuracy: f64,
    /// Hardware statistics of the final retimed circuit.
    pub stats: CircuitStats,
    /// Modeled fmax (MHz).
    pub fmax_mhz: f64,
    /// Modeled end-to-end latency (ns).
    pub latency_ns: f64,
}

impl ResultRow {
    /// Assemble from circuit stats + timing model.
    pub fn from_stats(arch: &str, accuracy: f64, stats: CircuitStats, tm: &TimingModel) -> Self {
        ResultRow {
            arch: arch.to_string(),
            accuracy,
            stats,
            fmax_mhz: tm.fmax_mhz(stats.max_stage_depth),
            latency_ns: tm.latency_ns(stats.latency_cycles, stats.max_stage_depth),
        }
    }
}

/// Comparison of our row vs a baseline row (factors as the paper prints
/// them: LUT/FF decrease factors, fmax increase factor, accuracy delta).
#[derive(Clone, Debug)]
pub struct Comparison {
    pub ours: ResultRow,
    pub baseline: ResultRow,
}

impl Comparison {
    /// Accuracy increase in percentage points.
    pub fn accuracy_delta_pp(&self) -> f64 {
        (self.ours.accuracy - self.baseline.accuracy) * 100.0
    }

    /// Baseline LUTs / our LUTs (the "(Dec.)" factor — higher is better).
    pub fn lut_decrease(&self) -> f64 {
        self.baseline.stats.luts as f64 / self.ours.stats.luts.max(1) as f64
    }

    /// FF decrease factor.
    pub fn ff_decrease(&self) -> f64 {
        self.baseline.stats.ffs as f64 / self.ours.stats.ffs.max(1) as f64
    }

    /// fmax increase factor.
    pub fn fmax_increase(&self) -> f64 {
        self.ours.fmax_mhz / self.baseline.fmax_mhz
    }

    /// Latency decrease factor (headline metric).
    pub fn latency_decrease(&self) -> f64 {
        self.baseline.latency_ns / self.ours.latency_ns
    }
}

/// One-line compile-time netlist-optimizer summary. Quoted by the flow
/// report (`nullanet flow`), the benchmark (`nullanet bench`), and — per
/// model, as raw counts — the serving `depth` admin command.
pub fn format_opt_stats(s: &OptStats) -> String {
    format!(
        "optimizer: {} → {} LUTs ({} const-folded, {} deduped, {} dead removed)",
        s.luts_before, s.luts_after, s.const_folded, s.deduped, s.dead_removed
    )
}

/// Render rows in the paper's Table-I layout.
pub fn format_table(rows: &[Comparison]) -> String {
    let mut s = String::new();
    s.push_str(
        "| Arch  | Accuracy (Inc.)   | LUTs (Dec.)      | FFs (Dec.)     | fmax (Inc.)        | Latency (Dec.)   |\n",
    );
    s.push_str(
        "|-------|-------------------|------------------|----------------|--------------------|------------------|\n",
    );
    for c in rows {
        s.push_str(&format!(
            "| {:<5} | {:>6.2}% ({:+.2}pp)  | {:>6} ({:.2}x)   | {:>5} ({:.2}x)  | {:>7.0} MHz ({:.2}x) | {:>7.2} ns ({:.2}x) |\n",
            c.ours.arch,
            c.ours.accuracy * 100.0,
            c.accuracy_delta_pp(),
            c.ours.stats.luts,
            c.lut_decrease(),
            c.ours.stats.ffs,
            c.ff_decrease(),
            c.ours.fmax_mhz,
            c.fmax_increase(),
            c.ours.latency_ns,
            c.latency_decrease(),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(arch: &str, acc: f64, luts: usize, ffs: usize, depth: u32, cycles: u32) -> ResultRow {
        let stats = CircuitStats { luts, ffs, max_stage_depth: depth, latency_cycles: cycles };
        ResultRow::from_stats(arch, acc, stats, &TimingModel::vu9p())
    }

    #[test]
    fn factors() {
        let c = Comparison {
            ours: row("JSC-S", 0.6965, 39, 75, 1, 4),
            baseline: row("JSC-S", 0.678, 214, 247, 3, 4),
        };
        assert!((c.accuracy_delta_pp() - 1.85).abs() < 0.01);
        assert!((c.lut_decrease() - 214.0 / 39.0).abs() < 1e-9);
        assert!(c.fmax_increase() > 1.0);
        assert!(c.latency_decrease() > 1.0);
    }

    #[test]
    fn opt_stats_formatting() {
        let s = OptStats {
            luts_before: 120,
            luts_after: 95,
            const_folded: 10,
            deduped: 9,
            dead_removed: 6,
        };
        let line = format_opt_stats(&s);
        assert!(line.contains("120 → 95"), "{line}");
        assert!(line.contains("10 const-folded"), "{line}");
        assert!(line.contains("9 deduped"), "{line}");
        assert!(line.contains("6 dead removed"), "{line}");
    }

    #[test]
    fn table_formatting() {
        let c = Comparison {
            ours: row("JSC-M", 0.7222, 1553, 151, 3, 5),
            baseline: row("JSC-M", 0.7049, 14428, 440, 4, 5),
        };
        let t = format_table(&[c]);
        assert!(t.contains("JSC-M"));
        assert!(t.contains("1553"));
        assert!(t.contains("MHz"));
        assert!(t.lines().count() >= 3);
    }
}
