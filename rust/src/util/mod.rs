//! Dependency-free utility substrates.
//!
//! The offline build environment provides no third-party crates; everything
//! a production coordinator normally pulls from crates.io is implemented
//! here (see `rust/DESIGN.md` §3, S1–S7):
//!
//! * [`json`] — RFC 8259 parser/writer (replaces serde_json)
//! * [`cli`] — argument parsing (replaces clap)
//! * [`threadpool`] — fixed pool + `par_map` (replaces rayon); also shards
//!   packed inference batches across engine workers
//! * [`prng`] — SplitMix64/xoshiro256** (replaces rand)
//! * [`bitvec`] — packed bit vectors for truth tables & simulation, plus
//!   [`bitvec::PackedBatch`], the serving path's batch representation
//! * [`proptest`] — property testing with shrinking (replaces proptest)
//! * [`bench`] — benchmark statistics harness (replaces criterion)
//! * [`timer`] — stage profiling for the flow report and §Perf
//! * [`sat`] — CDCL SAT solver (replaces a solver crate) backing the
//!   [`crate::logic::cec`] equivalence proofs
//! * [`mc`] — deterministic concurrency model checker (replaces loom)
//! * [`sync`] — crate-wide sync shim: std-backed normally, model-checked
//!   under `--cfg nnt_model_check`; poison policy + lock-order analysis
//! * [`evloop`] — epoll event loop + eventfd waker (replaces mio) backing
//!   the nonblocking serving front end
//! * [`fault`] — named fault-injection points (no-ops unless
//!   `--cfg nnt_fault`) driving the chaos suite

pub mod bench;
pub mod bitvec;
pub mod cli;
pub mod evloop;
pub mod fault;
pub mod json;
pub mod mc;
pub mod prng;
pub mod proptest;
pub mod sat;
pub mod sync;
pub mod threadpool;
pub mod timer;
