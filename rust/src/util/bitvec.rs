//! Packed bit vectors.
//!
//! Truth tables, simulation values, and don't-care sets are all dense bit
//! sets; this module provides a compact `u64`-word representation with the
//! bulk Boolean operations the logic-synthesis core needs. Word-level ops are
//! the backbone of the 64-way bit-parallel netlist simulator
//! ([`crate::logic::sim`]), so the hot methods are `#[inline]`.

/// A fixed-length vector of bits packed into `u64` words (LSB-first within a
/// word; bit `i` lives in word `i / 64` at position `i % 64`).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitVec {
    len: usize,
    words: Vec<u64>,
}

impl std::fmt::Debug for BitVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitVec[{}; ", self.len)?;
        for i in 0..self.len.min(64) {
            write!(f, "{}", if self.get(i) { '1' } else { '0' })?;
        }
        if self.len > 64 {
            write!(f, "…")?;
        }
        write!(f, "]")
    }
}

impl BitVec {
    /// All-zero bit vector of length `len`.
    pub fn zeros(len: usize) -> Self {
        Self { len, words: vec![0; len.div_ceil(64)] }
    }

    /// All-one bit vector of length `len` (trailing bits in the last word are
    /// kept zero so equality and popcount stay canonical).
    pub fn ones(len: usize) -> Self {
        let mut v = Self { len, words: vec![!0u64; len.div_ceil(64)] };
        v.mask_tail();
        v
    }

    /// Build from pre-packed LSB-first words (e.g. straight off a binary
    /// wire frame). `words.len()` must be exactly `len.div_ceil(64)`; any
    /// stray bits past `len` in the last word are masked to keep equality
    /// and popcount canonical.
    pub fn from_words(len: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), len.div_ceil(64), "from_words: wrong word count for {len} bits");
        let mut v = Self { len, words };
        v.mask_tail();
        v
    }

    /// Build from an iterator of bools.
    pub fn from_bools(bits: impl IntoIterator<Item = bool>) -> Self {
        let bits: Vec<bool> = bits.into_iter().collect();
        let mut v = Self::zeros(bits.len());
        for (i, b) in bits.iter().enumerate() {
            if *b {
                v.set(i, true);
            }
        }
        v
    }

    /// Number of bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if the vector has zero length.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Write bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i >> 6];
        let m = 1u64 << (i & 63);
        if v {
            *w |= m;
        } else {
            *w &= !m;
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True if no bit is set.
    pub fn is_zero(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// True if every bit is set.
    pub fn is_all_ones(&self) -> bool {
        self.count_ones() == self.len
    }

    /// Raw word slice (read-only).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Raw word slice (mutable). Callers must preserve the tail invariant via
    /// [`BitVec::mask_tail`] if they may set bits past `len`.
    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Zero any bits at positions ≥ `len` in the final word.
    #[inline]
    pub fn mask_tail(&mut self) {
        let rem = self.len & 63;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// `self |= other` (lengths must match).
    pub fn or_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// `self &= other` (lengths must match).
    pub fn and_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// `self ^= other` (lengths must match).
    pub fn xor_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a ^= b;
        }
    }

    /// Bitwise complement (respects the tail invariant).
    pub fn not(&self) -> BitVec {
        let mut out = self.clone();
        for w in &mut out.words {
            *w = !*w;
        }
        out.mask_tail();
        out
    }

    /// True if `self ∧ other = self` (subset as bit sets).
    pub fn is_subset_of(&self, other: &BitVec) -> bool {
        assert_eq!(self.len, other.len);
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// True if the two vectors share any set bit.
    pub fn intersects(&self, other: &BitVec) -> bool {
        assert_eq!(self.len, other.len);
        self.words.iter().zip(&other.words).any(|(a, b)| a & b != 0)
    }

    /// Indices of set bits, ascending.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            let mut out = Vec::with_capacity(w.count_ones() as usize);
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                out.push((wi << 6) + b);
                w &= w - 1;
            }
            out
        })
    }

    /// Parse a [`BitVec::to_hex`] string back into a bit vector of length
    /// `len` (MSB-first nibbles, exactly `len.div_ceil(4)` of them).
    /// Returns `None` on a wrong-length string, a non-hex digit, or a set
    /// bit at or beyond `len`.
    pub fn from_hex(len: usize, hex: &str) -> Option<BitVec> {
        let nibbles = len.div_ceil(4);
        if hex.len() != nibbles {
            return None;
        }
        let mut v = BitVec::zeros(len);
        // `to_hex` emits the highest nibble first; reverse to nibble order.
        for (n, c) in hex.chars().rev().enumerate() {
            let d = c.to_digit(16)?;
            for b in 0..4 {
                if (d >> b) & 1 == 1 {
                    let i = n * 4 + b;
                    if i >= len {
                        return None; // set bit past the declared length
                    }
                    v.set(i, true);
                }
            }
        }
        Some(v)
    }

    /// Compact hex string (for hashing/debug of truth tables).
    pub fn to_hex(&self) -> String {
        let nibbles = self.len.div_ceil(4);
        let mut s = String::with_capacity(nibbles);
        for n in (0..nibbles).rev() {
            let mut v = 0u8;
            for b in 0..4 {
                let i = n * 4 + b;
                if i < self.len && self.get(i) {
                    v |= 1 << b;
                }
            }
            s.push(char::from_digit(v as u32, 16).unwrap());
        }
        s
    }
}

/// A batch of samples packed for the word-parallel simulator: one `u64`
/// word per signal per 64-sample *lane group*, stored lane-group-major so
/// the words of group `g` form exactly the `inputs` slice
/// [`crate::logic::sim::CompiledNetlist::run_words`] consumes — handing a
/// group to the engine is a slice borrow, not a transpose, and a contiguous
/// range of groups is a shard for a worker thread.
///
/// Sample `s` lives in group `s / 64` at lane `s % 64`; bit `(s, signal)`
/// is `words[(s / 64) * signals + signal] >> (s % 64) & 1`. Lanes at or
/// beyond `num_samples` in the last group are kept zero (canonical for
/// equality).
#[derive(Clone, PartialEq, Eq)]
pub struct PackedBatch {
    signals: usize,
    samples: usize,
    /// `words[g * signals + i]` = 64 lanes of signal `i` in group `g`.
    words: Vec<u64>,
}

impl std::fmt::Debug for PackedBatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PackedBatch[{} samples × {} signals, {} groups]",
            self.samples,
            self.signals,
            self.num_groups()
        )
    }
}

impl PackedBatch {
    /// Empty batch over `signals` input signals, with room reserved for
    /// `max_samples` samples.
    pub fn with_capacity(signals: usize, max_samples: usize) -> Self {
        PackedBatch {
            signals,
            samples: 0,
            words: Vec::with_capacity(max_samples.div_ceil(64) * signals),
        }
    }

    /// Rebuild from raw group-major output words (as produced by the
    /// simulator). Tail lanes of the last group are masked to keep equality
    /// canonical.
    pub fn from_group_major_words(signals: usize, samples: usize, mut words: Vec<u64>) -> Self {
        let groups = samples.div_ceil(64);
        assert_eq!(words.len(), groups * signals, "word count must be groups × signals");
        mask_group_tail(&mut words, signals, samples);
        PackedBatch { signals, samples, words }
    }

    /// Signals per sample.
    #[inline]
    pub fn num_signals(&self) -> usize {
        self.signals
    }

    /// Samples currently packed.
    #[inline]
    pub fn num_samples(&self) -> usize {
        self.samples
    }

    /// True when no samples are packed.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.samples == 0
    }

    /// Number of 64-sample lane groups (the shardable unit).
    #[inline]
    pub fn num_groups(&self) -> usize {
        self.samples.div_ceil(64)
    }

    /// The `signals` input words of lane group `g` — exactly the slice the
    /// simulator's word pass consumes.
    #[inline]
    pub fn group_words(&self, g: usize) -> &[u64] {
        &self.words[g * self.signals..(g + 1) * self.signals]
    }

    /// Raw word storage (group-major).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Read bit (`sample`, `signal`).
    #[inline]
    pub fn get(&self, sample: usize, signal: usize) -> bool {
        assert!(sample < self.samples && signal < self.signals);
        (self.words[(sample >> 6) * self.signals + signal] >> (sample & 63)) & 1 == 1
    }

    /// Append one sample from a packed [`BitVec`] (`bits.len()` must equal
    /// the signal count). Allocation-free apart from the amortized per-group
    /// extension of the word storage.
    pub fn push_sample(&mut self, bits: &BitVec) {
        assert_eq!(bits.len(), self.signals, "sample width must match signal count");
        self.push_sample_words(bits.words());
    }

    /// Append one sample whose bits are already packed into a single `u64`
    /// (LSB-first; the batch must pack ≤ 64 signals — the common case for
    /// circuit inputs). Word-level: only the *set* bits are scattered into
    /// the transposed storage, one `trailing_zeros` step each, instead of
    /// one branch per signal. This is the batcher's flush fast path.
    pub fn push_sample_word(&mut self, bits: u64) {
        assert!(
            self.signals <= 64,
            "push_sample_word: batch packs {} signals (> 64); use push_sample_words",
            self.signals
        );
        if self.signals < 64 {
            debug_assert_eq!(bits >> self.signals, 0, "set bit past the signal count");
        }
        let (g, lane) = (self.samples >> 6, self.samples & 63);
        if lane == 0 {
            self.words.resize((g + 1) * self.signals, 0);
        }
        self.samples += 1;
        let base = g * self.signals;
        let mut w = bits;
        while w != 0 {
            let b = w.trailing_zeros() as usize;
            self.words[base + b] |= 1 << lane;
            w &= w - 1;
        }
    }

    /// Multi-word generalization of [`PackedBatch::push_sample_word`]:
    /// append one sample given as `signals.div_ceil(64)` LSB-first words
    /// (bits at or beyond the signal count must be zero — the [`BitVec`]
    /// tail invariant).
    pub fn push_sample_words(&mut self, words: &[u64]) {
        assert_eq!(
            words.len(),
            self.signals.div_ceil(64),
            "push_sample_words: {} words for {} signals",
            words.len(),
            self.signals
        );
        if self.signals & 63 != 0 {
            debug_assert_eq!(
                words[words.len() - 1] >> (self.signals & 63),
                0,
                "set bit past the signal count"
            );
        }
        let (g, lane) = (self.samples >> 6, self.samples & 63);
        if lane == 0 {
            self.words.resize((g + 1) * self.signals, 0);
        }
        self.samples += 1;
        let base = g * self.signals;
        for (wi, &word) in words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                self.words[base + (wi << 6) + b] |= 1 << lane;
                w &= w - 1;
            }
        }
    }

    /// Append one sample given as a bool slice (tests/offline tools).
    pub fn push_sample_bools(&mut self, bits: &[bool]) {
        assert_eq!(bits.len(), self.signals, "sample width must match signal count");
        let (g, lane) = (self.samples >> 6, self.samples & 63);
        if lane == 0 {
            self.words.resize((g + 1) * self.signals, 0);
        }
        self.samples += 1;
        let base = g * self.signals;
        for (i, &v) in bits.iter().enumerate() {
            if v {
                self.words[base + i] |= 1 << lane;
            }
        }
    }
}

/// Zero every lane at or beyond `samples` in the last group of a
/// group-major word buffer (`signals` words per 64-sample group) — the one
/// implementation of the tail-lane invariant, shared by
/// [`PackedBatch::from_group_major_words`] and the simulator's reusable
/// output buffers ([`crate::logic::sim`]).
pub fn mask_group_tail(words: &mut [u64], signals: usize, samples: usize) {
    let rem = samples & 63;
    if rem != 0 && signals > 0 {
        let mask = (1u64 << rem) - 1;
        let groups = samples.div_ceil(64);
        for w in &mut words[(groups - 1) * signals..] {
            *w &= mask;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        for i in (0..130).step_by(3) {
            v.set(i, true);
        }
        for i in 0..130 {
            assert_eq!(v.get(i), i % 3 == 0, "bit {i}");
        }
        assert_eq!(v.count_ones(), (0..130).step_by(3).count());
    }

    #[test]
    fn ones_respects_tail() {
        let v = BitVec::ones(70);
        assert_eq!(v.count_ones(), 70);
        assert!(v.is_all_ones());
        assert_eq!(v.words()[1] >> 6, 0, "tail bits must stay zero");
    }

    #[test]
    fn not_is_involution_and_respects_len() {
        let mut v = BitVec::zeros(100);
        v.set(3, true);
        v.set(99, true);
        let n = v.not();
        assert_eq!(n.count_ones(), 98);
        assert_eq!(n.not(), v);
    }

    #[test]
    fn boolean_ops() {
        let a = BitVec::from_bools([true, true, false, false]);
        let b = BitVec::from_bools([true, false, true, false]);
        let mut o = a.clone();
        o.or_assign(&b);
        assert_eq!(o, BitVec::from_bools([true, true, true, false]));
        let mut x = a.clone();
        x.and_assign(&b);
        assert_eq!(x, BitVec::from_bools([true, false, false, false]));
        let mut e = a.clone();
        e.xor_assign(&b);
        assert_eq!(e, BitVec::from_bools([false, true, true, false]));
    }

    #[test]
    fn subset_and_intersect() {
        let a = BitVec::from_bools([true, false, true, false]);
        let b = BitVec::from_bools([true, true, true, false]);
        assert!(a.is_subset_of(&b));
        assert!(!b.is_subset_of(&a));
        assert!(a.intersects(&b));
        let z = BitVec::zeros(4);
        assert!(z.is_subset_of(&a));
        assert!(!z.intersects(&a));
    }

    #[test]
    fn iter_ones_ascending() {
        let mut v = BitVec::zeros(200);
        let idx = [0usize, 63, 64, 65, 127, 128, 199];
        for &i in &idx {
            v.set(i, true);
        }
        let got: Vec<usize> = v.iter_ones().collect();
        assert_eq!(got, idx);
    }

    #[test]
    fn hex_digest_distinguishes() {
        let mut a = BitVec::zeros(16);
        a.set(0, true);
        let mut b = BitVec::zeros(16);
        b.set(1, true);
        assert_ne!(a.to_hex(), b.to_hex());
        assert_eq!(a.to_hex().len(), 4);
    }

    #[test]
    fn hex_roundtrip() {
        for len in [0usize, 1, 3, 4, 5, 16, 64, 70, 130] {
            let mut v = BitVec::zeros(len);
            for i in (0..len).step_by(3) {
                v.set(i, true);
            }
            let hex = v.to_hex();
            let back = BitVec::from_hex(len, &hex).expect("round-trip");
            assert_eq!(back, v, "len={len} hex={hex}");
        }
    }

    #[test]
    fn from_hex_rejects_garbage() {
        assert!(BitVec::from_hex(8, "g0").is_none(), "non-hex digit");
        assert!(BitVec::from_hex(8, "000").is_none(), "wrong length");
        // 2-bit vector is one nibble; a set bit at position 2 is out of range.
        assert!(BitVec::from_hex(2, "4").is_none(), "bit past len");
        assert!(BitVec::from_hex(2, "3").is_some());
    }

    #[test]
    fn from_bools_empty() {
        let v = BitVec::from_bools([]);
        assert!(v.is_empty());
        assert!(v.is_zero());
        assert_eq!(v.count_ones(), 0);
    }

    #[test]
    fn packed_batch_push_and_get() {
        // 5 signals, 130 samples (2 full groups + partial tail).
        let mut p = PackedBatch::with_capacity(5, 130);
        assert!(p.is_empty());
        for s in 0..130usize {
            let bits: Vec<bool> = (0..5).map(|i| (s * 7 + i) % 3 == 0).collect();
            if s % 2 == 0 {
                p.push_sample_bools(&bits);
            } else {
                p.push_sample(&BitVec::from_bools(bits.iter().copied()));
            }
        }
        assert_eq!(p.num_samples(), 130);
        assert_eq!(p.num_groups(), 3);
        assert_eq!(p.words().len(), 3 * 5);
        for s in 0..130usize {
            for i in 0..5usize {
                assert_eq!(p.get(s, i), (s * 7 + i) % 3 == 0, "sample {s} signal {i}");
            }
        }
    }

    #[test]
    fn push_sample_word_matches_bool_push() {
        let mut a = PackedBatch::with_capacity(7, 130);
        let mut b = PackedBatch::with_capacity(7, 130);
        for s in 0..130usize {
            let bits: Vec<bool> = (0..7).map(|i| (s * 5 + i) % 3 == 0).collect();
            let word: u64 = bits
                .iter()
                .enumerate()
                .map(|(i, &v)| if v { 1u64 << i } else { 0 })
                .sum();
            a.push_sample_bools(&bits);
            b.push_sample_word(word);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn push_sample_words_handles_wide_samples() {
        // 70 signals span two words per sample.
        let mut a = PackedBatch::with_capacity(70, 80);
        let mut b = PackedBatch::with_capacity(70, 80);
        for s in 0..80usize {
            let bits: Vec<bool> = (0..70).map(|i| (s + i) % 4 == 0).collect();
            let v = BitVec::from_bools(bits.iter().copied());
            a.push_sample_bools(&bits);
            b.push_sample_words(v.words());
        }
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "push_sample_word")]
    fn push_sample_word_rejects_wide_batches() {
        let mut p = PackedBatch::with_capacity(65, 1);
        p.push_sample_word(0);
    }

    #[test]
    #[should_panic(expected = "push_sample_words")]
    fn push_sample_words_rejects_wrong_word_count() {
        let mut p = PackedBatch::with_capacity(70, 1);
        p.push_sample_words(&[0u64]);
    }

    #[test]
    fn packed_batch_group_words_are_lane_slices() {
        let mut p = PackedBatch::with_capacity(2, 70);
        for s in 0..70usize {
            p.push_sample_bools(&[s % 2 == 0, s >= 64]);
        }
        // group 0, signal 0: even lanes set
        assert_eq!(p.group_words(0)[0], 0x5555_5555_5555_5555);
        // group 0, signal 1: none set
        assert_eq!(p.group_words(0)[1], 0);
        // group 1, signal 1: lanes 0..6 set (samples 64..70)
        assert_eq!(p.group_words(1)[1], 0b11_1111);
    }

    #[test]
    fn packed_batch_from_words_masks_tail() {
        // 1 signal, 66 samples, but hand it words with garbage tail lanes.
        let words = vec![!0u64, !0u64];
        let p = PackedBatch::from_group_major_words(1, 66, words);
        assert_eq!(p.group_words(1)[0], 0b11, "lanes ≥ 66 must be masked");
        let mut q = PackedBatch::with_capacity(1, 66);
        for _ in 0..66 {
            q.push_sample_bools(&[true]);
        }
        assert_eq!(p, q, "masking keeps equality canonical");
    }
}
