//! A small fixed-size thread pool with a `scope`-style parallel map.
//!
//! rayon/tokio are unavailable offline; the flow and serving engines only
//! need two primitives: fire-and-forget task execution and `par_map` over a
//! slice of independent work items (one logic-synthesis job per neuron at
//! build time; one lane-group shard of a [`PackedBatch`] per pop on the
//! inference path — see [`CompiledNetlist::run_packed_sharded`]). Work is
//! distributed through a shared injector queue guarded by a mutex+condvar —
//! at those job granularities (an ESPRESSO run, or ≥ 64 samples × many LUTs
//! per pop) queue contention is unmeasurable, which keeps the
//! implementation auditable.
//!
//! All synchronization goes through the [`crate::util::sync`] shim, so the
//! pool's shutdown protocol is model-checked under `--cfg nnt_model_check`
//! (see `tests/model_check.rs`). The shutdown flag lives *inside* the queue
//! mutex: an earlier revision kept it in a separate atomic, which had a
//! lost-wakeup window (worker checks the flag, drop stores it and notifies,
//! worker then parks forever) — exactly the class of bug the model checker
//! exists to catch.
//!
//! [`PackedBatch`]: crate::util::bitvec::PackedBatch
//! [`CompiledNetlist::run_packed_sharded`]: crate::logic::sim::CompiledNetlist::run_packed_sharded

use std::collections::VecDeque;
use std::sync::Arc;

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::{thread, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<PoolState>,
    available: Condvar,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::named(
                "threadpool.queue",
                PoolState {
                    jobs: VecDeque::new(),
                    shutdown: false,
                },
            ),
            available: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("nnt-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers, size }
    }

    /// Pool sized to the machine (`available_parallelism`, capped at 16).
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n.min(16))
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let mut q = self.shared.queue.lock();
        q.jobs.push_back(Box::new(job));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Apply `f` to every item of `items` in parallel and return results in
    /// input order. `f` runs on pool workers; the calling thread also helps
    /// drain the queue, so `par_map` can be called from a single-threaded
    /// program without deadlock.
    pub fn par_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let remaining = Arc::new(AtomicUsize::new(n));

        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let remaining = Arc::clone(&remaining);
            self.execute(move || {
                let r = f(item);
                results.lock()[i] = Some(r);
                remaining.fetch_sub(1, Ordering::Release);
            });
        }

        // Help drain the queue while waiting; this both avoids idle spinning
        // on the caller and makes a 1-worker pool behave like 2-way.
        while remaining.load(Ordering::Acquire) != 0 {
            let job = { self.shared.queue.lock().jobs.pop_front() };
            match job {
                Some(job) => job(),
                None => thread::yield_now(),
            }
        }

        Arc::try_unwrap(results)
            .ok()
            .expect("no outstanding refs")
            .into_inner()
            .into_iter()
            .map(|r| r.expect("all jobs completed"))
            .collect()
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break Some(job);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q);
            }
        };
        match job {
            Some(job) => job(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // The flag flip and the notify are both under/after the queue lock:
        // no worker can re-check the flag and park between them.
        self.shared.queue.lock().shutdown = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            });
        }
        drop(pool); // join workers
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.par_map((0..200).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..200).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.par_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn par_map_single_worker_no_deadlock() {
        let pool = ThreadPool::new(1);
        let out = pool.par_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn par_map_uneven_durations() {
        let pool = ThreadPool::new(4);
        let out = pool.par_map((0..20).collect::<Vec<u64>>(), |x| {
            std::thread::sleep(std::time::Duration::from_millis(x % 3));
            x * 2
        });
        assert_eq!(out, (0..20).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn reusable_across_batches() {
        let pool = ThreadPool::new(2);
        for round in 0..5 {
            let out = pool.par_map(vec![round; 10], |x| x);
            assert_eq!(out, vec![round; 10]);
        }
    }
}
