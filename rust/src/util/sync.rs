//! Crate-wide synchronization shim.
//!
//! Every module in the serving stack (`coordinator::*`, `util::threadpool`,
//! the `ShardRunner` path in `logic::sim`) takes its sync primitives from
//! here instead of `std::sync` (CI enforces this with a source lint). The
//! shim buys three things:
//!
//! 1. **Model checking.** Under `--cfg nnt_model_check`, primitives
//!    constructed inside an active `util::mc` model run route through the
//!    deterministic cooperative scheduler, so thread interleavings of the
//!    real production code can be explored exhaustively. In normal builds
//!    (and outside model runs even in model-check builds) everything is
//!    std-backed; the `mpsc`/`thread`/`atomic` modules are plain re-exports
//!    of std in normal builds.
//!
//! 2. **One poison policy.** `lock()`/`read()`/`write()` recover from
//!    poisoning (log + heal + return the guard) so a panicked serving thread
//!    cannot wedge every subsequent request; `lock_checked()` /
//!    `read_checked()` / `write_checked()` return a typed [`SyncError`]
//!    (convertible to `NnError`) for correctness-critical registry/router
//!    paths that must not silently observe torn state.
//!
//! 3. **Lock-order analysis.** Locks constructed with `named()` record
//!    runtime acquisition-order edges into a global graph (on by default in
//!    debug builds, opt-in via [`set_lock_tracking`] in release). Cycle
//!    detection over that graph powers `nullanet check --locks`.

#[cfg(nnt_model_check)]
use crate::util::mc;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool as StdAtomicBool, AtomicU64 as StdAtomicU64, Ordering};
use std::sync::{Mutex as StdMutex, OnceLock};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Poison policy plumbing
// ---------------------------------------------------------------------------

/// Typed error for the checked lock accessors: the lock was poisoned by a
/// panicking thread. The lock is healed (`clear_poison`) as a side effect,
/// so the *next* caller proceeds; the current caller gets a clean error
/// instead of a panic or silently-torn state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SyncError {
    /// Static name of the lock (or `"<unnamed>"`).
    pub lock: &'static str,
}

impl std::fmt::Display for SyncError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lock '{}' was poisoned by a panicked thread", self.lock)
    }
}

impl std::error::Error for SyncError {}

static POISON_RECOVERIES: StdAtomicU64 = StdAtomicU64::new(0);

/// How many poisoned-lock recoveries the recovering accessors performed.
pub fn poison_recoveries() -> u64 {
    POISON_RECOVERIES.load(Ordering::Relaxed)
}

fn note_poison(name: Option<&'static str>) {
    POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed);
    eprintln!(
        "[sync] recovered a poisoned lock ({}); state may reflect a partial update",
        name.unwrap_or("<unnamed>")
    );
}

// ---------------------------------------------------------------------------
// Lock-order analysis
// ---------------------------------------------------------------------------

static TRACK_LOCK_ORDER: StdAtomicBool = StdAtomicBool::new(cfg!(debug_assertions));

/// Enable/disable lock-order edge recording (debug builds default to on).
pub fn set_lock_tracking(on: bool) {
    TRACK_LOCK_ORDER.store(on, Ordering::Relaxed);
}

fn tracking() -> bool {
    TRACK_LOCK_ORDER.load(Ordering::Relaxed)
}

fn edge_graph() -> &'static StdMutex<BTreeSet<(&'static str, &'static str)>> {
    static EDGES: OnceLock<StdMutex<BTreeSet<(&'static str, &'static str)>>> = OnceLock::new();
    EDGES.get_or_init(|| StdMutex::new(BTreeSet::new()))
}

thread_local! {
    static HELD: std::cell::RefCell<Vec<&'static str>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Snapshot of the recorded acquisition-order edges (held-lock -> acquired).
pub fn lock_order_edges() -> Vec<(&'static str, &'static str)> {
    edge_graph()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .copied()
        .collect()
}

/// Clear the recorded graph (tests and repeated CLI runs).
pub fn reset_lock_order() {
    edge_graph()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .clear();
}

/// Find a cycle in an acquisition-order edge list. A cycle means two locks
/// are taken in opposite orders somewhere — a potential deadlock. Returns
/// the lock names along the cycle (first == last omitted).
pub fn find_cycle_in(
    edges: &[(&'static str, &'static str)],
) -> Option<Vec<&'static str>> {
    use std::collections::BTreeMap;
    let mut adj: BTreeMap<&'static str, Vec<&'static str>> = BTreeMap::new();
    for &(a, b) in edges {
        adj.entry(a).or_default().push(b);
        adj.entry(b).or_default();
    }
    // 0 = unvisited, 1 = on the current DFS path, 2 = done.
    let mut state: BTreeMap<&'static str, u8> = adj.keys().map(|&k| (k, 0u8)).collect();

    fn dfs(
        node: &'static str,
        adj: &BTreeMap<&'static str, Vec<&'static str>>,
        state: &mut BTreeMap<&'static str, u8>,
        path: &mut Vec<&'static str>,
    ) -> Option<Vec<&'static str>> {
        state.insert(node, 1);
        path.push(node);
        if let Some(next) = adj.get(node) {
            for &nb in next {
                match state.get(&nb).copied().unwrap_or(0) {
                    1 => {
                        let start = path.iter().position(|&p| p == nb).unwrap_or(0);
                        return Some(path[start..].to_vec());
                    }
                    0 => {
                        if let Some(c) = dfs(nb, adj, state, path) {
                            return Some(c);
                        }
                    }
                    _ => {}
                }
            }
        }
        path.pop();
        state.insert(node, 2);
        None
    }

    let nodes: Vec<&'static str> = state.keys().copied().collect();
    for n in nodes {
        if state.get(&n).copied() == Some(0) {
            let mut path = Vec::new();
            if let Some(c) = dfs(n, &adj, &mut state, &mut path) {
                return Some(c);
            }
        }
    }
    None
}

/// Detect a cycle in the currently recorded graph.
pub fn find_lock_cycle() -> Option<Vec<&'static str>> {
    find_cycle_in(&lock_order_edges())
}

/// Crafted deadlocking fixture for `nullanet check --locks`: takes two named
/// locks in opposite orders (sequentially, so it never actually hangs) and
/// thereby plants an A->B / B->A cycle in the acquisition graph.
pub fn run_deadlock_fixture() {
    let a = Mutex::named("fixture.lock_a", 0u32);
    let b = Mutex::named("fixture.lock_b", 0u32);
    {
        let _ga = a.lock();
        let _gb = b.lock();
    }
    {
        let _gb = b.lock();
        let _ga = a.lock();
    }
}

/// RAII token for one held named lock; records edges on acquisition and pops
/// the thread-local held stack on release.
struct Held {
    name: Option<&'static str>,
}

impl Held {
    fn acquire(name: Option<&'static str>) -> Held {
        let Some(n) = name else {
            return Held { name: None };
        };
        if !tracking() {
            return Held { name: None };
        }
        HELD.with(|h| {
            let mut stack = h.borrow_mut();
            if !stack.is_empty() {
                let mut g = edge_graph().lock().unwrap_or_else(|e| e.into_inner());
                for &held in stack.iter() {
                    if held != n {
                        g.insert((held, n));
                    }
                }
            }
            stack.push(n);
        });
        Held { name: Some(n) }
    }
}

impl Drop for Held {
    fn drop(&mut self) {
        if let Some(n) = self.name {
            HELD.with(|h| {
                let mut stack = h.borrow_mut();
                if let Some(pos) = stack.iter().rposition(|&x| x == n) {
                    stack.remove(pos);
                }
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

enum MutexInner<T> {
    Std(std::sync::Mutex<T>),
    #[cfg(nnt_model_check)]
    Model(mc::Mutex<T>),
}

/// Shim mutex: std-backed normally, scheduler-backed inside a model run.
pub struct Mutex<T> {
    name: Option<&'static str>,
    inner: MutexInner<T>,
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        Self::build(None, value)
    }

    /// A named mutex participates in lock-order analysis.
    pub fn named(name: &'static str, value: T) -> Self {
        Self::build(Some(name), value)
    }

    fn build(name: Option<&'static str>, value: T) -> Self {
        #[cfg(nnt_model_check)]
        if mc::active() {
            return Mutex {
                name,
                inner: MutexInner::Model(mc::Mutex::new(value)),
            };
        }
        Mutex {
            name,
            inner: MutexInner::Std(std::sync::Mutex::new(value)),
        }
    }

    /// Acquire with the recover-and-log poison policy.
    pub fn lock(&self) -> MutexGuard<'_, T> {
        match &self.inner {
            MutexInner::Std(m) => {
                let g = m.lock().unwrap_or_else(|e| {
                    note_poison(self.name);
                    m.clear_poison();
                    e.into_inner()
                });
                MutexGuard {
                    inner: MutexGuardInner::Std(g),
                    name: self.name,
                    _held: Held::acquire(self.name),
                }
            }
            #[cfg(nnt_model_check)]
            MutexInner::Model(m) => MutexGuard {
                inner: MutexGuardInner::Model(m.lock()),
                name: self.name,
                _held: Held::acquire(self.name),
            },
        }
    }

    /// Acquire with the typed-error poison policy: a poisoned lock heals but
    /// reports `SyncError` to the caller instead of handing out the guard.
    pub fn lock_checked(&self) -> Result<MutexGuard<'_, T>, SyncError> {
        match &self.inner {
            MutexInner::Std(m) => match m.lock() {
                Ok(g) => Ok(MutexGuard {
                    inner: MutexGuardInner::Std(g),
                    name: self.name,
                    _held: Held::acquire(self.name),
                }),
                Err(_) => {
                    note_poison(self.name);
                    m.clear_poison();
                    Err(SyncError {
                        lock: self.name.unwrap_or("<unnamed>"),
                    })
                }
            },
            #[cfg(nnt_model_check)]
            MutexInner::Model(m) => Ok(MutexGuard {
                inner: MutexGuardInner::Model(m.lock()),
                name: self.name,
                _held: Held::acquire(self.name),
            }),
        }
    }

    /// Consume the mutex, returning the data (poison recovered).
    pub fn into_inner(self) -> T {
        match self.inner {
            MutexInner::Std(m) => m.into_inner().unwrap_or_else(|e| e.into_inner()),
            #[cfg(nnt_model_check)]
            MutexInner::Model(m) => m.into_inner(),
        }
    }
}

enum MutexGuardInner<'a, T> {
    Std(std::sync::MutexGuard<'a, T>),
    #[cfg(nnt_model_check)]
    Model(mc::MutexGuard<'a, T>),
}

pub struct MutexGuard<'a, T> {
    inner: MutexGuardInner<'a, T>,
    name: Option<&'static str>,
    _held: Held,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            MutexGuardInner::Std(g) => g,
            #[cfg(nnt_model_check)]
            MutexGuardInner::Model(g) => g,
        }
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            MutexGuardInner::Std(g) => g,
            #[cfg(nnt_model_check)]
            MutexGuardInner::Model(g) => g,
        }
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

enum CondvarInner {
    Std(std::sync::Condvar),
    #[cfg(nnt_model_check)]
    Model(mc::Condvar),
}

/// Shim condvar; must be paired with a shim [`Mutex`] from the same world
/// (both created inside, or both outside, a model run).
pub struct Condvar {
    inner: CondvarInner,
}

impl Condvar {
    pub fn new() -> Self {
        #[cfg(nnt_model_check)]
        if mc::active() {
            return Condvar {
                inner: CondvarInner::Model(mc::Condvar::new()),
            };
        }
        Condvar {
            inner: CondvarInner::Std(std::sync::Condvar::new()),
        }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let MutexGuard { inner, name, _held } = guard;
        drop(_held);
        match (&self.inner, inner) {
            (CondvarInner::Std(cv), MutexGuardInner::Std(g)) => {
                let g = cv.wait(g).unwrap_or_else(|e| {
                    note_poison(name);
                    e.into_inner()
                });
                MutexGuard {
                    inner: MutexGuardInner::Std(g),
                    name,
                    _held: Held::acquire(name),
                }
            }
            #[cfg(nnt_model_check)]
            (CondvarInner::Model(cv), MutexGuardInner::Model(g)) => MutexGuard {
                inner: MutexGuardInner::Model(cv.wait(g)),
                name,
                _held: Held::acquire(name),
            },
            #[cfg(nnt_model_check)]
            _ => unreachable!("condvar paired with a mutex from a different world"),
        }
    }

    /// Wait with a timeout; returns `(guard, timed_out)`.
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let MutexGuard { inner, name, _held } = guard;
        drop(_held);
        match (&self.inner, inner) {
            (CondvarInner::Std(cv), MutexGuardInner::Std(g)) => {
                let (g, to) = cv.wait_timeout(g, dur).unwrap_or_else(|e| {
                    note_poison(name);
                    e.into_inner()
                });
                (
                    MutexGuard {
                        inner: MutexGuardInner::Std(g),
                        name,
                        _held: Held::acquire(name),
                    },
                    to.timed_out(),
                )
            }
            #[cfg(nnt_model_check)]
            (CondvarInner::Model(cv), MutexGuardInner::Model(g)) => {
                let (g, timed_out) = cv.wait_timeout(g, dur);
                (
                    MutexGuard {
                        inner: MutexGuardInner::Model(g),
                        name,
                        _held: Held::acquire(name),
                    },
                    timed_out,
                )
            }
            #[cfg(nnt_model_check)]
            _ => unreachable!("condvar paired with a mutex from a different world"),
        }
    }

    pub fn notify_one(&self) {
        match &self.inner {
            CondvarInner::Std(cv) => cv.notify_one(),
            #[cfg(nnt_model_check)]
            CondvarInner::Model(cv) => cv.notify_one(),
        }
    }

    pub fn notify_all(&self) {
        match &self.inner {
            CondvarInner::Std(cv) => cv.notify_all(),
            #[cfg(nnt_model_check)]
            CondvarInner::Model(cv) => cv.notify_all(),
        }
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// RwLock
// ---------------------------------------------------------------------------

enum RwLockInner<T> {
    Std(std::sync::RwLock<T>),
    #[cfg(nnt_model_check)]
    Model(mc::RwLock<T>),
}

/// Shim RwLock with the same dual poison policy as [`Mutex`].
pub struct RwLock<T> {
    name: Option<&'static str>,
    inner: RwLockInner<T>,
}

impl<T> RwLock<T> {
    pub fn new(value: T) -> Self {
        Self::build(None, value)
    }

    pub fn named(name: &'static str, value: T) -> Self {
        Self::build(Some(name), value)
    }

    fn build(name: Option<&'static str>, value: T) -> Self {
        #[cfg(nnt_model_check)]
        if mc::active() {
            return RwLock {
                name,
                inner: RwLockInner::Model(mc::RwLock::new(value)),
            };
        }
        RwLock {
            name,
            inner: RwLockInner::Std(std::sync::RwLock::new(value)),
        }
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        match &self.inner {
            RwLockInner::Std(l) => {
                let g = l.read().unwrap_or_else(|e| {
                    note_poison(self.name);
                    l.clear_poison();
                    e.into_inner()
                });
                RwLockReadGuard {
                    inner: ReadGuardInner::Std(g),
                    _held: Held::acquire(self.name),
                }
            }
            #[cfg(nnt_model_check)]
            RwLockInner::Model(l) => RwLockReadGuard {
                inner: ReadGuardInner::Model(l.read()),
                _held: Held::acquire(self.name),
            },
        }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        match &self.inner {
            RwLockInner::Std(l) => {
                let g = l.write().unwrap_or_else(|e| {
                    note_poison(self.name);
                    l.clear_poison();
                    e.into_inner()
                });
                RwLockWriteGuard {
                    inner: WriteGuardInner::Std(g),
                    _held: Held::acquire(self.name),
                }
            }
            #[cfg(nnt_model_check)]
            RwLockInner::Model(l) => RwLockWriteGuard {
                inner: WriteGuardInner::Model(l.write()),
                _held: Held::acquire(self.name),
            },
        }
    }

    pub fn read_checked(&self) -> Result<RwLockReadGuard<'_, T>, SyncError> {
        match &self.inner {
            RwLockInner::Std(l) => match l.read() {
                Ok(g) => Ok(RwLockReadGuard {
                    inner: ReadGuardInner::Std(g),
                    _held: Held::acquire(self.name),
                }),
                Err(_) => {
                    note_poison(self.name);
                    l.clear_poison();
                    Err(SyncError {
                        lock: self.name.unwrap_or("<unnamed>"),
                    })
                }
            },
            #[cfg(nnt_model_check)]
            RwLockInner::Model(l) => Ok(RwLockReadGuard {
                inner: ReadGuardInner::Model(l.read()),
                _held: Held::acquire(self.name),
            }),
        }
    }

    pub fn write_checked(&self) -> Result<RwLockWriteGuard<'_, T>, SyncError> {
        match &self.inner {
            RwLockInner::Std(l) => match l.write() {
                Ok(g) => Ok(RwLockWriteGuard {
                    inner: WriteGuardInner::Std(g),
                    _held: Held::acquire(self.name),
                }),
                Err(_) => {
                    note_poison(self.name);
                    l.clear_poison();
                    Err(SyncError {
                        lock: self.name.unwrap_or("<unnamed>"),
                    })
                }
            },
            #[cfg(nnt_model_check)]
            RwLockInner::Model(l) => Ok(RwLockWriteGuard {
                inner: WriteGuardInner::Model(l.write()),
                _held: Held::acquire(self.name),
            }),
        }
    }
}

enum ReadGuardInner<'a, T> {
    Std(std::sync::RwLockReadGuard<'a, T>),
    #[cfg(nnt_model_check)]
    Model(mc::RwLockReadGuard<'a, T>),
}

pub struct RwLockReadGuard<'a, T> {
    inner: ReadGuardInner<'a, T>,
    _held: Held,
}

impl<T> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            ReadGuardInner::Std(g) => g,
            #[cfg(nnt_model_check)]
            ReadGuardInner::Model(g) => g,
        }
    }
}

enum WriteGuardInner<'a, T> {
    Std(std::sync::RwLockWriteGuard<'a, T>),
    #[cfg(nnt_model_check)]
    Model(mc::RwLockWriteGuard<'a, T>),
}

pub struct RwLockWriteGuard<'a, T> {
    inner: WriteGuardInner<'a, T>,
    _held: Held,
}

impl<T> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            WriteGuardInner::Std(g) => g,
            #[cfg(nnt_model_check)]
            WriteGuardInner::Model(g) => g,
        }
    }
}

impl<T> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            WriteGuardInner::Std(g) => g,
            #[cfg(nnt_model_check)]
            WriteGuardInner::Model(g) => g,
        }
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

#[cfg(not(nnt_model_check))]
pub mod atomic {
    //! Plain re-export of std atomics in normal builds.
    pub use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
}

#[cfg(nnt_model_check)]
pub mod atomic {
    //! Model-aware atomics: std-backed outside model runs, scheduler-backed
    //! (sequentially consistent) inside. Ordering arguments are accepted for
    //! API parity and ignored by the model.
    use crate::util::mc;
    pub use std::sync::atomic::Ordering;

    enum BoolInner {
        Std(std::sync::atomic::AtomicBool),
        Model(mc::AtomicBool),
    }

    pub struct AtomicBool {
        inner: BoolInner,
    }

    impl AtomicBool {
        pub fn new(v: bool) -> Self {
            if mc::active() {
                AtomicBool {
                    inner: BoolInner::Model(mc::AtomicBool::new(v)),
                }
            } else {
                AtomicBool {
                    inner: BoolInner::Std(std::sync::atomic::AtomicBool::new(v)),
                }
            }
        }

        pub fn load(&self, order: Ordering) -> bool {
            match &self.inner {
                BoolInner::Std(a) => a.load(order),
                BoolInner::Model(a) => a.load(),
            }
        }

        pub fn store(&self, v: bool, order: Ordering) {
            match &self.inner {
                BoolInner::Std(a) => a.store(v, order),
                BoolInner::Model(a) => a.store(v),
            }
        }

        pub fn swap(&self, v: bool, order: Ordering) -> bool {
            match &self.inner {
                BoolInner::Std(a) => a.swap(v, order),
                BoolInner::Model(a) => a.swap(v),
            }
        }
    }

    enum UsizeInner {
        Std(std::sync::atomic::AtomicUsize),
        Model(mc::AtomicUsize),
    }

    pub struct AtomicUsize {
        inner: UsizeInner,
    }

    impl AtomicUsize {
        pub fn new(v: usize) -> Self {
            if mc::active() {
                AtomicUsize {
                    inner: UsizeInner::Model(mc::AtomicUsize::new(v)),
                }
            } else {
                AtomicUsize {
                    inner: UsizeInner::Std(std::sync::atomic::AtomicUsize::new(v)),
                }
            }
        }

        pub fn load(&self, order: Ordering) -> usize {
            match &self.inner {
                UsizeInner::Std(a) => a.load(order),
                UsizeInner::Model(a) => a.load(),
            }
        }

        pub fn store(&self, v: usize, order: Ordering) {
            match &self.inner {
                UsizeInner::Std(a) => a.store(v, order),
                UsizeInner::Model(a) => a.store(v),
            }
        }

        pub fn fetch_add(&self, v: usize, order: Ordering) -> usize {
            match &self.inner {
                UsizeInner::Std(a) => a.fetch_add(v, order),
                UsizeInner::Model(a) => a.fetch_add(v),
            }
        }

        pub fn fetch_sub(&self, v: usize, order: Ordering) -> usize {
            match &self.inner {
                UsizeInner::Std(a) => a.fetch_sub(v, order),
                UsizeInner::Model(a) => a.fetch_sub(v),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// mpsc
// ---------------------------------------------------------------------------

#[cfg(not(nnt_model_check))]
pub use std::sync::mpsc;

#[cfg(nnt_model_check)]
pub mod mpsc {
    //! Model-aware mpsc channel: std-backed outside model runs.
    use crate::util::mc;
    use std::time::Duration;
    pub use std::sync::mpsc::{RecvError, RecvTimeoutError, SendError, TryRecvError};

    enum SenderInner<T> {
        Std(std::sync::mpsc::Sender<T>),
        Model(mc::mpsc::Sender<T>),
    }

    pub struct Sender<T> {
        inner: SenderInner<T>,
    }

    enum ReceiverInner<T> {
        Std(std::sync::mpsc::Receiver<T>),
        Model(mc::mpsc::Receiver<T>),
    }

    pub struct Receiver<T> {
        inner: ReceiverInner<T>,
    }

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        if mc::active() {
            let (tx, rx) = mc::mpsc::channel();
            (
                Sender {
                    inner: SenderInner::Model(tx),
                },
                Receiver {
                    inner: ReceiverInner::Model(rx),
                },
            )
        } else {
            let (tx, rx) = std::sync::mpsc::channel();
            (
                Sender {
                    inner: SenderInner::Std(tx),
                },
                Receiver {
                    inner: ReceiverInner::Std(rx),
                },
            )
        }
    }

    impl<T> Sender<T> {
        pub fn send(&self, value: T) -> Result<(), SendError<T>> {
            match &self.inner {
                SenderInner::Std(tx) => tx.send(value),
                SenderInner::Model(tx) => tx.send(value),
            }
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            match &self.inner {
                SenderInner::Std(tx) => Sender {
                    inner: SenderInner::Std(tx.clone()),
                },
                SenderInner::Model(tx) => Sender {
                    inner: SenderInner::Model(tx.clone()),
                },
            }
        }
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            match &self.inner {
                ReceiverInner::Std(rx) => rx.recv(),
                ReceiverInner::Model(rx) => rx.recv(),
            }
        }

        pub fn recv_timeout(&self, dur: Duration) -> Result<T, RecvTimeoutError> {
            match &self.inner {
                ReceiverInner::Std(rx) => rx.recv_timeout(dur),
                ReceiverInner::Model(rx) => rx.recv_timeout(dur),
            }
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            match &self.inner {
                ReceiverInner::Std(rx) => rx.try_recv(),
                ReceiverInner::Model(rx) => rx.try_recv(),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// thread
// ---------------------------------------------------------------------------

#[cfg(not(nnt_model_check))]
pub use std::thread;

#[cfg(nnt_model_check)]
pub mod thread {
    //! Model-aware thread spawn/join: std-backed outside model runs.
    use crate::util::mc;
    use std::time::Duration;

    enum HandleInner<T> {
        Std(std::thread::JoinHandle<T>),
        Model(mc::JoinHandle<T>),
    }

    pub struct JoinHandle<T> {
        inner: HandleInner<T>,
    }

    impl<T: 'static> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            match self.inner {
                HandleInner::Std(h) => h.join(),
                HandleInner::Model(h) => h.join(),
            }
        }

        pub fn is_finished(&self) -> bool {
            match &self.inner {
                HandleInner::Std(h) => h.is_finished(),
                HandleInner::Model(h) => h.is_finished(),
            }
        }
    }

    pub struct Builder {
        name: Option<String>,
    }

    impl Builder {
        pub fn new() -> Self {
            Builder { name: None }
        }

        pub fn name(mut self, name: String) -> Self {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            if mc::active() {
                let name = self.name.unwrap_or_else(|| "model".to_string());
                Ok(JoinHandle {
                    inner: HandleInner::Model(mc::spawn(name, f)),
                })
            } else {
                let mut b = std::thread::Builder::new();
                if let Some(n) = self.name {
                    b = b.name(n);
                }
                b.spawn(f).map(|h| JoinHandle {
                    inner: HandleInner::Std(h),
                })
            }
        }
    }

    impl Default for Builder {
        fn default() -> Self {
            Self::new()
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("failed to spawn thread")
    }

    pub fn yield_now() {
        if mc::active() {
            mc::yield_now();
        } else {
            std::thread::yield_now();
        }
    }

    pub fn sleep(dur: Duration) {
        if mc::active() {
            // Time does not advance in the model; a sleep is just a
            // scheduling opportunity.
            mc::yield_now();
        } else {
            std::thread::sleep(dur);
        }
    }

    pub fn available_parallelism() -> std::io::Result<std::num::NonZeroUsize> {
        std::thread::available_parallelism()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovering_lock_survives_poison() {
        let m = std::sync::Arc::new(Mutex::named("test.poison", 7u32));
        let before = poison_recoveries();
        let m2 = std::sync::Arc::clone(&m);
        let r = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison it");
        })
        .join();
        assert!(r.is_err());
        // Recover-and-log path hands out the guard.
        assert_eq!(*m.lock(), 7);
        assert!(poison_recoveries() > before);
        // Once healed, the checked path succeeds again.
        assert!(m.lock_checked().is_ok());
    }

    #[test]
    fn checked_lock_reports_poison_once_then_heals() {
        let m = std::sync::Arc::new(Mutex::named("test.checked", 1u32));
        let m2 = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock_checked().unwrap();
            panic!("poison it");
        })
        .join();
        let err = m.lock_checked().expect_err("first access sees the error");
        assert_eq!(err.lock, "test.checked");
        assert!(m.lock_checked().is_ok(), "lock healed after report");
    }

    #[test]
    fn rwlock_poison_policies() {
        let l = std::sync::Arc::new(RwLock::named("test.rw", 5u32));
        let l2 = std::sync::Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write();
            panic!("poison it");
        })
        .join();
        assert_eq!(*l.read(), 5, "recovering read survives");
        assert!(l.write_checked().is_ok(), "healed by the recovery");
    }

    #[test]
    fn condvar_roundtrip() {
        let m = std::sync::Arc::new(Mutex::new(false));
        let cv = std::sync::Arc::new(Condvar::new());
        let (m2, cv2) = (std::sync::Arc::clone(&m), std::sync::Arc::clone(&cv));
        let h = std::thread::spawn(move || {
            let mut g = m2.lock();
            while !*g {
                g = cv2.wait(g);
            }
        });
        *m.lock() = true;
        cv.notify_all();
        h.join().unwrap();
        let (g, timed_out) = cv.wait_timeout(m.lock(), Duration::from_millis(1));
        assert!(*g && timed_out, "nobody signals: must time out");
    }

    #[test]
    fn lock_order_cycle_detection() {
        let edges = [("a", "b"), ("b", "c")];
        assert!(find_cycle_in(&edges).is_none());
        let edges = [("a", "b"), ("b", "c"), ("c", "a")];
        let cycle = find_cycle_in(&edges).expect("cycle exists");
        assert!(cycle.len() >= 2, "cycle too short: {cycle:?}");
    }

    #[test]
    fn deadlock_fixture_plants_a_cycle() {
        let was = tracking();
        set_lock_tracking(true);
        reset_lock_order();
        run_deadlock_fixture();
        let cycle = find_lock_cycle().expect("fixture must produce a cycle");
        assert!(
            cycle.iter().any(|n| n.starts_with("fixture.")),
            "cycle should involve the fixture locks: {cycle:?}"
        );
        reset_lock_order();
        set_lock_tracking(was);
    }
}
