//! In-tree micro/macro benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets in `rust/benches/` use `harness = false` and drive
//! this module directly. The harness does warmup, adaptive iteration-count
//! selection targeting a minimum measurement window, and reports
//! median/mean/p95 over sample batches — the statistics `rust/DESIGN.md` §6
//! quotes. Results can also be dumped as JSON for the §Perf log.

use std::time::{Duration, Instant};

/// Statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    /// Nanoseconds per iteration.
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchStats {
    /// Iterations per second implied by the median.
    pub fn throughput(&self) -> f64 {
        1e9 / self.median_ns
    }

    /// Human-readable time per iteration.
    pub fn human_time(&self) -> String {
        format_ns(self.median_ns)
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn format_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with fixed sample/warmup policy.
pub struct Bench {
    /// Number of measured sample batches.
    pub samples: usize,
    /// Target wall-clock duration per sample batch.
    pub sample_target: Duration,
    /// Warmup duration before calibration.
    pub warmup: Duration,
    results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Default policy: 20 samples of ≥5 ms each after 50 ms warmup. Honors
    /// `NNT_BENCH_FAST=1` (used by CI/tests) by shrinking the windows.
    pub fn new() -> Self {
        let fast = std::env::var("NNT_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        if fast {
            Self {
                samples: 5,
                sample_target: Duration::from_millis(1),
                warmup: Duration::from_millis(2),
                results: Vec::new(),
            }
        } else {
            Self {
                samples: 20,
                sample_target: Duration::from_millis(5),
                warmup: Duration::from_millis(50),
                results: Vec::new(),
            }
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> BenchStats {
        // Warmup.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 3 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // Calibrate iterations per sample from warmup rate.
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.sample_target.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut sample_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            sample_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        sample_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sample_ns[sample_ns.len() / 2];
        let mean = sample_ns.iter().sum::<f64>() / sample_ns.len() as f64;
        let p95 = sample_ns[((sample_ns.len() as f64 * 0.95) as usize).min(sample_ns.len() - 1)];
        let stats = BenchStats {
            name: name.to_string(),
            median_ns: median,
            mean_ns: mean,
            p95_ns: p95,
            min_ns: sample_ns[0],
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!(
            "bench {:<44} {:>12}/iter  (mean {:>12}, p95 {:>12}, {} iters x {} samples)",
            stats.name,
            format_ns(stats.median_ns),
            format_ns(stats.mean_ns),
            format_ns(stats.p95_ns),
            stats.iters_per_sample,
            stats.samples,
        );
        self.results.push(stats.clone());
        stats
    }

    /// All results measured so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        std::env::set_var("NNT_BENCH_FAST", "1");
        let mut b = Bench::new();
        let s = b.run("noop-ish", || std::hint::black_box(3u64).wrapping_mul(5));
        assert!(s.median_ns > 0.0);
        assert!(s.median_ns < 1e6, "trivial op should be well under 1ms");
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
    }

    #[test]
    fn format_units() {
        assert!(format_ns(12.0).ends_with("ns"));
        assert!(format_ns(12_000.0).ends_with("µs"));
        assert!(format_ns(12_000_000.0).ends_with("ms"));
        assert!(format_ns(2e9).ends_with("s"));
    }

    #[test]
    fn records_results() {
        std::env::set_var("NNT_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.run("a", || 1 + 1);
        b.run("b", || 2 + 2);
        assert_eq!(b.results().len(), 2);
        assert_eq!(b.results()[0].name, "a");
    }
}
