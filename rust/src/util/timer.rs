//! Lightweight scoped timing + stage profiling used by the flow engine and
//! the §Perf pass. A [`StageTimer`] accumulates named wall-clock spans and
//! prints a flow report (the "Fig. 1 stage log" in DESIGN.md §6/F1).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates named durations across repeated spans.
#[derive(Default, Debug)]
pub struct StageTimer {
    totals: BTreeMap<String, (Duration, u64)>,
    order: Vec<String>,
}

impl StageTimer {
    /// New empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage`.
    pub fn time<R>(&mut self, stage: &str, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.add(stage, t.elapsed());
        r
    }

    /// Record an externally measured duration.
    pub fn add(&mut self, stage: &str, d: Duration) {
        if !self.totals.contains_key(stage) {
            self.order.push(stage.to_string());
        }
        let e = self.totals.entry(stage.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Merge another timer's totals into this one (used to fold per-worker
    /// timers from the thread pool into the flow report).
    pub fn merge(&mut self, other: &StageTimer) {
        for name in &other.order {
            let (d, n) = other.totals[name];
            if !self.totals.contains_key(name) {
                self.order.push(name.clone());
            }
            let e = self.totals.entry(name.clone()).or_insert((Duration::ZERO, 0));
            e.0 += d;
            e.1 += n;
        }
    }

    /// Total time across stages.
    pub fn total(&self) -> Duration {
        self.totals.values().map(|(d, _)| *d).sum()
    }

    /// Duration of one stage, if recorded.
    pub fn stage_total(&self, stage: &str) -> Option<Duration> {
        self.totals.get(stage).map(|(d, _)| *d)
    }

    /// Stage names in first-recorded order.
    pub fn stages(&self) -> &[String] {
        &self.order
    }

    /// Render the stage table.
    pub fn report(&self, title: &str) -> String {
        let mut s = format!("── {title} ──\n");
        let total = self.total().as_secs_f64().max(1e-12);
        for name in &self.order {
            let (d, n) = self.totals[name];
            s.push_str(&format!(
                "  {:<28} {:>10.3} ms  ({:>5.1}%)  x{}\n",
                name,
                d.as_secs_f64() * 1e3,
                100.0 * d.as_secs_f64() / total,
                n
            ));
        }
        s.push_str(&format!("  {:<28} {:>10.3} ms\n", "TOTAL", total * 1e3));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_spans() {
        let mut t = StageTimer::new();
        t.add("a", Duration::from_millis(5));
        t.add("a", Duration::from_millis(5));
        t.add("b", Duration::from_millis(10));
        assert_eq!(t.stage_total("a"), Some(Duration::from_millis(10)));
        assert_eq!(t.total(), Duration::from_millis(20));
        assert_eq!(t.stages(), &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = StageTimer::new();
        let v = t.time("work", || 42);
        assert_eq!(v, 42);
        assert!(t.stage_total("work").is_some());
    }

    #[test]
    fn merge_folds_counts() {
        let mut a = StageTimer::new();
        a.add("x", Duration::from_millis(1));
        let mut b = StageTimer::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.stage_total("x"), Some(Duration::from_millis(3)));
        assert_eq!(a.stage_total("y"), Some(Duration::from_millis(3)));
    }

    #[test]
    fn report_contains_stages() {
        let mut t = StageTimer::new();
        t.add("enumerate", Duration::from_millis(1));
        t.add("espresso", Duration::from_millis(2));
        let r = t.report("flow");
        assert!(r.contains("enumerate"));
        assert!(r.contains("espresso"));
        assert!(r.contains("TOTAL"));
    }
}
