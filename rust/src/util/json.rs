//! Minimal, dependency-free JSON parser and writer.
//!
//! The offline build environment has no `serde`, so model files
//! (`artifacts/*.model.json`), flow configuration, and coordinator wire
//! messages use this in-tree implementation. It supports the full JSON
//! grammar (RFC 8259) minus exotic corner cases we never emit: numbers are
//! parsed as `f64` (with exact `i64` retained when representable), and
//! strings support the standard escapes including `\uXXXX` (with surrogate
//! pairs).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Numbers keep both representations: `i` is `Some` when the literal was
    /// integral and fits an `i64` (weights and truth-table entries must
    /// round-trip exactly).
    Num { f: f64, i: Option<i64> },
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`Json::parse`], with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Construct an integer number.
    pub fn int(v: i64) -> Json {
        Json::Num { f: v as f64, i: Some(v) }
    }

    /// Construct a float number.
    pub fn float(v: f64) -> Json {
        let i = if v.fract() == 0.0 && v.abs() < 9.0e15 { Some(v as i64) } else { None };
        Json::Num { f: v, i }
    }

    /// Construct a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Construct an object from pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors ----

    /// As bool, if this is a Bool.
    pub fn as_bool(&self) -> Option<bool> {
        if let Json::Bool(b) = self { Some(*b) } else { None }
    }

    /// As f64, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        if let Json::Num { f, .. } = self { Some(*f) } else { None }
    }

    /// As i64, if this is an integral number.
    pub fn as_i64(&self) -> Option<i64> {
        if let Json::Num { i, .. } = self { *i } else { None }
    }

    /// As usize, if this is a non-negative integral number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// As str, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        if let Json::Str(s) = self { Some(s) } else { None }
    }

    /// As array slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        if let Json::Arr(a) = self { Some(a) } else { None }
    }

    /// As object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        if let Json::Obj(o) = self { Some(o) } else { None }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers used by the model loader: error messages name
    /// the missing key instead of panicking deep in a decoder.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    /// Decode a `Vec<f64>` from a JSON array of numbers.
    pub fn to_f64_vec(&self) -> Result<Vec<f64>, String> {
        let arr = self.as_arr().ok_or("expected array")?;
        arr.iter()
            .map(|v| v.as_f64().ok_or_else(|| "expected number".to_string()))
            .collect()
    }

    /// Decode a `Vec<i64>` from a JSON array of integers.
    pub fn to_i64_vec(&self) -> Result<Vec<i64>, String> {
        let arr = self.as_arr().ok_or("expected array")?;
        arr.iter()
            .map(|v| v.as_i64().ok_or_else(|| "expected integer".to_string()))
            .collect()
    }

    /// Decode a `Vec<usize>` from a JSON array of non-negative integers.
    pub fn to_usize_vec(&self) -> Result<Vec<usize>, String> {
        let arr = self.as_arr().ok_or("expected array")?;
        arr.iter()
            .map(|v| v.as_usize().ok_or_else(|| "expected non-negative integer".to_string()))
            .collect()
    }

    // ---- parsing ----

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- emission ----

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize human-readably: objects and mixed arrays get one entry per
    /// line (two-space indent), while arrays of scalars stay inline. Used
    /// for inspectable on-disk artifacts; parses back identically.
    pub fn to_pretty_string(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        fn pad(out: &mut String, n: usize) {
            for _ in 0..n {
                out.push(' ');
            }
        }
        match self {
            Json::Arr(a)
                if !a.is_empty()
                    && a.iter().any(|v| matches!(v, Json::Arr(_) | Json::Obj(_))) =>
            {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    pad(out, indent + 2);
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    pad(out, indent + 2);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                pad(out, indent);
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num { f, i } => {
                if let Some(i) = i {
                    out.push_str(&i.to_string());
                } else if f.is_finite() {
                    // Shortest float repr Rust gives round-trips through f64.
                    let _ = fmt::Write::write_fmt(out, format_args!("{f}"));
                    if !out.ends_with(|c: char| c.is_ascii_digit()) || !out.contains(['.', 'e']) {
                        // ensure it re-parses as a number either way; `{f}`
                        // already emits a valid JSON number for finite f64s
                        // except integral values, which took the branch above.
                    }
                } else {
                    // JSON has no NaN/Inf; emit null (we never produce these
                    // in model files — guarded by tests).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { at: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-decode UTF-8: collect continuation bytes.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        self.pos = start + width;
                        if self.pos > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let Some(c) = self.peek() else {
                return Err(self.err("truncated \\u escape"));
            };
            self.pos += 1;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("bad hex digit"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut integral = true;
        if self.peek() == Some(b'.') {
            integral = false;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            integral = false;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let f: f64 = text.parse().map_err(|_| self.err("bad number"))?;
        let i = if integral { text.parse::<i64>().ok() } else { None };
        Ok(Json::Num { f, i })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("-7").unwrap().as_i64(), Some(-7));
        assert_eq!(Json::parse("2.5").unwrap().as_f64(), Some(2.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap().as_str(), Some("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line\nquote\"back\\slash\ttab\u{1F600}";
        let j = Json::str(s);
        let emitted = j.to_string();
        let parsed = Json::parse(&emitted).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap().as_str(),
            Some("\u{1F600}")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn big_int_roundtrip_exact() {
        let v = 9_007_199_254_740_993i64; // 2^53 + 1: not representable in f64
        let j = Json::int(v);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_i64(), Some(v));
    }

    #[test]
    fn float_roundtrip() {
        for &f in &[0.1, -3.25e-9, 1.0 / 3.0, 6.02e23] {
            let j = Json::float(f);
            let back = Json::parse(&j.to_string()).unwrap();
            assert_eq!(back.as_f64(), Some(f));
        }
    }

    #[test]
    fn object_emission_is_deterministic() {
        let j = Json::obj([("b", Json::int(1)), ("a", Json::int(2))]);
        assert_eq!(j.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn vec_decoders() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.to_i64_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.to_usize_vec().unwrap(), vec![1, 2, 3]);
        let f = Json::parse("[1.5, -2.0]").unwrap();
        assert_eq!(f.to_f64_vec().unwrap(), vec![1.5, -2.0]);
        assert!(f.to_i64_vec().is_err());
        assert!(Json::parse("[-1]").unwrap().to_usize_vec().is_err());
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let j = Json::obj([
            ("a", Json::Arr(vec![Json::int(1), Json::int(2)])),
            (
                "b",
                Json::Arr(vec![Json::obj([("x", Json::Bool(true))])]),
            ),
            ("c", Json::str("s")),
        ]);
        let pretty = j.to_pretty_string();
        assert!(pretty.contains("\n  \"a\": [1,2]"), "{pretty}");
        assert!(pretty.contains("\n  \"b\": [\n"), "{pretty}");
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        // Scalars stay compact.
        assert_eq!(Json::int(7).to_pretty_string(), "7\n");
    }

    #[test]
    fn req_reports_missing_key() {
        let v = Json::parse(r#"{"x": 1}"#).unwrap();
        assert!(v.req("x").is_ok());
        let e = v.req("y").unwrap_err();
        assert!(e.contains("'y'"), "{e}");
    }
}
