//! Dependency-free nonblocking event loop over thin epoll syscall shims.
//!
//! The serving front end (`coordinator::server`) historically ran one
//! blocking thread per connection with a 50 ms read-timeout poll to notice
//! shutdown — the exact host-side overhead the paper's fixed-function
//! datapath is supposed to eliminate. This module is the substrate for the
//! event-driven replacement (S8 in the `rust/DESIGN.md` §3 substitution
//! table, standing in for `mio`): a level-triggered epoll wrapper plus an
//! `eventfd` waker, with **no timers and no polling** — every wakeup is a
//! readiness edge or an explicit [`Waker::wake`].
//!
//! Scope is deliberately thin: readiness multiplexing only. Accept loops,
//! per-connection state machines, framing, and backpressure live in the
//! caller (`coordinator::server::serve_event`); this module owns exactly
//! the `unsafe` FFI surface, so everything above it stays safe Rust.
//!
//! The syscall shims are direct `extern "C"` declarations against the
//! platform libc that `std` already links — no crates, no bindings
//! generator. On non-Linux targets the module compiles to a stub whose
//! constructor reports [`std::io::ErrorKind::Unsupported`]; the serving
//! binary falls back to the blocking path there.

#![allow(clippy::needless_return)]

use std::io;
use std::time::Duration;

/// Token [`EventLoop::wait`] reports when [`Waker::wake`] was called.
/// Reserved: user registrations must not use it.
pub const WAKER_TOKEN: u64 = u64::MAX;

/// Which readiness a registration wants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    /// Wake when the fd is readable (or the peer hung up).
    pub readable: bool,
    /// Wake when the fd accepts writes without blocking.
    pub writable: bool,
}

impl Interest {
    /// Read readiness only — the steady state of an idle connection.
    pub const READ: Interest = Interest { readable: true, writable: false };
    /// Write readiness only — a connection paused for backpressure.
    pub const WRITE: Interest = Interest { readable: false, writable: true };
    /// Both directions — a connection flushing a partial write while
    /// still accepting pipelined requests.
    pub const BOTH: Interest = Interest { readable: true, writable: true };
}

/// One readiness notification from [`EventLoop::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the fd was registered with ([`WAKER_TOKEN`] for wakeups).
    pub token: u64,
    /// Reading will not block (data, EOF, or a pending accept).
    pub readable: bool,
    /// Writing will not block.
    pub writable: bool,
    /// The peer closed or the fd errored (`EPOLLERR`/`EPOLLHUP`/
    /// `EPOLLRDHUP`); the connection should be torn down after draining.
    pub closed: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    use super::{Event, Interest, WAKER_TOKEN};
    use std::ffi::{c_int, c_uint, c_void};
    use std::io;
    use std::os::fd::RawFd;
    use std::sync::Arc;
    use std::time::Duration;

    const EPOLL_CLOEXEC: c_int = 0x80000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLLRDHUP: u32 = 0x2000;
    const EFD_CLOEXEC: c_int = 0x80000;
    const EFD_NONBLOCK: c_int = 0x800;

    // The kernel ABI packs the 12-byte epoll_event on x86 so the 64-bit
    // data field sits at offset 4; other architectures use natural layout.
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }
    #[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    // Declarations against the libc `std` already links — prototypes match
    // epoll_create1(2), epoll_ctl(2), epoll_wait(2), eventfd(2), close(2),
    // read(2), write(2).
    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }

    fn interest_mask(interest: Interest) -> u32 {
        let mut ev = EPOLLRDHUP; // always learn about peer half-close
        if interest.readable {
            ev |= EPOLLIN;
        }
        if interest.writable {
            ev |= EPOLLOUT;
        }
        ev
    }

    /// Owns the eventfd; closed exactly once when the last clone
    /// (event loop or any [`Waker`]) drops.
    struct WakeFd(RawFd);

    impl Drop for WakeFd {
        fn drop(&mut self) {
            // SAFETY: self.0 is the eventfd this struct uniquely owns; it
            // is closed exactly once, here.
            unsafe { close(self.0) };
        }
    }

    /// Cloneable, `Send + Sync` handle that interrupts a blocked
    /// [`EventLoop::wait`] from any thread.
    #[derive(Clone)]
    pub struct Waker {
        fd: Arc<WakeFd>,
    }

    impl Waker {
        /// Wake the event loop. Nonblocking and async-signal-cheap: a
        /// single 8-byte write to an eventfd. Multiple wakes before the
        /// loop runs coalesce into one [`WAKER_TOKEN`] event.
        pub fn wake(&self) {
            let one: u64 = 1;
            // SAFETY: fd is a live eventfd (kept alive by the Arc), and we
            // pass a valid 8-byte buffer as eventfd(2) requires. A full
            // counter (EAGAIN) still leaves the fd readable, which is all
            // a wakeup needs, so the result is intentionally ignored.
            unsafe { write(self.fd.0, (&one as *const u64).cast::<c_void>(), 8) };
        }
    }

    /// Level-triggered epoll instance plus its wakeup eventfd.
    pub struct EventLoop {
        epfd: RawFd,
        waker: Waker,
        buf: Vec<EpollEvent>,
    }

    impl EventLoop {
        /// Create the epoll instance and its waker eventfd, both
        /// close-on-exec.
        pub fn new() -> io::Result<EventLoop> {
            // SAFETY: epoll_create1 takes a flags word and returns a new
            // fd or -1; no pointers are involved.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            // SAFETY: eventfd takes an initial counter and flags and
            // returns a new fd or -1; no pointers are involved.
            let efd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
            if efd < 0 {
                let err = io::Error::last_os_error();
                // SAFETY: epfd was just returned by epoll_create1.
                unsafe { close(epfd) };
                return Err(err);
            }
            let waker = Waker { fd: Arc::new(WakeFd(efd)) };
            let lp = EventLoop { epfd, waker, buf: Vec::new() };
            lp.ctl(EPOLL_CTL_ADD, efd, EPOLLIN, WAKER_TOKEN)?;
            Ok(lp)
        }

        /// A handle other threads use to interrupt [`wait`](Self::wait).
        pub fn waker(&self) -> Waker {
            self.waker.clone()
        }

        fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            // SAFETY: epfd is our live epoll fd, and `ev` is a valid
            // epoll_event for the duration of the call (epoll_ctl copies
            // it into the kernel before returning).
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Start watching `fd` with `token`. `token` must not be
        /// [`WAKER_TOKEN`]. The caller keeps ownership of the fd and must
        /// [`deregister`](Self::deregister) before closing it.
        pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            assert_ne!(token, WAKER_TOKEN, "WAKER_TOKEN is reserved");
            self.ctl(EPOLL_CTL_ADD, fd, interest_mask(interest), token)
        }

        /// Change the interest set of an already-registered fd.
        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            assert_ne!(token, WAKER_TOKEN, "WAKER_TOKEN is reserved");
            self.ctl(EPOLL_CTL_MOD, fd, interest_mask(interest), token)
        }

        /// Stop watching `fd`.
        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Block until at least one fd is ready, a waker fires, or
        /// `timeout` elapses (`None` = wait forever). Appends to nothing:
        /// `events` is cleared first. Returns the number of events
        /// delivered (0 = timeout). `EINTR` restarts the wait.
        ///
        /// A waker firing is reported as an [`Event`] with
        /// [`WAKER_TOKEN`]; the eventfd counter is drained here so a
        /// level-triggered loop does not spin.
        pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<usize> {
            events.clear();
            let ms: c_int = match timeout {
                None => -1,
                // Round up so a 1 ns timeout still sleeps ~1 ms instead
                // of busy-looping at timeout 0.
                Some(d) => {
                    let up = u128::from(d.subsec_nanos() % 1_000_000 != 0);
                    (d.as_millis() + up).min(c_int::MAX as u128) as c_int
                }
            };
            self.buf.resize(64, EpollEvent { events: 0, data: 0 });
            let n = loop {
                // SAFETY: epfd is our live epoll fd and buf is a live,
                // properly-sized array of epoll_event the kernel fills in.
                let rc = unsafe {
                    epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, ms)
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            for raw in &self.buf[..n] {
                let (bits, token) = { (raw.events, raw.data) };
                if token == WAKER_TOKEN {
                    let mut counter: u64 = 0;
                    // SAFETY: the waker fd is a live nonblocking eventfd
                    // and we pass a valid 8-byte buffer; reading drains
                    // the coalesced counter (EAGAIN is fine).
                    unsafe {
                        read(self.waker.fd.0, (&mut counter as *mut u64).cast::<c_void>(), 8)
                    };
                    events.push(Event {
                        token: WAKER_TOKEN,
                        readable: false,
                        writable: false,
                        closed: false,
                    });
                    continue;
                }
                events.push(Event {
                    token,
                    readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    closed: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(events.len())
        }
    }

    impl Drop for EventLoop {
        fn drop(&mut self) {
            // SAFETY: epfd is the epoll fd this struct uniquely owns; it
            // is closed exactly once, here. The waker eventfd is closed by
            // the last WakeFd clone's Drop.
            unsafe { close(self.epfd) };
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    //! Stub for non-Linux targets: constructing an [`EventLoop`] reports
    //! `Unsupported`, and the serving binary falls back to the blocking
    //! thread-per-connection path.
    use super::{Event, Interest};
    use std::io;
    use std::time::Duration;

    /// No-op waker for targets without the event loop.
    #[derive(Clone)]
    pub struct Waker;

    impl Waker {
        /// No-op.
        pub fn wake(&self) {}
    }

    /// Unsupported on this target; [`EventLoop::new`] always errors.
    pub struct EventLoop;

    type RawFd = i32;

    impl EventLoop {
        /// Always `Err(Unsupported)` off Linux.
        pub fn new() -> io::Result<EventLoop> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "evloop requires Linux epoll; use the blocking serve path",
            ))
        }

        /// Unreachable (no instance can exist).
        pub fn waker(&self) -> Waker {
            Waker
        }

        /// Unreachable (no instance can exist).
        pub fn register(&self, _fd: RawFd, _token: u64, _i: Interest) -> io::Result<()> {
            unreachable!("no EventLoop instance exists off Linux")
        }

        /// Unreachable (no instance can exist).
        pub fn modify(&self, _fd: RawFd, _token: u64, _i: Interest) -> io::Result<()> {
            unreachable!("no EventLoop instance exists off Linux")
        }

        /// Unreachable (no instance can exist).
        pub fn deregister(&self, _fd: RawFd) -> io::Result<()> {
            unreachable!("no EventLoop instance exists off Linux")
        }

        /// Unreachable (no instance can exist).
        pub fn wait(&mut self, _ev: &mut Vec<Event>, _t: Option<Duration>) -> io::Result<usize> {
            unreachable!("no EventLoop instance exists off Linux")
        }
    }
}

pub use sys::{EventLoop, Waker};

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::time::Instant;

    #[test]
    fn timeout_expires_with_no_events() {
        let mut lp = EventLoop::new().unwrap();
        let mut events = Vec::new();
        let t0 = Instant::now();
        let n = lp.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert_eq!(n, 0);
        assert!(t0.elapsed() >= Duration::from_millis(15), "returned too early");
    }

    #[test]
    fn waker_interrupts_a_blocked_wait_from_another_thread() {
        let mut lp = EventLoop::new().unwrap();
        let waker = lp.waker();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
            waker.wake(); // coalesces with the first
        });
        let mut events = Vec::new();
        // No timeout: only the waker can end this wait.
        let n = lp.wait(&mut events, None).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token, WAKER_TOKEN);
        handle.join().unwrap();
        // The counter was drained: a short follow-up wait sees nothing.
        let n = lp.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn tcp_accept_read_write_readiness_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut lp = EventLoop::new().unwrap();
        lp.register(listener.as_raw_fd(), 1, Interest::READ).unwrap();

        let mut client = TcpStream::connect(addr).unwrap();
        let mut events = Vec::new();
        lp.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable), "accept readiness");

        let (mut conn, _) = listener.accept().unwrap();
        conn.set_nonblocking(true).unwrap();
        lp.register(conn.as_raw_fd(), 2, Interest::READ).unwrap();

        client.write_all(b"ping").unwrap();
        lp.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 2 && e.readable), "read readiness");
        let mut buf = [0u8; 4];
        conn.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");

        // An idle socket's send buffer is writable immediately.
        lp.modify(conn.as_raw_fd(), 2, Interest::BOTH).unwrap();
        lp.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 2 && e.writable), "write readiness");

        // Peer close surfaces as closed+readable so the conn drains then dies.
        drop(client);
        lp.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        let ev = events.iter().find(|e| e.token == 2).expect("hup event");
        assert!(ev.closed && ev.readable);

        lp.deregister(conn.as_raw_fd()).unwrap();
        lp.deregister(listener.as_raw_fd()).unwrap();
    }
}
