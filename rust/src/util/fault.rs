//! Deterministic fault injection for the resilience test suite.
//!
//! The serving stack promises graceful degradation — crash-safe artifact
//! writes, a native→SIMD→scalar engine ladder, deadline shedding — but a
//! recovery path that is never executed is a recovery path that does not
//! work. This module places **named injection points** at the exact
//! boundaries where the real world fails:
//!
//! | point            | site                                   | simulates                       |
//! |------------------|----------------------------------------|---------------------------------|
//! | `artifact.write` | [`crate::flow::store`] temp-file write | crash / full disk mid-write     |
//! | `codegen.rustc`  | [`crate::logic::codegen::build_so`]    | toolchain missing on serve host |
//! | `dlopen`         | [`crate::logic::codegen::NativeLib`]   | `.so` unlinked / loader failure |
//! | `engine.eval`    | `NativeCodegenEngine::classify`        | native library failing mid-serve|
//! | `socket.write`   | event-loop `Conn::flush`               | short writes / tiny send buffers|
//!
//! Following the `util::sync` / `util::mc` pattern, the harness has two
//! builds selected by `--cfg nnt_fault`:
//!
//! * **Default (release and tier-1 test builds):** [`should_fail`] is a
//!   `const`-foldable `false` — the injection points compile to nothing
//!   and the hot path pays zero cost.
//! * **`--cfg nnt_fault` (chaos builds):** each point carries an armed
//!   [`Plan`] — fire always, fire the next *n* calls, or fire a seeded
//!   per-mille fraction of calls. Rate decisions hash `(seed, point,
//!   call-index)`, so a given seed produces the same fault sequence at
//!   each point regardless of thread interleaving *between* points —
//!   the chaos suite (`rust/tests/chaos.rs`) replays bug reports by seed.
//!
//! State is process-global atomics (no locks: an injection point must
//! never block or reorder the code around it). Tests that arm points
//! serialize themselves and call [`reset`] when done.

use std::sync::atomic::{AtomicU64, Ordering};

/// Every named injection point, in registry order. Indexes into the
/// per-point atomics; [`point_index`] maps names back.
pub const POINTS: [&str; 5] =
    ["artifact.write", "codegen.rustc", "dlopen", "engine.eval", "socket.write"];

/// What an armed injection point does on each call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plan {
    /// Never fire (the disarmed state).
    Off,
    /// Fire on every call.
    Always,
    /// Fire on the next `n` calls, then disarm.
    Times(u32),
    /// Fire on `p` calls per thousand, decided by a seeded hash of the
    /// per-point call index — deterministic for a given seed.
    Permille(u32),
}

/// Whether fault injection is compiled into this build (`--cfg nnt_fault`).
/// The chaos suite asserts this; `nullanet check --faults` reports it.
pub const fn armed() -> bool {
    cfg!(nnt_fault)
}

/// Index of a point name in [`POINTS`], if known.
pub fn point_index(point: &str) -> Option<usize> {
    POINTS.iter().position(|&p| p == point)
}

const NPOINTS: usize = POINTS.len();

// Plan encoding, one u64 per point: bits 32..34 = mode (0 off, 1 always,
// 2 times, 3 permille), bits 0..32 = parameter (remaining count or
// per-mille rate). `Times` decrements the parameter with a CAS loop so
// concurrent callers fire exactly `n` times in total.
const MODE_OFF: u64 = 0;
const MODE_ALWAYS: u64 = 1 << 32;
const MODE_TIMES: u64 = 2 << 32;
const MODE_PERMILLE: u64 = 3 << 32;
const MODE_MASK: u64 = 3 << 32;
const PARAM_MASK: u64 = (1 << 32) - 1;

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static PLANS: [AtomicU64; NPOINTS] = [ZERO; NPOINTS];
static CALLS: [AtomicU64; NPOINTS] = [ZERO; NPOINTS];
static FIRED: [AtomicU64; NPOINTS] = [ZERO; NPOINTS];
static SEED: AtomicU64 = AtomicU64::new(0);

fn encode(plan: Plan) -> u64 {
    match plan {
        Plan::Off => MODE_OFF,
        Plan::Always => MODE_ALWAYS,
        Plan::Times(n) => MODE_TIMES | u64::from(n),
        Plan::Permille(p) => MODE_PERMILLE | u64::from(p.min(1000)),
    }
}

/// Arm one injection point with a plan. Unknown point names are ignored
/// (the inventory in [`POINTS`] is the contract; `check --faults`
/// exercises every entry). No-op without `--cfg nnt_fault`.
pub fn arm(point: &str, plan: Plan) {
    if !armed() {
        return;
    }
    if let Some(i) = point_index(point) {
        PLANS[i].store(encode(plan), Ordering::SeqCst);
    }
}

/// Arm every point at `permille` per-thousand, seeded: the canonical
/// chaos-sweep configuration. No-op without `--cfg nnt_fault`.
pub fn arm_all(seed: u64, permille: u32) {
    if !armed() {
        return;
    }
    SEED.store(seed, Ordering::SeqCst);
    for p in PLANS.iter() {
        p.store(encode(Plan::Permille(permille)), Ordering::SeqCst);
    }
}

/// Set the seed used by [`Plan::Permille`] decisions without changing
/// any plan. No-op without `--cfg nnt_fault`.
pub fn set_seed(seed: u64) {
    if armed() {
        SEED.store(seed, Ordering::SeqCst);
    }
}

/// Disarm every point and zero the call/fire counters.
pub fn reset() {
    for i in 0..NPOINTS {
        PLANS[i].store(MODE_OFF, Ordering::SeqCst);
        CALLS[i].store(0, Ordering::SeqCst);
        FIRED[i].store(0, Ordering::SeqCst);
    }
    SEED.store(0, Ordering::SeqCst);
}

/// Disarm one point and zero its counters, leaving the others alone —
/// lets parallel tests own disjoint points without a global gate.
pub fn reset_point(point: &str) {
    if let Some(i) = point_index(point) {
        PLANS[i].store(MODE_OFF, Ordering::SeqCst);
        CALLS[i].store(0, Ordering::SeqCst);
        FIRED[i].store(0, Ordering::SeqCst);
    }
}

/// How many times `point` has fired (decided to fail) since [`reset`].
pub fn injected(point: &str) -> u64 {
    point_index(point).map_or(0, |i| FIRED[i].load(Ordering::SeqCst))
}

/// How many times `point` has been consulted since [`reset`].
pub fn calls(point: &str) -> u64 {
    point_index(point).map_or(0, |i| CALLS[i].load(Ordering::SeqCst))
}

/// SplitMix64 — the same mix `util::prng` seeds with; good avalanche on
/// sequential inputs, which is exactly the (seed, point, call) stream.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The injection point itself: `true` means "fail here, now". Without
/// `--cfg nnt_fault` this is a constant `false` the optimizer deletes.
#[inline]
pub fn should_fail(point: &str) -> bool {
    if !armed() {
        return false;
    }
    let Some(i) = point_index(point) else { return false };
    let call = CALLS[i].fetch_add(1, Ordering::SeqCst);
    let fire = loop {
        let plan = PLANS[i].load(Ordering::SeqCst);
        match plan & MODE_MASK {
            MODE_ALWAYS => break true,
            MODE_TIMES => {
                let left = plan & PARAM_MASK;
                if left == 0 {
                    break false;
                }
                let next = if left == 1 { MODE_OFF } else { MODE_TIMES | (left - 1) };
                if PLANS[i]
                    .compare_exchange(plan, next, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    break true;
                }
                // lost the race; re-read the plan and retry
            }
            MODE_PERMILLE => {
                let p = plan & PARAM_MASK;
                let seed = SEED.load(Ordering::SeqCst);
                let h = mix(seed ^ ((i as u64) << 48) ^ call);
                break h % 1000 < p;
            }
            _ => break false,
        }
    };
    if fire {
        FIRED[i].fetch_add(1, Ordering::SeqCst);
    }
    fire
}

#[cfg(all(test, not(nnt_fault)))]
mod tests_disarmed {
    use super::*;

    #[test]
    fn disarmed_build_never_fires() {
        assert!(!armed());
        arm("engine.eval", Plan::Always);
        arm_all(7, 1000);
        for p in POINTS {
            assert!(!should_fail(p), "{p} fired in a disarmed build");
            assert_eq!(injected(p), 0);
        }
        reset();
    }
}

#[cfg(all(test, nnt_fault))]
mod tests_armed {
    // Harness state is process-global and the test runner is parallel, so
    // each test here owns a disjoint set of points and resets only those
    // — never the whole registry. (The chaos suite, a separate process,
    // serializes itself and may use the global `reset`.)
    use super::*;

    #[test]
    fn times_plan_fires_exactly_n_then_disarms() {
        reset_point("dlopen");
        arm("dlopen", Plan::Times(3));
        let fired: usize = (0..10).filter(|_| should_fail("dlopen")).count();
        assert_eq!(fired, 3);
        assert_eq!(injected("dlopen"), 3);
        assert_eq!(calls("dlopen"), 10);
        reset_point("dlopen");
    }

    #[test]
    fn permille_is_deterministic_per_seed() {
        let run = |seed: u64| -> Vec<bool> {
            reset_point("socket.write");
            set_seed(seed);
            arm("socket.write", Plan::Permille(250));
            (0..64).map(|_| should_fail("socket.write")).collect()
        };
        let a = run(11);
        let b = run(11);
        let c = run(12);
        assert_eq!(a, b, "same seed must replay the same fault sequence");
        assert_ne!(a, c, "different seeds should diverge (64 draws at 25%)");
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f));
        reset_point("socket.write");
    }

    #[test]
    fn points_are_independent() {
        reset_point("codegen.rustc");
        reset_point("artifact.write");
        arm("codegen.rustc", Plan::Always);
        assert!(should_fail("codegen.rustc"));
        assert!(!should_fail("artifact.write"));
        assert_eq!(injected("codegen.rustc"), 1);
        assert_eq!(injected("artifact.write"), 0);
        reset_point("codegen.rustc");
        reset_point("artifact.write");
    }
}
