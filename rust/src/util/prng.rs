//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so we implement the small
//! set of generators the project needs: [`SplitMix64`] for seeding and
//! [`Xoshiro256`] (xoshiro256**) for bulk generation. Both are
//! well-studied, public-domain algorithms (Blackman & Vigna). Everything in
//! the repository that consumes randomness (synthetic datasets, property
//! tests, benchmark workloads) is seeded explicitly so runs are reproducible.

/// SplitMix64: a tiny, fast 64-bit generator used to expand a single `u64`
/// seed into the larger state of [`Xoshiro256`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the project's general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion (the construction recommended by the
    /// xoshiro authors; avoids the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased multiply-shift
    /// rejection method. `n` must be nonzero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            // Rejection zone: accept unless lo < (2^64 mod n).
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Standard normal deviate (Box–Muller, polar form avoided for
    /// determinism of call counts: always consumes exactly two u64s).
    pub fn next_gaussian(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = if u1 <= f64::MIN_POSITIVE { f64::MIN_POSITIVE } else { u1 };
        let u2 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Bernoulli trial with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public-domain C code).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_well_spread() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::new(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3, "different seeds should diverge");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::new(3);
        let idx = r.sample_indices(20, 7);
        assert_eq!(idx.len(), 7);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 7);
    }

    #[test]
    fn range_i64_inclusive() {
        let mut r = Xoshiro256::new(13);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }
}
