//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--switch` style used by the `nullanet` binary and the examples. Unknown
//! flags are an error so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, named options, and positionals.
#[derive(Debug, Default)]
pub struct Args {
    /// First bare word, if any (e.g. `flow` in `nullanet flow --arch jsc-s`).
    pub command: Option<String>,
    /// `--key value` and `--key=value` pairs; bare `--switch` maps to "true".
    pub options: BTreeMap<String, String>,
    /// Remaining bare words after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — pass
    /// `std::env::args().skip(1)` in `main`.
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err("bare '--' is not supported".into());
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.options.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Integer option with default; errors on malformed input.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got '{v}'")),
        }
    }

    /// Float option with default; errors on malformed input.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected number, got '{v}'")),
        }
    }

    /// Boolean switch (`--foo` or `--foo=true/false`).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Error if any option key is not in `allowed` — catches typos.
    pub fn check_known(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.options.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown option --{k}; known: {}",
                    allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("flow --arch jsc-s --jobs 4 --verbose");
        assert_eq!(a.command.as_deref(), Some("flow"));
        assert_eq!(a.get_str("arch", "x"), "jsc-s");
        assert_eq!(a.get_usize("jobs", 1).unwrap(), 4);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn equals_style() {
        let a = parse("bench --n=100 --ratio=0.5");
        assert_eq!(a.get_usize("n", 0).unwrap(), 100);
        assert_eq!(a.get_f64("ratio", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn positionals() {
        let a = parse("run one two");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn defaults_on_missing() {
        let a = parse("x");
        assert_eq!(a.get_str("missing", "dflt"), "dflt");
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_opt("missing").is_none());
    }

    #[test]
    fn malformed_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 0).is_err());
        assert!(a.get_f64("n", 0.0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("x --good 1 --typo 2");
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "typo"]).is_ok());
    }
}
