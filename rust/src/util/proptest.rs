//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! Provides seeded random case generation with automatic shrinking for the
//! coordinator/logic invariants the test suites check (e.g. "ESPRESSO output
//! is equivalent to its input cover", "retiming preserves I/O behaviour").
//! Failures print the seed and the shrunken case so they can be replayed
//! deterministically (`NNT_PROPTEST_SEED` overrides the default seed;
//! `NNT_PROPTEST_CASES` the case count).

use crate::util::prng::Xoshiro256;

/// Per-case source of randomness handed to generators.
pub struct Gen {
    pub rng: Xoshiro256,
    /// Size hint in [0, 1]; early cases are small, later cases large — this
    /// gives coverage of both trivial and stressful inputs.
    pub size: f64,
}

impl Gen {
    /// Integer in `[lo, hi]` scaled so small `size` biases toward `lo`.
    pub fn sized_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64) * self.size).ceil() as usize;
        lo + self.rng.below(scaled as u64 + 1) as usize
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        let cases = std::env::var("NNT_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("NNT_PROPTEST_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(DEFAULT_SEED);
        Self { cases, seed, max_shrink_steps: 200 }
    }
}

/// Default property-test seed (overridable via `NNT_PROPTEST_SEED`).
const DEFAULT_SEED: u64 = 0x5EED_CAFE_F00D_0001;

/// Run property `prop` over `cases` generated inputs. `gen` produces a case
/// from a [`Gen`]; `shrink` proposes smaller variants of a failing case;
/// `prop` returns `Err(reason)` on violation.
pub fn check<T: Clone + std::fmt::Debug>(
    name: &str,
    config: &Config,
    mut generate: impl FnMut(&mut Gen) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case_idx in 0..config.cases {
        let case_seed = config.seed ^ (case_idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen {
            rng: Xoshiro256::new(case_seed),
            size: (case_idx as f64 + 1.0) / config.cases as f64,
        };
        let case = generate(&mut g);
        if let Err(reason) = prop(&case) {
            // Shrink: greedily accept any smaller failing variant.
            let mut best = case.clone();
            let mut best_reason = reason;
            let mut steps = 0;
            'outer: loop {
                for candidate in shrink(&best) {
                    steps += 1;
                    if steps > config.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(r) = prop(&candidate) {
                        best = candidate;
                        best_reason = r;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case_idx}, seed {case_seed:#x}):\n  \
                 reason: {best_reason}\n  shrunk case: {best:?}\n  \
                 replay with NNT_PROPTEST_SEED={}",
                config.seed
            );
        }
    }
}

/// Convenience wrapper with default config and no shrinking.
pub fn check_simple<T: Clone + std::fmt::Debug>(
    name: &str,
    generate: impl FnMut(&mut Gen) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    check(name, &Config::default(), generate, |_| Vec::new(), prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_simple(
            "reverse-reverse-id",
            |g| {
                let n = g.sized_range(0, 50);
                (0..n).map(|_| g.rng.next_u32()).collect::<Vec<u32>>()
            },
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v { Ok(()) } else { Err("not identity".into()) }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check_simple("always-fails", |g| g.rng.next_u32() % 100, |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_reduces_case() {
        // Property: all vectors have length < 5. Shrinker halves the vector.
        // The reported failure should be length exactly 5 after shrinking.
        let res = std::panic::catch_unwind(|| {
            check(
                "len<5",
                &Config { cases: 50, seed: 1, max_shrink_steps: 500 },
                |g| {
                    let n = g.sized_range(0, 40);
                    vec![0u8; n]
                },
                |v| {
                    let mut outs = Vec::new();
                    if !v.is_empty() {
                        outs.push(v[..v.len() - 1].to_vec());
                        outs.push(v[..v.len() / 2].to_vec());
                    }
                    outs
                },
                |v| if v.len() < 5 { Ok(()) } else { Err(format!("len={}", v.len())) },
            );
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("len=5"), "should shrink to minimal failing len: {msg}");
    }

    #[test]
    fn sized_range_respects_bounds() {
        let mut g = Gen { rng: Xoshiro256::new(1), size: 0.5 };
        for _ in 0..100 {
            let v = g.sized_range(3, 10);
            assert!((3..=10).contains(&v));
        }
        // size=0 pins to lo
        let mut g0 = Gen { rng: Xoshiro256::new(2), size: 0.0 };
        for _ in 0..10 {
            assert_eq!(g0.sized_range(4, 9), 4);
        }
    }
}
