//! Deterministic concurrency model checker (loom/CHESS style).
//!
//! This module is the engine behind the `crate::util::sync` shim when the
//! crate is built with `--cfg nnt_model_check`. A *model run* executes a test
//! closure on real OS threads, but only **one** thread is ever allowed to run
//! at a time: every visible operation (lock acquire, condvar wait/notify,
//! atomic access, channel send/recv, spawn, join, yield) first passes through
//! a scheduling decision point where the executor picks which thread runs
//! next. Recording those decisions yields a *schedule*; depth-first search
//! over alternative decisions (with a context-switch/preemption bound)
//! explores the interleaving space exhaustively. A failing schedule is
//! reported as a compact seed string (`mc1:3.0.1...`) that [`replay`]
//! re-executes deterministically.
//!
//! Design notes and limitations:
//!
//! - The checker is dependency-free and lives in-crate; it is always
//!   compiled (so its own unit tests run under tier-1), but production code
//!   only routes through it under `cfg(nnt_model_check)` via the shim.
//! - Scheduling points are placed on *acquisition-like* operations. Releases
//!   (guard drops, channel disconnects) update state and unblock waiters but
//!   do not branch the search; this keeps the state space tractable while
//!   still exposing lock-order deadlocks, lost wakeups and ordering races.
//! - Timed waits (`wait_timeout`, `recv_timeout`) are modeled as an
//!   "eventually" abstraction: a timed-blocked thread only fires its timeout
//!   when **no** other thread can run. Protocols whose progress depends on
//!   real wall-clock deadlines will livelock the model (caught by the
//!   `max_steps` bound) — model tests should use deadlines that never need
//!   to fire.
//! - The test closure must be deterministic given a schedule: no real
//!   randomness and no decisions based on elapsed wall-clock time.
//!
//! On failure (panic or deadlock) the run *aborts*: every parked thread is
//! woken, unwinds with a private `AbortToken` panic payload, and is joined,
//! so no OS threads leak between iterations. During an abort the model
//! primitives degrade to plain (really-locked) operations so destructors can
//! run safely without scheduling.

use std::any::Any;
use std::cell::{RefCell, UnsafeCell};
use std::marker::PhantomData;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar as OsCondvar, Mutex as OsMutex, MutexGuard as OsMutexGuard, OnceLock};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Thread context
// ---------------------------------------------------------------------------

struct Ctx {
    exec: Arc<Executor>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// True when the calling thread is part of an active model run. The
/// `util::sync` shim consults this at primitive construction time: primitives
/// created outside a model run are std-backed even in model-check builds.
pub fn active() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

fn ctx() -> (Arc<Executor>, usize) {
    CTX.with(|c| {
        let b = c.borrow();
        let x = b
            .as_ref()
            .expect("model-check primitive used outside an active model run");
        (Arc::clone(&x.exec), x.tid)
    })
}

fn set_ctx(exec: &Arc<Executor>, tid: usize) {
    CTX.with(|c| {
        *c.borrow_mut() = Some(Ctx {
            exec: Arc::clone(exec),
            tid,
        });
    });
}

fn clear_ctx() {
    CTX.with(|c| *c.borrow_mut() = None);
}

/// Fetch the caller's tid, checking the primitive belongs to this run.
fn op_tid(exec: &Arc<Executor>) -> usize {
    let (cur, tid) = ctx();
    assert!(
        Arc::ptr_eq(&cur, exec),
        "model primitive used across model runs (leaked from an earlier iteration?)"
    );
    tid
}

// ---------------------------------------------------------------------------
// Abort plumbing
// ---------------------------------------------------------------------------

/// Internal marker: the run is aborting; the current operation must not block.
struct Abort;

/// Panic payload used to unwind model threads during an abort. Recognized by
/// the per-thread `catch_unwind` so it is not reported as a real failure.
struct AbortToken;

fn abort_unwind() -> ! {
    // resume_unwind (unlike panic_any) does not invoke the panic hook, so
    // aborted iterations do not spam stderr.
    panic::resume_unwind(Box::new(AbortToken))
}

fn payload_msg(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    Runnable,
    Blocked(usize),
    TimedBlocked(usize),
    Finished,
}

struct Slot {
    status: Status,
    /// Set when a timed wait was force-fired (the "timeout elapsed" signal).
    timed_out: bool,
    name: String,
    join_res: usize,
    result: Option<Box<dyn Any + Send>>,
}

/// One recorded scheduling decision.
#[derive(Clone, Debug)]
struct Step {
    chosen: usize,
    /// The candidate set at this decision (runnable tids, or timed-blocked
    /// tids for a timeout-fire step).
    enabled: Vec<usize>,
    /// True when this step force-fired a timed wait.
    timed: bool,
}

struct Exec {
    slots: Vec<Slot>,
    current: usize,
    schedule: Vec<Step>,
    forced: Vec<usize>,
    failure: Option<String>,
    aborting: bool,
    finished: usize,
    next_res: usize,
    max_steps: usize,
    os_handles: Vec<std::thread::JoinHandle<()>>,
}

struct Executor {
    m: OsMutex<Exec>,
    cv: OsCondvar,
}

impl Executor {
    fn new(forced: Vec<usize>, max_steps: usize) -> Self {
        let driver = Slot {
            status: Status::Runnable,
            timed_out: false,
            name: "main".to_string(),
            join_res: 0,
            result: None,
        };
        Executor {
            m: OsMutex::new(Exec {
                slots: vec![driver],
                current: 0,
                schedule: Vec::new(),
                forced,
                failure: None,
                aborting: false,
                finished: 0,
                next_res: 1,
                max_steps,
                os_handles: Vec::new(),
            }),
            cv: OsCondvar::new(),
        }
    }

    fn lock(&self) -> OsMutexGuard<'_, Exec> {
        self.m.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn new_res(&self) -> usize {
        let mut g = self.lock();
        let r = g.next_res;
        g.next_res += 1;
        r
    }

    fn fail(&self, g: &mut Exec, msg: String) {
        if g.failure.is_none() {
            g.failure = Some(msg);
        }
        g.aborting = true;
        self.cv.notify_all();
    }

    /// Pick the next thread to run. `me` is the deciding thread (it may or
    /// may not be runnable). Returns Err when the run is aborting.
    fn decide(&self, g: &mut Exec, me: usize) -> Result<(), Abort> {
        if g.aborting {
            return Err(Abort);
        }
        if g.schedule.len() >= g.max_steps {
            let max = g.max_steps;
            self.fail(
                g,
                format!("schedule exceeded {max} steps: livelock or time-dependent loop"),
            );
            return Err(Abort);
        }
        let enabled: Vec<usize> = g
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s.status, Status::Runnable))
            .map(|(i, _)| i)
            .collect();
        let forced_choice = g.forced.get(g.schedule.len()).copied();
        if !enabled.is_empty() {
            let chosen = match forced_choice {
                Some(w) if enabled.contains(&w) => w,
                Some(w) => {
                    let at = g.schedule.len();
                    self.fail(
                        g,
                        format!("replay divergence at step {at}: thread {w} not enabled"),
                    );
                    return Err(Abort);
                }
                // Default order: keep running the current thread if it can
                // continue (non-preemptive), else lowest enabled tid.
                None if enabled.contains(&me) => me,
                None => enabled[0],
            };
            g.schedule.push(Step {
                chosen,
                enabled,
                timed: false,
            });
            g.current = chosen;
            return Ok(());
        }
        // Nothing runnable: fire a timed wait if one exists, else deadlock.
        let timed: Vec<usize> = g
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s.status, Status::TimedBlocked(_)))
            .map(|(i, _)| i)
            .collect();
        if timed.is_empty() {
            let dump: Vec<String> = g
                .slots
                .iter()
                .enumerate()
                .map(|(i, s)| format!("  [{i}] {:?} {}", s.status, s.name))
                .collect();
            self.fail(
                g,
                format!("deadlock: no runnable threads\n{}", dump.join("\n")),
            );
            return Err(Abort);
        }
        let chosen = match forced_choice {
            Some(w) if timed.contains(&w) => w,
            Some(w) => {
                let at = g.schedule.len();
                self.fail(
                    g,
                    format!("replay divergence at timed step {at}: thread {w} not timed-blocked"),
                );
                return Err(Abort);
            }
            None => timed[0],
        };
        g.slots[chosen].status = Status::Runnable;
        g.slots[chosen].timed_out = true;
        g.schedule.push(Step {
            chosen,
            enabled: timed,
            timed: true,
        });
        g.current = chosen;
        Ok(())
    }

    /// Park until the scheduler hands `me` the token. Returns the (and
    /// clears) the thread's `timed_out` flag.
    fn wait_my_turn(&self, mut g: OsMutexGuard<'_, Exec>, me: usize) -> Result<bool, Abort> {
        self.cv.notify_all();
        loop {
            if g.aborting {
                return Err(Abort);
            }
            if g.current == me && matches!(g.slots[me].status, Status::Runnable) {
                let t = g.slots[me].timed_out;
                g.slots[me].timed_out = false;
                return Ok(t);
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// A scheduling decision point for a runnable thread.
    fn op_point(&self, me: usize) -> Result<(), Abort> {
        let mut g = self.lock();
        if g.aborting {
            return Err(Abort);
        }
        self.decide(&mut g, me)?;
        self.wait_my_turn(g, me).map(|_| ())
    }

    /// Deterministic round-robin switch: recorded as a single-alternative
    /// step, so spin loops that yield do not branch the DFS.
    fn yield_point(&self, me: usize) -> Result<(), Abort> {
        let mut g = self.lock();
        if g.aborting {
            return Err(Abort);
        }
        if g.schedule.len() >= g.max_steps {
            let max = g.max_steps;
            self.fail(
                &mut g,
                format!("schedule exceeded {max} steps in a yield loop: livelock"),
            );
            return Err(Abort);
        }
        let n = g.slots.len();
        let mut next = me;
        for k in 1..=n {
            let c = (me + k) % n;
            if matches!(g.slots[c].status, Status::Runnable) {
                next = c;
                break;
            }
        }
        g.schedule.push(Step {
            chosen: next,
            enabled: vec![next],
            timed: false,
        });
        g.current = next;
        self.wait_my_turn(g, me).map(|_| ())
    }

    /// Mark `me` blocked on `res` (no scheduling yet).
    fn block_prepare(&self, me: usize, res: usize, timed: bool) {
        let mut g = self.lock();
        g.slots[me].status = if timed {
            Status::TimedBlocked(res)
        } else {
            Status::Blocked(res)
        };
        g.slots[me].timed_out = false;
    }

    /// Hand the token away and park until unblocked *and* scheduled.
    /// Returns true if the wait was force-fired as a timeout.
    fn block_commit(&self, me: usize) -> Result<bool, Abort> {
        let mut g = self.lock();
        if g.aborting {
            return Err(Abort);
        }
        self.decide(&mut g, me)?;
        self.wait_my_turn(g, me)
    }

    fn block_on(&self, me: usize, res: usize, timed: bool) -> Result<bool, Abort> {
        self.block_prepare(me, res, timed);
        self.block_commit(me)
    }

    fn unblock_in(g: &mut Exec, res: usize, max: usize) {
        let mut n = 0;
        for s in g.slots.iter_mut() {
            let hit = matches!(s.status, Status::Blocked(r) | Status::TimedBlocked(r) if r == res);
            if hit {
                s.status = Status::Runnable;
                s.timed_out = false;
                n += 1;
                if n == max {
                    break;
                }
            }
        }
    }

    fn unblock_all(&self, res: usize) {
        let mut g = self.lock();
        Self::unblock_in(&mut g, res, usize::MAX);
    }

    fn unblock_one(&self, res: usize) {
        let mut g = self.lock();
        Self::unblock_in(&mut g, res, 1);
    }

    /// First scheduling of a freshly spawned thread.
    fn wait_first(&self, me: usize) -> Result<(), Abort> {
        let mut g = self.lock();
        loop {
            if g.aborting {
                return Err(Abort);
            }
            if g.current == me && matches!(g.slots[me].status, Status::Runnable) {
                return Ok(());
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn finish(&self, me: usize, result: Option<Box<dyn Any + Send>>) {
        let mut g = self.lock();
        g.slots[me].status = Status::Finished;
        g.slots[me].result = result;
        g.finished += 1;
        let jr = g.slots[me].join_res;
        Self::unblock_in(&mut g, jr, usize::MAX);
        if !g.aborting && g.finished < g.slots.len() {
            // Hand the token to someone else; Err means the failure (e.g.
            // deadlock among the survivors) is already recorded.
            let _ = self.decide(&mut g, me);
        }
        self.cv.notify_all();
    }

    fn on_panic(&self, me: usize, payload: Box<dyn Any + Send>) {
        let is_abort = payload.downcast_ref::<AbortToken>().is_some();
        let mut g = self.lock();
        if !is_abort && g.failure.is_none() {
            let name = g.slots[me].name.clone();
            g.failure = Some(format!(
                "thread '{name}' panicked: {}",
                payload_msg(payload.as_ref())
            ));
        }
        g.aborting = true;
        g.slots[me].status = Status::Finished;
        g.finished += 1;
        let jr = g.slots[me].join_res;
        Self::unblock_in(&mut g, jr, usize::MAX);
        self.cv.notify_all();
    }

    fn is_finished(&self, tid: usize) -> bool {
        matches!(self.lock().slots[tid].status, Status::Finished)
    }

    fn take_result(&self, tid: usize) -> Option<Box<dyn Any + Send>> {
        self.lock().slots[tid].result.take()
    }

    /// Abort-mode join: wait on the OS condvar (no scheduling) until the
    /// target finishes. Safe to call from destructors.
    fn wait_finished_os(&self, tid: usize) {
        let mut g = self.lock();
        while !matches!(g.slots[tid].status, Status::Finished) {
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Driver: wait for every registered thread (including the driver slot)
    /// to finish.
    fn wait_all(&self) {
        let mut g = self.lock();
        while g.finished < g.slots.len() {
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

// ---------------------------------------------------------------------------
// Model primitives: Mutex / Condvar / RwLock
// ---------------------------------------------------------------------------

/// Model-checked mutex. Acquisitions are scheduling points; during an abort
/// it degrades to a plain spin lock so destructors stay safe.
pub struct Mutex<T> {
    exec: Arc<Executor>,
    res: usize,
    locked: OsMutex<bool>,
    data: UnsafeCell<T>,
}

// SAFETY: the `locked` flag (a real OsMutex) guarantees at most one guard
// exists at a time, so `data` is only ever accessed exclusively; `T: Send`
// lets that exclusive access hop between threads, mirroring std's bounds.
unsafe impl<T: Send> Send for Mutex<T> {}
// SAFETY: as above — all shared access to `data` is mediated by the guard.
unsafe impl<T: Send> Sync for Mutex<T> {}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        let (exec, _) = ctx();
        let res = exec.new_res();
        Mutex {
            exec,
            res,
            locked: OsMutex::new(false),
            data: UnsafeCell::new(value),
        }
    }

    pub fn into_inner(self) -> T {
        self.data.into_inner()
    }

    fn try_acquire_real(&self) -> bool {
        let mut l = self.locked.lock().unwrap_or_else(|e| e.into_inner());
        if *l {
            false
        } else {
            *l = true;
            true
        }
    }

    fn acquire_abort(&self) -> MutexGuard<'_, T> {
        // The run is aborting: no scheduler discipline, threads really run
        // concurrently while unwinding. Spin on the real flag.
        loop {
            if self.try_acquire_real() {
                return MutexGuard { m: self };
            }
            std::thread::yield_now();
        }
    }

    pub fn lock(&self) -> MutexGuard<'_, T> {
        let tid = op_tid(&self.exec);
        loop {
            if self.exec.op_point(tid).is_err() {
                return self.acquire_abort();
            }
            if self.try_acquire_real() {
                return MutexGuard { m: self };
            }
            if self.exec.block_on(tid, self.res, false).is_err() {
                return self.acquire_abort();
            }
        }
    }

    fn release(&self) {
        let mut l = self.locked.lock().unwrap_or_else(|e| e.into_inner());
        *l = false;
        drop(l);
        self.exec.unblock_all(self.res);
    }
}

pub struct MutexGuard<'a, T> {
    m: &'a Mutex<T>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: the guard holds the (model) lock exclusively.
        unsafe { &*self.m.data.get() }
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: the guard holds the (model) lock exclusively.
        unsafe { &mut *self.m.data.get() }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        self.m.release();
    }
}

/// Model-checked condvar. `wait` releases the paired model mutex, parks on
/// the condvar's resource, and reacquires on wakeup.
pub struct Condvar {
    exec: Arc<Executor>,
    res: usize,
}

impl Condvar {
    pub fn new() -> Self {
        let (exec, _) = ctx();
        let res = exec.new_res();
        Condvar { exec, res }
    }

    fn wait_inner<'a, T>(&self, guard: MutexGuard<'a, T>, timed: bool) -> (MutexGuard<'a, T>, bool) {
        let tid = op_tid(&self.exec);
        let m = guard.m;
        // Atomically (w.r.t. the model: no scheduling point in between):
        // register as blocked, then release the mutex.
        self.exec.block_prepare(tid, self.res, timed);
        std::mem::forget(guard);
        m.release();
        match self.exec.block_commit(tid) {
            Err(Abort) => abort_unwind(),
            Ok(timed_out) => {
                let g = m.lock();
                (g, timed_out)
            }
        }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.wait_inner(guard, false).0
    }

    /// Returns `(guard, timed_out)`. The timeout only "fires" when no other
    /// thread is runnable (see module docs).
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        _dur: Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        self.wait_inner(guard, true)
    }

    pub fn notify_one(&self) {
        let tid = op_tid(&self.exec);
        // Soft point: on abort, still deliver the wakeup (drop-safe).
        let _ = self.exec.op_point(tid);
        self.exec.unblock_one(self.res);
    }

    pub fn notify_all(&self) {
        let tid = op_tid(&self.exec);
        let _ = self.exec.op_point(tid);
        self.exec.unblock_all(self.res);
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

struct RwState {
    writer: bool,
    readers: usize,
}

/// Model-checked RwLock (no writer preference; acquisitions are scheduling
/// points, releases unblock everyone waiting).
pub struct RwLock<T> {
    exec: Arc<Executor>,
    res: usize,
    st: OsMutex<RwState>,
    data: UnsafeCell<T>,
}

// SAFETY: `st` serializes state transitions; a write guard is exclusive and
// read guards are shared read-only, mirroring std's `T: Send` requirement.
unsafe impl<T: Send> Send for RwLock<T> {}
// SAFETY: read guards hand out `&T` from multiple threads (needs `T: Sync`);
// write guards are exclusive (needs `T: Send`). Same bounds as std.
unsafe impl<T: Send + Sync> Sync for RwLock<T> {}

impl<T> RwLock<T> {
    pub fn new(value: T) -> Self {
        let (exec, _) = ctx();
        let res = exec.new_res();
        RwLock {
            exec,
            res,
            st: OsMutex::new(RwState {
                writer: false,
                readers: 0,
            }),
            data: UnsafeCell::new(value),
        }
    }

    fn try_read_real(&self) -> bool {
        let mut s = self.st.lock().unwrap_or_else(|e| e.into_inner());
        if s.writer {
            false
        } else {
            s.readers += 1;
            true
        }
    }

    fn try_write_real(&self) -> bool {
        let mut s = self.st.lock().unwrap_or_else(|e| e.into_inner());
        if s.writer || s.readers > 0 {
            false
        } else {
            s.writer = true;
            true
        }
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        let tid = op_tid(&self.exec);
        loop {
            if self.exec.op_point(tid).is_err() {
                return self.read_abort();
            }
            if self.try_read_real() {
                return RwLockReadGuard { l: self };
            }
            if self.exec.block_on(tid, self.res, false).is_err() {
                return self.read_abort();
            }
        }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        let tid = op_tid(&self.exec);
        loop {
            if self.exec.op_point(tid).is_err() {
                return self.write_abort();
            }
            if self.try_write_real() {
                return RwLockWriteGuard { l: self };
            }
            if self.exec.block_on(tid, self.res, false).is_err() {
                return self.write_abort();
            }
        }
    }

    fn read_abort(&self) -> RwLockReadGuard<'_, T> {
        loop {
            if self.try_read_real() {
                return RwLockReadGuard { l: self };
            }
            std::thread::yield_now();
        }
    }

    fn write_abort(&self) -> RwLockWriteGuard<'_, T> {
        loop {
            if self.try_write_real() {
                return RwLockWriteGuard { l: self };
            }
            std::thread::yield_now();
        }
    }

    fn release_read(&self) {
        let mut s = self.st.lock().unwrap_or_else(|e| e.into_inner());
        s.readers -= 1;
        drop(s);
        self.exec.unblock_all(self.res);
    }

    fn release_write(&self) {
        let mut s = self.st.lock().unwrap_or_else(|e| e.into_inner());
        s.writer = false;
        drop(s);
        self.exec.unblock_all(self.res);
    }
}

pub struct RwLockReadGuard<'a, T> {
    l: &'a RwLock<T>,
}

impl<T> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: read guard held — no writer can exist.
        unsafe { &*self.l.data.get() }
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        self.l.release_read();
    }
}

pub struct RwLockWriteGuard<'a, T> {
    l: &'a RwLock<T>,
}

impl<T> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: write guard held — exclusive access.
        unsafe { &*self.l.data.get() }
    }
}

impl<T> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: write guard held — exclusive access.
        unsafe { &mut *self.l.data.get() }
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        self.l.release_write();
    }
}

// ---------------------------------------------------------------------------
// Model atomics (sequentially consistent; every access is a soft point)
// ---------------------------------------------------------------------------

macro_rules! model_atomic {
    ($name:ident, $ty:ty) => {
        pub struct $name {
            exec: Arc<Executor>,
            st: OsMutex<$ty>,
        }

        impl $name {
            pub fn new(v: $ty) -> Self {
                let (exec, _) = ctx();
                $name {
                    exec,
                    st: OsMutex::new(v),
                }
            }

            /// Soft scheduling point: during an abort the access still
            /// happens (destructor paths touch atomics) without scheduling.
            fn point(&self) {
                let tid = op_tid(&self.exec);
                let _ = self.exec.op_point(tid);
            }

            pub fn load(&self) -> $ty {
                self.point();
                *self.st.lock().unwrap_or_else(|e| e.into_inner())
            }

            pub fn store(&self, v: $ty) {
                self.point();
                *self.st.lock().unwrap_or_else(|e| e.into_inner()) = v;
            }

            pub fn swap(&self, v: $ty) -> $ty {
                self.point();
                let mut g = self.st.lock().unwrap_or_else(|e| e.into_inner());
                std::mem::replace(&mut *g, v)
            }
        }
    };
}

model_atomic!(AtomicBool, bool);
model_atomic!(AtomicUsize, usize);

impl AtomicUsize {
    pub fn fetch_add(&self, v: usize) -> usize {
        self.point();
        let mut g = self.st.lock().unwrap_or_else(|e| e.into_inner());
        let old = *g;
        *g = old.wrapping_add(v);
        old
    }

    pub fn fetch_sub(&self, v: usize) -> usize {
        self.point();
        let mut g = self.st.lock().unwrap_or_else(|e| e.into_inner());
        let old = *g;
        *g = old.wrapping_sub(v);
        old
    }
}

// ---------------------------------------------------------------------------
// Model mpsc channel
// ---------------------------------------------------------------------------

pub mod mpsc {
    //! Cooperative multi-producer single-consumer channel with std's
    //! disconnect semantics, schedulable by the model executor.

    use super::{abort_unwind, ctx, op_tid, Abort, Executor, OsMutex};
    use std::collections::VecDeque;
    use std::sync::mpsc::{RecvError, RecvTimeoutError, SendError, TryRecvError};
    use std::sync::Arc;
    use std::time::Duration;

    struct ChanState<T> {
        queue: VecDeque<T>,
        senders: usize,
        rx_alive: bool,
    }

    struct Chan<T> {
        exec: Arc<Executor>,
        res: usize,
        st: OsMutex<ChanState<T>>,
    }

    pub struct Sender<T> {
        ch: Arc<Chan<T>>,
    }

    pub struct Receiver<T> {
        ch: Arc<Chan<T>>,
    }

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let (exec, _) = ctx();
        let res = exec.new_res();
        let ch = Arc::new(Chan {
            exec,
            res,
            st: OsMutex::new(ChanState {
                queue: VecDeque::new(),
                senders: 1,
                rx_alive: true,
            }),
        });
        (
            Sender {
                ch: Arc::clone(&ch),
            },
            Receiver { ch },
        )
    }

    impl<T> Chan<T> {
        fn st(&self) -> std::sync::MutexGuard<'_, ChanState<T>> {
            self.st.lock().unwrap_or_else(|e| e.into_inner())
        }
    }

    impl<T> Sender<T> {
        pub fn send(&self, value: T) -> Result<(), SendError<T>> {
            let tid = op_tid(&self.ch.exec);
            // Soft point: a send from an unwinding frame still lands.
            let _ = self.ch.exec.op_point(tid);
            let mut s = self.ch.st();
            if !s.rx_alive {
                return Err(SendError(value));
            }
            s.queue.push_back(value);
            drop(s);
            self.ch.exec.unblock_all(self.ch.res);
            Ok(())
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            self.ch.st().senders += 1;
            Sender {
                ch: Arc::clone(&self.ch),
            }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            let mut s = self.ch.st();
            s.senders -= 1;
            let disconnected = s.senders == 0;
            drop(s);
            if disconnected {
                self.ch.exec.unblock_all(self.ch.res);
            }
        }
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            let tid = op_tid(&self.ch.exec);
            loop {
                if self.ch.exec.op_point(tid).is_err() {
                    abort_unwind()
                }
                {
                    let mut s = self.ch.st();
                    if let Some(v) = s.queue.pop_front() {
                        return Ok(v);
                    }
                    if s.senders == 0 {
                        return Err(RecvError);
                    }
                }
                match self.ch.exec.block_on(tid, self.ch.res, false) {
                    Err(Abort) => abort_unwind(),
                    Ok(_) => {}
                }
            }
        }

        pub fn recv_timeout(&self, _dur: Duration) -> Result<T, RecvTimeoutError> {
            let tid = op_tid(&self.ch.exec);
            loop {
                if self.ch.exec.op_point(tid).is_err() {
                    abort_unwind()
                }
                {
                    let mut s = self.ch.st();
                    if let Some(v) = s.queue.pop_front() {
                        return Ok(v);
                    }
                    if s.senders == 0 {
                        return Err(RecvTimeoutError::Disconnected);
                    }
                }
                match self.ch.exec.block_on(tid, self.ch.res, true) {
                    Err(Abort) => abort_unwind(),
                    Ok(timed_out) => {
                        if timed_out {
                            let mut s = self.ch.st();
                            if let Some(v) = s.queue.pop_front() {
                                return Ok(v);
                            }
                            return Err(RecvTimeoutError::Timeout);
                        }
                    }
                }
            }
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            let tid = op_tid(&self.ch.exec);
            if self.ch.exec.op_point(tid).is_err() {
                abort_unwind()
            }
            let mut s = self.ch.st();
            if let Some(v) = s.queue.pop_front() {
                return Ok(v);
            }
            if s.senders == 0 {
                Err(TryRecvError::Disconnected)
            } else {
                Err(TryRecvError::Empty)
            }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            self.ch.st().rx_alive = false;
            self.ch.exec.unblock_all(self.ch.res);
        }
    }
}

// ---------------------------------------------------------------------------
// Model threads
// ---------------------------------------------------------------------------

pub struct JoinHandle<T> {
    exec: Arc<Executor>,
    tid: usize,
    join_res: usize,
    _marker: PhantomData<fn() -> T>,
}

impl<T: 'static> JoinHandle<T> {
    pub fn is_finished(&self) -> bool {
        self.exec.is_finished(self.tid)
    }

    pub fn join(self) -> std::thread::Result<T> {
        let tid = op_tid(&self.exec);
        loop {
            match self.exec.op_point(tid) {
                Err(Abort) => {
                    // Abort-mode: wait for the target on the raw condvar so
                    // destructor-driven joins cannot panic or hang.
                    self.exec.wait_finished_os(self.tid);
                    break;
                }
                Ok(()) => {}
            }
            if self.exec.is_finished(self.tid) {
                break;
            }
            match self.exec.block_on(tid, self.join_res, false) {
                Err(Abort) => {
                    self.exec.wait_finished_os(self.tid);
                    break;
                }
                Ok(_) => {}
            }
        }
        match self.exec.take_result(self.tid) {
            Some(b) => Ok(*b
                .downcast::<T>()
                .expect("model join: result type mismatch")),
            None => Err(Box::new(AbortToken) as Box<dyn Any + Send>),
        }
    }
}

pub fn spawn<T, F>(name: String, f: F) -> JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (exec, me) = ctx();
    if exec.op_point(me).is_err() {
        abort_unwind()
    }
    let (tid, join_res) = {
        let mut g = exec.lock();
        let join_res = g.next_res;
        g.next_res += 1;
        let tid = g.slots.len();
        g.slots.push(Slot {
            status: Status::Runnable,
            timed_out: false,
            name: name.clone(),
            join_res,
            result: None,
        });
        (tid, join_res)
    };
    let exec2 = Arc::clone(&exec);
    let os = std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            set_ctx(&exec2, tid);
            if exec2.wait_first(tid).is_ok() {
                let r = panic::catch_unwind(AssertUnwindSafe(|| {
                    Box::new(f()) as Box<dyn Any + Send>
                }));
                match r {
                    Ok(v) => exec2.finish(tid, Some(v)),
                    Err(p) => exec2.on_panic(tid, p),
                }
            } else {
                // Aborted before first scheduling: drop the closure's
                // captures with ctx set, then finish quietly.
                drop(f);
                exec2.finish(tid, None);
            }
            clear_ctx();
        })
        .expect("failed to spawn model OS thread");
    exec.lock().os_handles.push(os);
    JoinHandle {
        exec,
        tid,
        join_res,
        _marker: PhantomData,
    }
}

/// Cooperative yield: deterministic round-robin, does not branch the DFS.
pub fn yield_now() {
    let (exec, tid) = ctx();
    if exec.yield_point(tid).is_err() {
        abort_unwind()
    }
}

// ---------------------------------------------------------------------------
// Check driver: DFS with preemption bounding + seeded replay
// ---------------------------------------------------------------------------

/// Exploration bounds for [`check`].
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Max preemptive context switches per schedule (CHESS-style bound).
    pub max_preemptions: usize,
    /// Give up (Pass with `complete: false`) after this many schedules.
    pub max_iterations: usize,
    /// Per-run step bound; exceeding it is reported as a livelock failure.
    pub max_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            max_preemptions: 2,
            max_iterations: 200_000,
            max_steps: 20_000,
        }
    }
}

/// Result of a model-check exploration.
#[derive(Debug)]
pub enum Outcome {
    Pass {
        iterations: usize,
        /// True when the bounded schedule space was fully explored.
        complete: bool,
    },
    Fail {
        /// Replayable schedule seed ("mc1:...").
        seed: String,
        message: String,
        iterations: usize,
    },
}

impl Outcome {
    /// Panic (with the replay seed) unless the exploration passed.
    pub fn assert_pass(&self, what: &str) {
        match self {
            Outcome::Pass { .. } => {}
            Outcome::Fail {
                seed,
                message,
                iterations,
            } => panic!(
                "model '{what}' failed after {iterations} schedules: {message}\nreplay seed: {seed}"
            ),
        }
    }

    /// Panic unless the exploration passed *and* was exhaustive.
    pub fn assert_complete(&self, what: &str) {
        self.assert_pass(what);
        if let Outcome::Pass {
            complete: false,
            iterations,
        } = self
        {
            panic!("model '{what}' hit the iteration bound ({iterations}) before exhausting the schedule space");
        }
    }

    /// Extract the counterexample, panicking if the model unexpectedly passed.
    pub fn expect_fail(&self, what: &str) -> (String, String) {
        match self {
            Outcome::Fail { seed, message, .. } => (seed.clone(), message.clone()),
            Outcome::Pass { iterations, .. } => panic!(
                "model '{what}' unexpectedly passed ({iterations} schedules) — the fixture is supposed to be buggy"
            ),
        }
    }
}

fn encode_seed(schedule: &[Step]) -> String {
    let mut s = String::from("mc1:");
    for (i, st) in schedule.iter().enumerate() {
        if i > 0 {
            s.push('.');
        }
        s.push_str(&st.chosen.to_string());
    }
    s
}

/// Parse an "mc1:" seed back into a forced-choice list.
pub fn decode_seed(seed: &str) -> Option<Vec<usize>> {
    let rest = seed.strip_prefix("mc1:")?;
    if rest.is_empty() {
        return Some(Vec::new());
    }
    rest.split('.').map(|t| t.parse::<usize>().ok()).collect()
}

fn prev_runner(schedule: &[Step], i: usize) -> usize {
    if i == 0 {
        0
    } else {
        schedule[i - 1].chosen
    }
}

/// A switch is preemptive when the previously running thread could have
/// continued but a different thread was chosen.
fn is_preemptive(schedule: &[Step], i: usize, cand: usize) -> bool {
    let p = prev_runner(schedule, i);
    !schedule[i].timed && schedule[i].enabled.contains(&p) && cand != p
}

fn admissible(schedule: &[Step], i: usize, max_preemptions: usize) -> Vec<usize> {
    let s = &schedule[i];
    if s.enabled.len() == 1 {
        return vec![s.chosen];
    }
    let budget_used = (0..i)
        .filter(|&j| is_preemptive(schedule, j, schedule[j].chosen))
        .count();
    let mut alts = vec![s.chosen];
    for &t in &s.enabled {
        if t == s.chosen {
            continue;
        }
        if !is_preemptive(schedule, i, t) || budget_used < max_preemptions {
            alts.push(t);
        }
    }
    alts
}

struct Node {
    alts: Vec<usize>,
    idx: usize,
}

fn gate() -> &'static OsMutex<()> {
    static GATE: OnceLock<OsMutex<()>> = OnceLock::new();
    GATE.get_or_init(|| OsMutex::new(()))
}

fn run_once(forced: &[usize], max_steps: usize, f: &dyn Fn()) -> (Vec<Step>, Option<String>) {
    let exec = Arc::new(Executor::new(forced.to_vec(), max_steps));
    set_ctx(&exec, 0);
    let r = panic::catch_unwind(AssertUnwindSafe(|| f()));
    match r {
        Ok(()) => exec.finish(0, None),
        Err(p) => exec.on_panic(0, p),
    }
    exec.wait_all();
    clear_ctx();
    let handles = std::mem::take(&mut exec.lock().os_handles);
    for h in handles {
        let _ = h.join();
    }
    let mut g = exec.lock();
    (std::mem::take(&mut g.schedule), g.failure.take())
}

/// Exhaustively explore interleavings of `f` (up to the preemption bound).
///
/// `f` is run once per schedule; it must create all its threads and sync
/// primitives through the model (via the `util::sync` shim under
/// `cfg(nnt_model_check)`, or the `mc` types directly) and must not leak
/// primitives across iterations.
pub fn check<F: Fn()>(cfg: Config, f: F) -> Outcome {
    let _gate = gate().lock().unwrap_or_else(|e| e.into_inner());
    assert!(!active(), "nested model check is not supported");
    let mut stack: Vec<Node> = Vec::new();
    let mut forced: Vec<usize> = Vec::new();
    let mut iterations = 0usize;
    loop {
        iterations += 1;
        let (schedule, failure) = run_once(&forced, cfg.max_steps, &f);
        if let Some(message) = failure {
            return Outcome::Fail {
                seed: encode_seed(&schedule),
                message,
                iterations,
            };
        }
        if iterations >= cfg.max_iterations {
            return Outcome::Pass {
                iterations,
                complete: false,
            };
        }
        for i in stack.len()..schedule.len() {
            stack.push(Node {
                alts: admissible(&schedule, i, cfg.max_preemptions),
                idx: 0,
            });
        }
        loop {
            match stack.last_mut() {
                None => {
                    return Outcome::Pass {
                        iterations,
                        complete: true,
                    }
                }
                Some(n) if n.idx + 1 < n.alts.len() => {
                    n.idx += 1;
                    break;
                }
                Some(_) => {
                    stack.pop();
                }
            }
        }
        forced = stack.iter().map(|n| n.alts[n.idx]).collect();
    }
}

/// Deterministically re-run a single schedule from a seed produced by a
/// failing [`check`]. Returns the outcome of that one run.
pub fn replay<F: Fn()>(seed: &str, f: F) -> Outcome {
    let _gate = gate().lock().unwrap_or_else(|e| e.into_inner());
    assert!(!active(), "nested model check is not supported");
    let forced = decode_seed(seed).expect("malformed model-check seed");
    let (schedule, failure) = run_once(&forced, Config::default().max_steps, &f);
    match failure {
        Some(message) => Outcome::Fail {
            seed: encode_seed(&schedule),
            message,
            iterations: 1,
        },
        None => Outcome::Pass {
            iterations: 1,
            complete: false,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_preemptions: usize) -> Config {
        Config {
            max_preemptions,
            ..Config::default()
        }
    }

    /// A correct mutex-protected counter passes exhaustively.
    #[test]
    fn mutex_counter_passes() {
        let out = check(cfg(2), || {
            let m = Arc::new(Mutex::new(0u32));
            let hs: Vec<_> = (0..2)
                .map(|i| {
                    let m = Arc::clone(&m);
                    spawn(format!("inc{i}"), move || {
                        let mut g = m.lock();
                        *g += 1;
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(*m.lock(), 2);
        });
        out.assert_complete("mutex counter");
        if let Outcome::Pass { iterations, .. } = out {
            assert!(iterations > 1, "expected more than one interleaving");
        }
    }

    /// A racy read-modify-write on a model atomic is caught.
    #[test]
    fn racy_increment_fails() {
        let out = check(cfg(2), || {
            let a = Arc::new(AtomicUsize::new(0));
            let hs: Vec<_> = (0..2)
                .map(|i| {
                    let a = Arc::clone(&a);
                    spawn(format!("racy{i}"), move || {
                        let v = a.load();
                        a.store(v + 1);
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(a.load(), 2, "lost update");
        });
        let (seed, msg) = out.expect_fail("racy increment");
        assert!(msg.contains("lost update"), "unexpected message: {msg}");
        assert!(seed.starts_with("mc1:"), "bad seed: {seed}");
    }

    /// The classic lost-wakeup bug: flag outside the mutex + `if` instead of
    /// `while` around the condvar wait. The model finds the deadlock.
    fn lost_wakeup_fixture() {
        let m = Arc::new(Mutex::new(()));
        let cv = Arc::new(Condvar::new());
        let flag = Arc::new(AtomicBool::new(false));
        let waiter = {
            let (m, cv, flag) = (Arc::clone(&m), Arc::clone(&cv), Arc::clone(&flag));
            spawn("waiter".to_string(), move || {
                let g = m.lock();
                if !flag.load() {
                    let _g = cv.wait(g);
                }
            })
        };
        let setter = {
            let (cv, flag) = (Arc::clone(&cv), Arc::clone(&flag));
            spawn("setter".to_string(), move || {
                flag.store(true);
                cv.notify_all();
            })
        };
        setter.join().unwrap();
        waiter.join().unwrap();
    }

    #[test]
    fn lost_wakeup_found_and_replays_deterministically() {
        let out = check(cfg(2), lost_wakeup_fixture);
        let (seed, msg) = out.expect_fail("lost wakeup");
        assert!(msg.contains("deadlock"), "expected a deadlock, got: {msg}");

        // The seed must reproduce the identical failure, twice.
        for round in 0..2 {
            let r = replay(&seed, lost_wakeup_fixture);
            let (seed2, msg2) = r.expect_fail("lost wakeup replay");
            assert_eq!(seed2, seed, "replay diverged on round {round}");
            assert_eq!(msg2, msg, "replay failure differs on round {round}");
        }
    }

    /// The fixed version (check under the lock, `while` loop) passes.
    #[test]
    fn correct_wakeup_passes() {
        let out = check(cfg(2), || {
            let m = Arc::new(Mutex::new(false));
            let cv = Arc::new(Condvar::new());
            let waiter = {
                let (m, cv) = (Arc::clone(&m), Arc::clone(&cv));
                spawn("waiter".to_string(), move || {
                    let mut g = m.lock();
                    while !*g {
                        g = cv.wait(g);
                    }
                })
            };
            let setter = {
                let (m, cv) = (Arc::clone(&m), Arc::clone(&cv));
                spawn("setter".to_string(), move || {
                    *m.lock() = true;
                    cv.notify_all();
                })
            };
            setter.join().unwrap();
            waiter.join().unwrap();
        });
        out.assert_complete("correct wakeup");
    }

    /// Channel send/recv with disconnect semantics under the model.
    #[test]
    fn channel_disconnect_passes() {
        let out = check(cfg(2), || {
            let (tx, rx) = mpsc::channel::<u32>();
            let tx2 = tx.clone();
            let p1 = spawn("p1".to_string(), move || {
                tx.send(1).unwrap();
            });
            let p2 = spawn("p2".to_string(), move || {
                tx2.send(2).unwrap();
            });
            let mut got = vec![rx.recv().unwrap(), rx.recv().unwrap()];
            got.sort_unstable();
            assert_eq!(got, vec![1, 2]);
            p1.join().unwrap();
            p2.join().unwrap();
            assert!(rx.recv().is_err(), "all senders gone: recv must error");
        });
        out.assert_complete("channel disconnect");
    }

    /// RwLock: concurrent readers plus a writer keep the invariant.
    #[test]
    fn rwlock_passes() {
        let out = check(cfg(1), || {
            let l = Arc::new(RwLock::new((0u32, 0u32)));
            let w = {
                let l = Arc::clone(&l);
                spawn("writer".to_string(), move || {
                    let mut g = l.write();
                    g.0 += 1;
                    g.1 += 1;
                })
            };
            let r = {
                let l = Arc::clone(&l);
                spawn("reader".to_string(), move || {
                    let g = l.read();
                    assert_eq!(g.0, g.1, "reader saw a torn write");
                })
            };
            w.join().unwrap();
            r.join().unwrap();
        });
        out.assert_complete("rwlock invariant");
    }

    #[test]
    fn seed_roundtrip() {
        assert_eq!(decode_seed("mc1:"), Some(vec![]));
        assert_eq!(decode_seed("mc1:3.0.12"), Some(vec![3, 0, 12]));
        assert_eq!(decode_seed("bogus"), None);
        assert_eq!(decode_seed("mc1:x"), None);
    }
}
