//! Dependency-free CDCL SAT solver (MiniSat-style; crates.io is unavailable
//! offline, see `rust/DESIGN.md` §3).
//!
//! Implements the classic architecture: two-watched-literal unit propagation,
//! first-UIP conflict analysis with clause learning, exponential-decay
//! variable activity, phase saving, and Luby restarts. The instances produced
//! by [`crate::logic::cec`] — miters of structurally similar netlists with
//! fanin-bounded cones — are easy for CDCL, so the solver favours clarity
//! over throughput: no clause deletion, no literal-block-distance tracking,
//! and an O(vars) linear scan for decisions.

use std::ops::Not;

/// Variable index (0-based, dense).
pub type Var = u32;

/// A literal: a variable plus polarity, packed as `var << 1 | negated`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Lit(u32);

impl Lit {
    /// The positive literal of `v`.
    pub fn pos(v: Var) -> Lit {
        Lit(v << 1)
    }

    /// The negative literal of `v`.
    pub fn neg(v: Var) -> Lit {
        Lit(v << 1 | 1)
    }

    /// The variable this literal tests.
    pub fn var(self) -> Var {
        self.0 >> 1
    }

    /// True for `¬v` literals.
    pub fn is_neg(self) -> bool {
        self.0 & 1 == 1
    }

    fn index(self) -> usize {
        self.0 as usize
    }
}

impl Not for Lit {
    type Output = Lit;
    fn not(self) -> Lit {
        Lit(self.0 ^ 1)
    }
}

/// Outcome of [`Solver::solve`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SatResult {
    /// Satisfiable; the witness assigns every variable, indexed by [`Var`].
    Sat(Vec<bool>),
    /// Proven unsatisfiable.
    Unsat,
}

/// Sentinel for "assigned by decision, not propagation".
const NO_REASON: u32 = u32::MAX;

/// One-shot CDCL solver: create, [`Solver::new_var`] /
/// [`Solver::add_clause`] the formula, then [`Solver::solve`].
pub struct Solver {
    /// Problem + learned clauses. Watched literals sit in slots 0 and 1.
    clauses: Vec<Vec<Lit>>,
    /// Per literal index: ids of clauses currently watching that literal.
    watches: Vec<Vec<u32>>,
    /// Per var: 1 = true, -1 = false, 0 = unassigned.
    assign: Vec<i8>,
    /// Last polarity each var was assigned (phase saving).
    phase: Vec<bool>,
    /// Decision level at which each var was assigned.
    level: Vec<u32>,
    /// Clause id that propagated each var, or [`NO_REASON`].
    reason: Vec<u32>,
    /// VSIDS-style activity, bumped on conflict participation.
    activity: Vec<f64>,
    var_inc: f64,
    trail: Vec<Lit>,
    trail_lim: Vec<usize>,
    qhead: usize,
    /// Scratch marks for conflict analysis.
    seen: Vec<bool>,
    unsat: bool,
}

impl Default for Solver {
    fn default() -> Self {
        Solver::new()
    }
}

impl Solver {
    /// Empty formula over zero variables.
    pub fn new() -> Solver {
        Solver {
            clauses: Vec::new(),
            watches: Vec::new(),
            assign: Vec::new(),
            phase: Vec::new(),
            level: Vec::new(),
            reason: Vec::new(),
            activity: Vec::new(),
            var_inc: 1.0,
            trail: Vec::new(),
            trail_lim: Vec::new(),
            qhead: 0,
            seen: Vec::new(),
            unsat: false,
        }
    }

    /// Number of variables allocated so far.
    pub fn num_vars(&self) -> usize {
        self.assign.len()
    }

    /// Allocate a fresh variable.
    pub fn new_var(&mut self) -> Var {
        let v = self.assign.len() as Var;
        self.assign.push(0);
        self.phase.push(false);
        self.level.push(0);
        self.reason.push(NO_REASON);
        self.activity.push(0.0);
        self.seen.push(false);
        self.watches.push(Vec::new());
        self.watches.push(Vec::new());
        v
    }

    fn lit_value(&self, l: Lit) -> i8 {
        let a = self.assign[l.var() as usize];
        if l.is_neg() {
            -a
        } else {
            a
        }
    }

    /// Add a clause. Must be called before [`Solver::solve`] (the solver is
    /// at decision level 0). Returns `false` once the formula is known
    /// unsatisfiable — callers may stop encoding early.
    pub fn add_clause(&mut self, lits: &[Lit]) -> bool {
        debug_assert!(self.trail_lim.is_empty(), "clauses must be added at decision level 0");
        if self.unsat {
            return false;
        }
        // Simplify under the level-0 assignment: drop false literals, drop
        // the whole clause on a true literal or a (p ∨ ¬p) tautology.
        let mut c: Vec<Lit> = Vec::with_capacity(lits.len());
        for &l in lits {
            debug_assert!((l.var() as usize) < self.assign.len(), "literal for unknown variable");
            match self.lit_value(l) {
                1 => return true,
                -1 => continue,
                _ => {
                    if c.contains(&!l) {
                        return true;
                    }
                    if !c.contains(&l) {
                        c.push(l);
                    }
                }
            }
        }
        match c.len() {
            0 => {
                self.unsat = true;
                false
            }
            1 => {
                // Propagate eagerly so later add_clause calls see the unit.
                self.enqueue(c[0], NO_REASON);
                if self.propagate().is_some() {
                    self.unsat = true;
                }
                !self.unsat
            }
            _ => {
                let id = self.clauses.len() as u32;
                self.watches[c[0].index()].push(id);
                self.watches[c[1].index()].push(id);
                self.clauses.push(c);
                true
            }
        }
    }

    fn enqueue(&mut self, l: Lit, reason: u32) {
        debug_assert_eq!(self.lit_value(l), 0);
        let v = l.var() as usize;
        self.assign[v] = if l.is_neg() { -1 } else { 1 };
        self.phase[v] = !l.is_neg();
        self.level[v] = self.trail_lim.len() as u32;
        self.reason[v] = reason;
        self.trail.push(l);
    }

    /// Unit propagation to fixpoint; returns a conflicting clause id, if any.
    fn propagate(&mut self) -> Option<u32> {
        while self.qhead < self.trail.len() {
            let p = self.trail[self.qhead];
            self.qhead += 1;
            let false_lit = !p;
            // Detach the watch list; surviving entries are re-attached below.
            let mut ws = std::mem::take(&mut self.watches[false_lit.index()]);
            let mut i = 0;
            'clauses: while i < ws.len() {
                let cid = ws[i] as usize;
                // Normalize: the falsified watch goes to slot 1.
                if self.clauses[cid][0] == false_lit {
                    self.clauses[cid].swap(0, 1);
                }
                let first = self.clauses[cid][0];
                if self.lit_value(first) == 1 {
                    i += 1;
                    continue;
                }
                // Find a non-false replacement watch.
                for k in 2..self.clauses[cid].len() {
                    let cand = self.clauses[cid][k];
                    if self.lit_value(cand) != -1 {
                        self.clauses[cid].swap(1, k);
                        self.watches[cand.index()].push(cid as u32);
                        ws.swap_remove(i);
                        continue 'clauses;
                    }
                }
                // Clause is unit or conflicting on `first`.
                if self.lit_value(first) == -1 {
                    self.watches[false_lit.index()] = ws;
                    return Some(cid as u32);
                }
                self.enqueue(first, cid as u32);
                i += 1;
            }
            self.watches[false_lit.index()] = ws;
        }
        None
    }

    /// First-UIP conflict analysis. Returns the learned clause (asserting
    /// literal in slot 0, watch partner in slot 1) and the backtrack level.
    fn analyze(&mut self, mut confl: u32) -> (Vec<Lit>, u32) {
        let current = self.trail_lim.len() as u32;
        let mut learnt: Vec<Lit> = vec![Lit::pos(0)]; // slot 0 patched below
        let mut counter = 0usize;
        let mut idx = self.trail.len();
        let mut p: Option<Lit> = None;
        loop {
            // Reason clauses keep their propagated literal in slot 0; skip it
            // on every round after the conflict clause itself.
            let start = usize::from(p.is_some());
            let lits: Vec<Lit> = self.clauses[confl as usize][start..].to_vec();
            for q in lits {
                let v = q.var() as usize;
                if !self.seen[v] && self.level[v] > 0 {
                    self.seen[v] = true;
                    self.bump(v);
                    if self.level[v] >= current {
                        counter += 1;
                    } else {
                        learnt.push(q);
                    }
                }
            }
            // Walk the trail back to the most recent marked literal.
            loop {
                idx -= 1;
                if self.seen[self.trail[idx].var() as usize] {
                    break;
                }
            }
            let pl = self.trail[idx];
            self.seen[pl.var() as usize] = false;
            counter -= 1;
            p = Some(pl);
            if counter == 0 {
                break;
            }
            confl = self.reason[pl.var() as usize];
        }
        learnt[0] = !p.unwrap();
        // Backtrack to the second-highest level in the clause; put that
        // literal in slot 1 so it is watched.
        let mut bt_level = 0u32;
        if learnt.len() > 1 {
            let mut max_k = 1;
            for k in 2..learnt.len() {
                if self.level[learnt[k].var() as usize] > self.level[learnt[max_k].var() as usize] {
                    max_k = k;
                }
            }
            learnt.swap(1, max_k);
            bt_level = self.level[learnt[1].var() as usize];
        }
        for &l in &learnt {
            self.seen[l.var() as usize] = false;
        }
        (learnt, bt_level)
    }

    fn bump(&mut self, v: usize) {
        self.activity[v] += self.var_inc;
        if self.activity[v] > 1e100 {
            for a in &mut self.activity {
                *a *= 1e-100;
            }
            self.var_inc *= 1e-100;
        }
    }

    fn backtrack(&mut self, lvl: u32) {
        while self.trail_lim.len() as u32 > lvl {
            let lim = self.trail_lim.pop().unwrap();
            while self.trail.len() > lim {
                let l = self.trail.pop().unwrap();
                let v = l.var() as usize;
                self.assign[v] = 0;
                self.reason[v] = NO_REASON;
            }
        }
        self.qhead = self.trail.len();
    }

    /// Decide satisfiability. One-shot: adding clauses after a `solve` call
    /// is unsupported.
    pub fn solve(&mut self) -> SatResult {
        if self.unsat {
            return SatResult::Unsat;
        }
        let mut restart_idx = 0u64;
        let mut budget = 64 * luby(restart_idx);
        let mut since_restart = 0u64;
        loop {
            if let Some(confl) = self.propagate() {
                if self.trail_lim.is_empty() {
                    self.unsat = true;
                    return SatResult::Unsat;
                }
                since_restart += 1;
                let (learnt, bt) = self.analyze(confl);
                self.backtrack(bt);
                if learnt.len() == 1 {
                    self.enqueue(learnt[0], NO_REASON);
                } else {
                    let id = self.clauses.len() as u32;
                    self.watches[learnt[0].index()].push(id);
                    self.watches[learnt[1].index()].push(id);
                    let asserting = learnt[0];
                    self.clauses.push(learnt);
                    self.enqueue(asserting, id);
                }
                self.decay();
            } else if since_restart >= budget {
                since_restart = 0;
                restart_idx += 1;
                budget = 64 * luby(restart_idx);
                self.backtrack(0);
            } else {
                // Decide: unassigned variable with maximal activity, saved
                // polarity first.
                let mut pick: Option<usize> = None;
                for (v, &a) in self.assign.iter().enumerate() {
                    if a == 0 && pick.map(|p| self.activity[v] > self.activity[p]).unwrap_or(true) {
                        pick = Some(v);
                    }
                }
                match pick {
                    None => {
                        return SatResult::Sat(self.assign.iter().map(|&a| a == 1).collect());
                    }
                    Some(v) => {
                        self.trail_lim.push(self.trail.len());
                        let l = if self.phase[v] {
                            Lit::pos(v as Var)
                        } else {
                            Lit::neg(v as Var)
                        };
                        self.enqueue(l, NO_REASON);
                    }
                }
            }
        }
    }

    fn decay(&mut self) {
        self.var_inc /= 0.95;
    }
}

/// Luby restart sequence: 1, 1, 2, 1, 1, 2, 4, 1, 1, 2, 1, 1, 2, 4, 8, …
fn luby(mut x: u64) -> u64 {
    let (mut size, mut seq) = (1u64, 0u32);
    while size < x + 1 {
        seq += 1;
        size = 2 * size + 1;
    }
    while size - 1 != x {
        size = (size - 1) >> 1;
        seq -= 1;
        x %= size;
    }
    1u64 << seq
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_model_satisfies(clauses: &[Vec<Lit>], model: &[bool]) {
        for c in clauses {
            assert!(
                c.iter().any(|&l| model[l.var() as usize] != l.is_neg()),
                "model does not satisfy {c:?}"
            );
        }
    }

    #[test]
    fn trivial_sat_with_forced_literal() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        assert!(s.add_clause(&[Lit::pos(a), Lit::pos(b)]));
        assert!(s.add_clause(&[Lit::neg(a)]));
        match s.solve() {
            SatResult::Sat(m) => {
                assert!(!m[a as usize]);
                assert!(m[b as usize]);
            }
            SatResult::Unsat => panic!("expected SAT"),
        }
    }

    #[test]
    fn contradictory_units_are_unsat() {
        let mut s = Solver::new();
        let a = s.new_var();
        s.add_clause(&[Lit::pos(a)]);
        s.add_clause(&[Lit::neg(a)]);
        assert_eq!(s.solve(), SatResult::Unsat);
    }

    #[test]
    fn empty_clause_is_unsat() {
        let mut s = Solver::new();
        let _ = s.new_var();
        assert!(!s.add_clause(&[]));
        assert_eq!(s.solve(), SatResult::Unsat);
    }

    #[test]
    fn tautology_and_duplicates_are_harmless() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        assert!(s.add_clause(&[Lit::pos(a), Lit::neg(a)]));
        assert!(s.add_clause(&[Lit::pos(b), Lit::pos(b)]));
        match s.solve() {
            SatResult::Sat(m) => assert!(m[b as usize]),
            SatResult::Unsat => panic!("expected SAT"),
        }
    }

    #[test]
    fn xor_chain_is_sat_with_consistent_model() {
        // x0 ⊕ x1 = 1, x1 ⊕ x2 = 1, x2 ⊕ x3 = 1 — alternating assignment.
        let mut s = Solver::new();
        let xs: Vec<Var> = (0..4).map(|_| s.new_var()).collect();
        let mut clauses = Vec::new();
        for w in xs.windows(2) {
            let (p, q) = (w[0], w[1]);
            clauses.push(vec![Lit::pos(p), Lit::pos(q)]);
            clauses.push(vec![Lit::neg(p), Lit::neg(q)]);
        }
        for c in &clauses {
            assert!(s.add_clause(c));
        }
        match s.solve() {
            SatResult::Sat(m) => {
                assert_model_satisfies(&clauses, &m);
                assert_ne!(m[0], m[1]);
                assert_ne!(m[1], m[2]);
                assert_ne!(m[2], m[3]);
            }
            SatResult::Unsat => panic!("expected SAT"),
        }
    }

    #[test]
    fn pigeonhole_4_into_3_is_unsat() {
        // 4 pigeons, 3 holes: at-least-one hole per pigeon, at-most-one
        // pigeon per hole. Forces real conflict analysis and restarts.
        const P: usize = 4;
        const H: usize = 3;
        let mut s = Solver::new();
        let mut v: [[Var; H]; P] = [[0; H]; P];
        for row in v.iter_mut() {
            for cell in row.iter_mut() {
                *cell = s.new_var();
            }
        }
        for row in &v {
            let c: Vec<Lit> = row.iter().map(|&x| Lit::pos(x)).collect();
            s.add_clause(&c);
        }
        for h in 0..H {
            for (i, ri) in v.iter().enumerate() {
                for rj in v.iter().skip(i + 1) {
                    s.add_clause(&[Lit::neg(ri[h]), Lit::neg(rj[h])]);
                }
            }
        }
        assert_eq!(s.solve(), SatResult::Unsat);
    }

    #[test]
    fn pigeonhole_3_into_3_is_sat() {
        const P: usize = 3;
        const H: usize = 3;
        let mut s = Solver::new();
        let mut v: [[Var; H]; P] = [[0; H]; P];
        let mut clauses = Vec::new();
        for row in v.iter_mut() {
            for cell in row.iter_mut() {
                *cell = s.new_var();
            }
        }
        for row in &v {
            clauses.push(row.iter().map(|&x| Lit::pos(x)).collect::<Vec<_>>());
        }
        for h in 0..H {
            for (i, ri) in v.iter().enumerate() {
                for rj in v.iter().skip(i + 1) {
                    clauses.push(vec![Lit::neg(ri[h]), Lit::neg(rj[h])]);
                }
            }
        }
        for c in &clauses {
            assert!(s.add_clause(c));
        }
        match s.solve() {
            SatResult::Sat(m) => assert_model_satisfies(&clauses, &m),
            SatResult::Unsat => panic!("expected SAT"),
        }
    }

    #[test]
    fn literal_packing_roundtrip() {
        let l = Lit::pos(7);
        assert_eq!(l.var(), 7);
        assert!(!l.is_neg());
        assert_eq!((!l).var(), 7);
        assert!((!l).is_neg());
        assert_eq!(!!l, l);
        assert_eq!(Lit::neg(3), !Lit::pos(3));
    }

    #[test]
    fn luby_prefix_is_correct() {
        let want = [1, 1, 2, 1, 1, 2, 4, 1, 1, 2, 1, 1, 2, 4, 8];
        let got: Vec<u64> = (0..want.len() as u64).map(luby).collect();
        assert_eq!(got, want);
    }
}
