//! Quantized, fanin-constrained model representation.
//!
//! The interchange format between the Python training stack (L2) and the
//! Rust flow engine (L3): `artifacts/<arch>.model.json`. Quantizers are
//! exported as explicit *level tables* (`code → value`) plus *threshold
//! arrays* (`value → code` via binary search over bucket boundaries), so the
//! Rust side never re-implements PACT/sign math — it replays exactly what
//! training quantized, making the integer evaluation in
//! [`crate::nn::eval`] the gold reference the logic must match bit-for-bit.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// A scalar quantizer given by its reconstruction levels and the decision
/// thresholds between adjacent codes. `levels.len() == 2^bits`,
/// `thresholds.len() == levels.len() - 1`, and `value v` maps to the number
/// of thresholds strictly below `v` (i.e. code `c` ⇔
/// `thresholds[c-1] ≤ v < thresholds[c]`).
#[derive(Clone, Debug, PartialEq)]
pub struct Quantizer {
    /// Bits per code.
    pub bits: usize,
    /// Reconstruction value of each code (ascending).
    pub levels: Vec<f64>,
    /// Decision boundaries (ascending, one fewer than levels).
    pub thresholds: Vec<f64>,
}

impl Quantizer {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.levels.len() != 1usize << self.bits {
            return Err(format!(
                "levels.len()={} but bits={} (expected {})",
                self.levels.len(),
                self.bits,
                1usize << self.bits
            ));
        }
        if self.thresholds.len() + 1 != self.levels.len() {
            return Err("thresholds must have levels.len()-1 entries".into());
        }
        if self.levels.windows(2).any(|w| w[0] > w[1]) {
            return Err("levels must be ascending".into());
        }
        if self.thresholds.windows(2).any(|w| w[0] > w[1]) {
            return Err("thresholds must be ascending".into());
        }
        Ok(())
    }

    /// Quantize a value to its code.
    #[inline]
    pub fn code_of(&self, v: f64) -> usize {
        // number of thresholds ≤ v  (partition_point is a binary search)
        self.thresholds.partition_point(|&t| t <= v)
    }

    /// Reconstruction value of a code.
    #[inline]
    pub fn value_of(&self, code: usize) -> f64 {
        self.levels[code]
    }

    /// Quantize-dequantize.
    #[inline]
    pub fn quantize(&self, v: f64) -> f64 {
        self.value_of(self.code_of(v))
    }

    /// A symmetric signed uniform quantizer (test/quickstart helper): levels
    /// `{-m, …, m}·scale` spread over `2^bits` codes.
    pub fn signed_uniform(bits: usize, scale: f64) -> Quantizer {
        let n = 1usize << bits;
        let half = (n / 2) as f64;
        let levels: Vec<f64> = (0..n).map(|c| (c as f64 - half) * scale).collect();
        let thresholds = mid_thresholds(&levels);
        Quantizer { bits, levels, thresholds }
    }

    /// A PACT-style unsigned quantizer: levels `{0 … α}` over `2^bits` codes.
    pub fn pact(bits: usize, alpha: f64) -> Quantizer {
        let n = 1usize << bits;
        let levels: Vec<f64> = (0..n).map(|c| alpha * c as f64 / (n - 1) as f64).collect();
        let thresholds = mid_thresholds(&levels);
        Quantizer { bits, levels, thresholds }
    }

    /// Bipolar sign quantizer: 1 bit, {-1, +1}.
    pub fn sign() -> Quantizer {
        Quantizer { bits: 1, levels: vec![-1.0, 1.0], thresholds: vec![0.0] }
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("bits", Json::int(self.bits as i64)),
            ("levels", Json::Arr(self.levels.iter().map(|&v| Json::float(v)).collect())),
            (
                "thresholds",
                Json::Arr(self.thresholds.iter().map(|&v| Json::float(v)).collect()),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<Quantizer, String> {
        let q = Quantizer {
            bits: j.req("bits")?.as_usize().ok_or("bits must be usize")?,
            levels: j.req("levels")?.to_f64_vec()?,
            thresholds: j.req("thresholds")?.to_f64_vec()?,
        };
        q.validate()?;
        Ok(q)
    }
}

/// Midpoint thresholds between consecutive levels.
pub fn mid_thresholds(levels: &[f64]) -> Vec<f64> {
    levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect()
}

/// One fanin-constrained dense layer.
#[derive(Clone, Debug)]
pub struct Layer {
    /// Input width (neurons/features of the previous layer).
    pub in_width: usize,
    /// Output width (neurons).
    pub out_width: usize,
    /// Per-neuron surviving input indices (`mask[n]` has ≤ fanin entries).
    pub mask: Vec<Vec<usize>>,
    /// Per-neuron weights aligned with `mask[n]`.
    pub weights: Vec<Vec<f64>>,
    /// Per-neuron bias (batch-norm folded in by the exporter).
    pub bias: Vec<f64>,
    /// Activation quantizer applied to every neuron of this layer.
    pub act: Quantizer,
}

impl Layer {
    /// Maximum fanin across neurons.
    pub fn max_fanin(&self) -> usize {
        self.mask.iter().map(|m| m.len()).max().unwrap_or(0)
    }

    /// Validate shape invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.mask.len() != self.out_width
            || self.weights.len() != self.out_width
            || self.bias.len() != self.out_width
        {
            return Err("per-neuron arrays must match out_width".into());
        }
        for (n, (m, w)) in self.mask.iter().zip(&self.weights).enumerate() {
            if m.len() != w.len() {
                return Err(format!("neuron {n}: mask/weight length mismatch"));
            }
            if m.iter().any(|&i| i >= self.in_width) {
                return Err(format!("neuron {n}: mask index out of range"));
            }
            let mut sorted = m.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != m.len() {
                return Err(format!("neuron {n}: duplicate mask indices"));
            }
        }
        self.act.validate()
    }
}

/// A complete quantized model.
#[derive(Clone, Debug)]
pub struct Model {
    /// Architecture name (e.g. "jsc-s").
    pub name: String,
    /// Raw feature count.
    pub input_features: usize,
    /// Classes (argmax over the last layer's first `num_classes` neurons).
    pub num_classes: usize,
    /// Per-feature standardization (applied before input quantization).
    pub feature_mean: Vec<f64>,
    /// Per-feature std (divide).
    pub feature_std: Vec<f64>,
    /// Input quantizer (applied per standardized feature).
    pub input_quant: Quantizer,
    /// Layers, in order.
    pub layers: Vec<Layer>,
}

impl Model {
    /// Validate the whole model.
    pub fn validate(&self) -> Result<(), String> {
        if self.feature_mean.len() != self.input_features
            || self.feature_std.len() != self.input_features
        {
            return Err("feature stats must match input_features".into());
        }
        self.input_quant.validate()?;
        let mut width = self.input_features;
        for (i, l) in self.layers.iter().enumerate() {
            if l.in_width != width {
                return Err(format!(
                    "layer {i}: in_width {} != previous width {width}",
                    l.in_width
                ));
            }
            l.validate().map_err(|e| format!("layer {i}: {e}"))?;
            width = l.out_width;
        }
        let last = self.layers.last().ok_or("model needs ≥1 layer")?;
        if last.out_width < self.num_classes {
            return Err("last layer narrower than num_classes".into());
        }
        Ok(())
    }

    /// Total bits of the quantized input vector (the circuit's PI count).
    pub fn input_bits(&self) -> usize {
        self.input_features * self.input_quant.bits
    }

    /// Bits of the activation quantizer of layer `l`'s *inputs*
    /// (input_quant for layer 0).
    pub fn in_quant_of_layer(&self, l: usize) -> &Quantizer {
        if l == 0 {
            &self.input_quant
        } else {
            &self.layers[l - 1].act
        }
    }

    // ---- JSON (de)serialization ----

    /// Serialize to the interchange JSON.
    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                Json::obj([
                    ("in", Json::int(l.in_width as i64)),
                    ("out", Json::int(l.out_width as i64)),
                    (
                        "mask",
                        Json::Arr(
                            l.mask
                                .iter()
                                .map(|m| {
                                    Json::Arr(m.iter().map(|&i| Json::int(i as i64)).collect())
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "weights",
                        Json::Arr(
                            l.weights
                                .iter()
                                .map(|w| Json::Arr(w.iter().map(|&v| Json::float(v)).collect()))
                                .collect(),
                        ),
                    ),
                    ("bias", Json::Arr(l.bias.iter().map(|&v| Json::float(v)).collect())),
                    ("act", l.act.to_json()),
                ])
            })
            .collect();
        Json::obj([
            ("name", Json::str(self.name.clone())),
            ("input_features", Json::int(self.input_features as i64)),
            ("num_classes", Json::int(self.num_classes as i64)),
            (
                "feature_mean",
                Json::Arr(self.feature_mean.iter().map(|&v| Json::float(v)).collect()),
            ),
            (
                "feature_std",
                Json::Arr(self.feature_std.iter().map(|&v| Json::float(v)).collect()),
            ),
            ("input_quant", self.input_quant.to_json()),
            ("layers", Json::Arr(layers)),
        ])
    }

    /// Parse from interchange JSON.
    pub fn from_json(j: &Json) -> Result<Model, String> {
        let layers_json = j.req("layers")?.as_arr().ok_or("layers must be array")?;
        let mut layers = Vec::with_capacity(layers_json.len());
        for (i, lj) in layers_json.iter().enumerate() {
            let mask_json = lj.req("mask")?.as_arr().ok_or("mask must be array")?;
            let mask: Result<Vec<Vec<usize>>, String> =
                mask_json.iter().map(|m| m.to_usize_vec()).collect();
            let weights_json = lj.req("weights")?.as_arr().ok_or("weights must be array")?;
            let weights: Result<Vec<Vec<f64>>, String> =
                weights_json.iter().map(|w| w.to_f64_vec()).collect();
            layers.push(Layer {
                in_width: lj.req("in")?.as_usize().ok_or("in must be usize")?,
                out_width: lj.req("out")?.as_usize().ok_or("out must be usize")?,
                mask: mask.map_err(|e| format!("layer {i} mask: {e}"))?,
                weights: weights.map_err(|e| format!("layer {i} weights: {e}"))?,
                bias: lj.req("bias")?.to_f64_vec()?,
                act: Quantizer::from_json(lj.req("act")?)?,
            });
        }
        let m = Model {
            name: j.req("name")?.as_str().ok_or("name must be string")?.to_string(),
            input_features: j.req("input_features")?.as_usize().ok_or("bad input_features")?,
            num_classes: j.req("num_classes")?.as_usize().ok_or("bad num_classes")?,
            feature_mean: j.req("feature_mean")?.to_f64_vec()?,
            feature_std: j.req("feature_std")?.to_f64_vec()?,
            input_quant: Quantizer::from_json(j.req("input_quant")?)?,
            layers,
        };
        m.validate()?;
        Ok(m)
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<Model, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let j = crate::util::json::Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        Model::from_json(&j)
    }

    /// Save to a JSON file.
    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_string()).map_err(|e| format!("{path}: {e}"))
    }

    /// Summary for logs.
    pub fn summary(&self) -> String {
        let widths: Vec<String> = std::iter::once(self.input_features.to_string())
            .chain(self.layers.iter().map(|l| l.out_width.to_string()))
            .collect();
        let fanins: Vec<String> =
            self.layers.iter().map(|l| l.max_fanin().to_string()).collect();
        format!(
            "{}: {} (fanin {}, input {}b, acts {})",
            self.name,
            widths.join("→"),
            fanins.join("/"),
            self.input_quant.bits,
            self.layers
                .iter()
                .map(|l| l.act.bits.to_string())
                .collect::<Vec<_>>()
                .join("/")
        )
    }
}

/// Build a deterministic random model for tests, examples, and benches —
/// the shape of a NullaNet Tiny network without any training.
pub fn random_model(
    name: &str,
    input_features: usize,
    widths: &[usize],
    fanin: usize,
    act_bits: usize,
    seed: u64,
) -> Model {
    use crate::util::prng::Xoshiro256;
    let mut rng = Xoshiro256::new(seed);
    let mut layers = Vec::new();
    let mut in_w = input_features;
    for (li, &out_w) in widths.iter().enumerate() {
        let is_last = li == widths.len() - 1;
        let f = fanin.min(in_w);
        let mut mask = Vec::with_capacity(out_w);
        let mut weights = Vec::with_capacity(out_w);
        let mut bias = Vec::with_capacity(out_w);
        for _ in 0..out_w {
            let mut m = rng.sample_indices(in_w, f);
            m.sort_unstable();
            mask.push(m);
            weights.push((0..f).map(|_| rng.next_gaussian()).collect());
            bias.push(0.2 * rng.next_gaussian());
        }
        // Hidden layers: PACT-like unsigned; last layer: signed for argmax.
        let act = if is_last {
            Quantizer::signed_uniform(act_bits + 2, 0.5)
        } else {
            Quantizer::pact(act_bits, 2.0)
        };
        layers.push(Layer { in_width: in_w, out_width: out_w, mask, weights, bias, act });
        in_w = out_w;
    }
    Model {
        name: name.to_string(),
        input_features,
        num_classes: widths.last().copied().unwrap_or(1),
        feature_mean: vec![0.0; input_features],
        feature_std: vec![1.0; input_features],
        input_quant: Quantizer::signed_uniform(act_bits, 1.0),
        layers,
    }
}

/// Named architecture presets mirroring DESIGN.md §5 (LogicNets-derived).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    JscS,
    JscM,
    JscL,
}

impl Arch {
    /// Parse "jsc-s"/"jsc-m"/"jsc-l".
    pub fn parse(s: &str) -> Option<Arch> {
        match s.to_ascii_lowercase().as_str() {
            "jsc-s" | "jscs" | "s" => Some(Arch::JscS),
            "jsc-m" | "jscm" | "m" => Some(Arch::JscM),
            "jsc-l" | "jscl" | "l" => Some(Arch::JscL),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Arch::JscS => "jsc-s",
            Arch::JscM => "jsc-m",
            Arch::JscL => "jsc-l",
        }
    }

    /// (hidden+output widths, activation bits, fanin) per DESIGN.md §5.
    pub fn spec(&self) -> (Vec<usize>, usize, usize) {
        match self {
            Arch::JscS => (vec![64, 32, 5], 2, 3),
            Arch::JscM => (vec![64, 32, 32, 5], 2, 4),
            Arch::JscL => (vec![32, 64, 192, 192, 16, 5], 3, 4),
        }
    }

    /// All presets.
    pub fn all() -> [Arch; 3] {
        [Arch::JscS, Arch::JscM, Arch::JscL]
    }
}

/// Quantizer registry for documentation/UI purposes.
pub fn describe_quantizers() -> BTreeMap<&'static str, &'static str> {
    let mut m = BTreeMap::new();
    m.insert("sign", "bipolar {-1,+1}, used when inputs span negative values");
    m.insert("pact", "PACT [9]: learned clip α, unsigned uniform levels");
    m.insert("signed_uniform", "symmetric signed uniform (input/output layers)");
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizer_code_roundtrip() {
        let q = Quantizer::signed_uniform(2, 0.5); // levels -1.0,-0.5,0,0.5
        assert_eq!(q.levels, vec![-1.0, -0.5, 0.0, 0.5]);
        assert_eq!(q.code_of(-2.0), 0);
        assert_eq!(q.code_of(-0.74), 1);
        assert_eq!(q.code_of(0.0), 2);
        assert_eq!(q.code_of(10.0), 3);
        for c in 0..4 {
            assert_eq!(q.code_of(q.value_of(c)), c, "levels quantize to themselves");
        }
    }

    #[test]
    fn pact_quantizer() {
        let q = Quantizer::pact(2, 3.0); // levels 0,1,2,3
        assert_eq!(q.levels, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(q.quantize(-5.0), 0.0);
        assert_eq!(q.quantize(1.4), 1.0);
        assert_eq!(q.quantize(99.0), 3.0);
    }

    #[test]
    fn sign_quantizer() {
        let q = Quantizer::sign();
        assert_eq!(q.quantize(-0.3), -1.0);
        assert_eq!(q.quantize(0.3), 1.0);
        assert_eq!(q.bits, 1);
    }

    #[test]
    fn quantizer_validation() {
        let mut q = Quantizer::pact(2, 1.0);
        assert!(q.validate().is_ok());
        q.levels.pop();
        assert!(q.validate().is_err());
        let bad = Quantizer { bits: 1, levels: vec![1.0, -1.0], thresholds: vec![0.0] };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn random_model_validates() {
        let m = random_model("t", 8, &[6, 4, 3], 3, 2, 42);
        m.validate().unwrap();
        assert_eq!(m.input_bits(), 16);
        assert_eq!(m.layers.len(), 3);
        assert!(m.layers.iter().all(|l| l.max_fanin() <= 3));
    }

    #[test]
    fn json_roundtrip_exact() {
        let m = random_model("rt", 6, &[5, 3], 3, 2, 7);
        let j = m.to_json().to_string();
        let back = Model::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.name, m.name);
        assert_eq!(back.layers.len(), m.layers.len());
        for (a, b) in m.layers.iter().zip(&back.layers) {
            assert_eq!(a.mask, b.mask);
            assert_eq!(a.weights, b.weights, "weights must round-trip bit-exact");
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.act, b.act);
        }
        assert_eq!(back.input_quant, m.input_quant);
    }

    #[test]
    fn file_roundtrip() {
        let m = random_model("file", 4, &[3, 2], 2, 1, 3);
        let path = "/tmp/nnt_model_test.json";
        m.save(path).unwrap();
        let back = Model::load(path).unwrap();
        assert_eq!(back.summary(), m.summary());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn validation_catches_bad_models() {
        let mut m = random_model("bad", 4, &[3, 2], 2, 1, 3);
        m.layers[1].in_width = 7;
        assert!(m.validate().is_err());
        let mut m2 = random_model("bad2", 4, &[3], 2, 1, 3);
        m2.layers[0].mask[0] = vec![0, 0]; // duplicate indices
        assert!(m2.validate().is_err());
        let mut m3 = random_model("bad3", 4, &[3], 2, 1, 3);
        m3.num_classes = 10; // wider than last layer
        assert!(m3.validate().is_err());
    }

    #[test]
    fn arch_presets() {
        assert_eq!(Arch::parse("JSC-S"), Some(Arch::JscS));
        assert_eq!(Arch::parse("jsc-l").unwrap().name(), "jsc-l");
        assert!(Arch::parse("nope").is_none());
        let (w, b, f) = Arch::JscL.spec();
        assert_eq!(w.last(), Some(&5));
        assert_eq!(b, 3);
        assert_eq!(f, 4);
        // enumeration cost stays feasible: γ·β ≤ 12
        for a in Arch::all() {
            let (_, bits, fanin) = a.spec();
            assert!(bits * fanin <= 12);
        }
    }
}
