//! Quantized-model substrate: interchange format, exact code-level
//! evaluation, and neuron truth-table enumeration (NullaNet's core step).

pub mod enumerate;
pub mod eval;
pub mod model;
