//! Neuron input enumeration → truth tables (NullaNet [32], step 3).
//!
//! A neuron with fanin γ whose inputs are β-bit codes is a completely
//! specified Boolean function `{0,1}^(γ·β) → {0,1}^(β_out)`: enumerate all
//! `2^(γ·β)` input-code combinations, run the exact integer neuron
//! evaluation, and record each output bit in its own [`TruthTable`].
//! Optionally, combinations never observed on training data become
//! don't-cares (the original NullaNet trick; NullaNet Tiny enumerates fully
//! but the flow exposes it as an ablation).

use crate::logic::truthtable::TruthTable;
use crate::nn::model::Model;

/// Hard bound on per-neuron enumeration width (bits = fanin · in_bits).
/// Both the exhaustive enumeration and the DC-pass observation tables
/// allocate `2^bits` entries, so every path that sizes such a table — not
/// just [`enumerate_neuron`] — must enforce the same limit *before*
/// allocating (a wide-fanin model would otherwise OOM or overflow the
/// shift in the DC pass before the enumeration guard could fire).
pub const MAX_ENUM_BITS: usize = 20;

/// The one shared bound check: every neuron of `layer` must fit
/// `2^MAX_ENUM_BITS`. Called by `run_flow` up front (all layers) and by
/// [`observed_patterns`] before it allocates; [`enumerate_neuron`] keeps
/// an assert as the last-resort invariant.
pub fn check_layer_enum_bounds(model: &Model, layer: usize) -> Result<(), String> {
    let l = &model.layers[layer];
    let in_bits = model.in_quant_of_layer(layer).bits;
    for (n, m) in l.mask.iter().enumerate() {
        let bits = m.len() * in_bits;
        if bits > MAX_ENUM_BITS {
            return Err(format!(
                "layer {layer} neuron {n}: fanin {} × {in_bits} input bits = \
                 2^{bits} enumeration/observation entries; the per-neuron \
                 bound is 2^{MAX_ENUM_BITS}",
                m.len()
            ));
        }
    }
    Ok(())
}

/// Enumerated function of one neuron: one table per output bit (LSB first),
/// plus the shared don't-care set.
#[derive(Clone, Debug)]
pub struct NeuronFunction {
    /// Layer index.
    pub layer: usize,
    /// Neuron index within the layer.
    pub neuron: usize,
    /// Input variables = mask.len() · in_bits.
    pub input_bits: usize,
    /// Per-output-bit ON-set tables (over `input_bits` variables).
    pub on: Vec<TruthTable>,
    /// Shared DC set (constant 0 unless data-derived DCs are enabled).
    pub dc: TruthTable,
}

/// Enumerate the function of `(layer, neuron)`. `observed` — if given —
/// restricts the care set: entry `i` of the slice corresponds to the packed
/// input assignment `i`; `false` marks never-observed patterns as DC.
pub fn enumerate_neuron(
    model: &Model,
    layer: usize,
    neuron: usize,
    observed: Option<&[bool]>,
) -> NeuronFunction {
    let l = &model.layers[layer];
    let in_q = model.in_quant_of_layer(layer);
    let in_bits_per = in_q.bits;
    let fanin = l.mask[neuron].len();
    let input_bits = fanin * in_bits_per;
    assert!(
        input_bits <= MAX_ENUM_BITS,
        "enumeration limited to {MAX_ENUM_BITS} input bits (got {input_bits})"
    );
    let out_bits = l.act.bits;
    let size = 1usize << input_bits;
    if let Some(obs) = observed {
        assert_eq!(obs.len(), size);
    }

    let mut on: Vec<TruthTable> = (0..out_bits).map(|_| TruthTable::zeros(input_bits)).collect();
    let mut dc = TruthTable::zeros(input_bits);

    // Pre-decode weights for speed: acc = bias + Σ w_i · level(code_i).
    let weights = &l.weights[neuron];
    let bias = l.bias[neuron];
    let nlevels = 1usize << in_bits_per;
    let code_mask = (nlevels - 1) as u64;

    // Per-input lookup: w_i · level(c) for every code c.
    let wl: Vec<Vec<f64>> = weights
        .iter()
        .map(|&w| (0..nlevels).map(|c| w * in_q.value_of(c)).collect())
        .collect();

    for m in 0..size as u64 {
        if let Some(obs) = observed {
            if !obs[m as usize] {
                dc.set_bit(m as usize, true);
                continue;
            }
        }
        let mut acc = bias;
        for (i, tbl) in wl.iter().enumerate() {
            let code = ((m >> (i * in_bits_per)) & code_mask) as usize;
            acc += tbl[code];
        }
        let out_code = l.act.code_of(acc);
        for (b, table) in on.iter_mut().enumerate() {
            if (out_code >> b) & 1 == 1 {
                table.set_bit(m as usize, true);
            }
        }
    }
    NeuronFunction { layer, neuron, input_bits, on, dc }
}

/// Collect, per neuron of `layer`, the set of observed packed input
/// assignments over a dataset of input-code traces (for DC-from-data mode).
///
/// Errors (instead of allocating) when any neuron's `fanin · in_bits`
/// exceeds [`MAX_ENUM_BITS`]: the observation table is the same `2^bits`
/// shape the enumeration builds, and the DC pass runs *first* in the flow.
pub fn observed_patterns(
    model: &Model,
    layer: usize,
    traces: &[crate::nn::eval::Trace],
) -> Result<Vec<Vec<bool>>, String> {
    check_layer_enum_bounds(model, layer)?;
    let l = &model.layers[layer];
    let in_bits_per = model.in_quant_of_layer(layer).bits;
    let mut out: Vec<Vec<bool>> = l
        .mask
        .iter()
        .map(|m| vec![false; 1usize << (m.len() * in_bits_per)])
        .collect();
    for tr in traces {
        let codes: &[usize] =
            if layer == 0 { &tr.input_codes } else { &tr.codes[layer - 1] };
        for (n, mask) in l.mask.iter().enumerate() {
            let mut packed = 0usize;
            for (i, &src) in mask.iter().enumerate() {
                packed |= codes[src] << (i * in_bits_per);
            }
            out[n][packed] = true;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::eval::{forward_codes, neuron_code};
    use crate::nn::model::random_model;

    #[test]
    fn enumeration_matches_eval_exhaustively() {
        let m = random_model("t", 6, &[4, 3], 3, 2, 11);
        for layer in 0..m.layers.len() {
            let in_bits_per = m.in_quant_of_layer(layer).bits;
            for neuron in 0..m.layers[layer].out_width {
                let f = enumerate_neuron(&m, layer, neuron, None);
                let fanin = m.layers[layer].mask[neuron].len();
                assert_eq!(f.input_bits, fanin * in_bits_per);
                assert!(f.dc.is_zero());
                // Cross-check every assignment against neuron_code.
                for a in 0..1u64 << f.input_bits {
                    // unpack codes for the masked inputs; other inputs = 0
                    let mut in_codes = vec![0usize; m.layers[layer].in_width];
                    for (i, &src) in m.layers[layer].mask[neuron].iter().enumerate() {
                        in_codes[src] = ((a >> (i * in_bits_per))
                            & ((1 << in_bits_per) - 1))
                            as usize;
                    }
                    let want = neuron_code(&m, layer, neuron, &in_codes);
                    let got: usize = f
                        .on
                        .iter()
                        .enumerate()
                        .map(|(b, t)| if t.eval(a) { 1usize << b } else { 0 })
                        .sum();
                    assert_eq!(got, want, "layer {layer} neuron {neuron} a={a}");
                }
            }
        }
    }

    #[test]
    fn out_bits_match_act_bits() {
        let m = random_model("t", 5, &[3], 2, 2, 3);
        let f = enumerate_neuron(&m, 0, 0, None);
        assert_eq!(f.on.len(), m.layers[0].act.bits);
    }

    #[test]
    fn observed_patterns_mark_dc() {
        let m = random_model("t", 4, &[3, 2], 2, 1, 23);
        // Traces from a few inputs.
        let traces: Vec<_> = (0..10u64)
            .map(|s| {
                let codes: Vec<usize> = (0..4).map(|i| ((s >> i) & 1) as usize).collect();
                forward_codes(&m, &codes)
            })
            .collect();
        let obs = observed_patterns(&m, 0, &traces).unwrap();
        assert_eq!(obs.len(), 3);
        // With 1-bit inputs and fanin 2 → 4 patterns; some must be observed.
        for o in &obs {
            assert_eq!(o.len(), 4);
            assert!(o.iter().any(|&b| b), "at least one observed pattern");
        }
        // Enumerate with DC: dc set = complement of observed.
        let f = enumerate_neuron(&m, 0, 0, Some(&obs[0]));
        let dc_count = f.dc.count_ones();
        let unobserved = obs[0].iter().filter(|&&b| !b).count();
        assert_eq!(dc_count, unobserved);
        // ON sets never intersect DC.
        for t in &f.on {
            assert!(t.and(&f.dc).is_zero());
        }
    }

    #[test]
    fn observed_patterns_reject_wide_fanin_before_allocating() {
        // fanin 21 × 1 input bit = 21 bits > MAX_ENUM_BITS: the old code
        // allocated vec![false; 1 << 21] per neuron unchecked (and would
        // overflow the shift entirely past 63 bits).
        let m = random_model("wide", 21, &[2], 21, 1, 5);
        let err = observed_patterns(&m, 0, &[]).unwrap_err();
        assert!(err.contains("2^21"), "{err}");
        assert!(err.contains("fanin 21"), "{err}");
    }

    #[test]
    fn layer1_uses_previous_act_quantizer() {
        let m = random_model("t", 4, &[3, 2], 2, 2, 31);
        let f = enumerate_neuron(&m, 1, 0, None);
        // layer 1 inputs are layer 0 activations: 2 bits each, fanin 2
        assert_eq!(f.input_bits, 4);
    }
}
