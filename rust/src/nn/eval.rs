//! Exact integer/code-level evaluation of quantized models.
//!
//! This is the *gold reference* for the logic flow: every neuron's output
//! code is computed with the same level tables the truth-table enumerator
//! uses, so "netlist ≡ NN" can be checked bit-for-bit. Also provides
//! float-free classification (argmax over last-layer codes' values) and
//! test-set accuracy — the numbers Table I's accuracy column reports.

use crate::nn::model::Model;

/// Per-layer neuron output codes for one sample (useful for debugging and
/// for data-derived don't-care collection).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Trace {
    /// `codes[l][n]` = code of neuron `n` of layer `l`.
    pub codes: Vec<Vec<usize>>,
    /// Quantized input codes (per feature).
    pub input_codes: Vec<usize>,
}

/// Standardize + quantize raw features into input codes.
pub fn quantize_input(model: &Model, features: &[f64]) -> Vec<usize> {
    assert_eq!(features.len(), model.input_features);
    features
        .iter()
        .enumerate()
        .map(|(i, &x)| {
            let z = (x - model.feature_mean[i]) / model.feature_std[i];
            model.input_quant.code_of(z)
        })
        .collect()
}

/// Evaluate one neuron from its input codes: decode levels, weighted sum,
/// re-quantize. `in_quant` is the quantizer of the layer's inputs.
#[inline]
pub fn neuron_code(
    model: &Model,
    layer: usize,
    neuron: usize,
    in_codes: &[usize],
) -> usize {
    let l = &model.layers[layer];
    let q_in = model.in_quant_of_layer(layer);
    let mut acc = l.bias[neuron];
    for (w, &src) in l.weights[neuron].iter().zip(&l.mask[neuron]) {
        acc += w * q_in.value_of(in_codes[src]);
    }
    l.act.code_of(acc)
}

/// Full forward pass on code level; returns the trace.
pub fn forward_codes(model: &Model, input_codes: &[usize]) -> Trace {
    let mut codes: Vec<Vec<usize>> = Vec::with_capacity(model.layers.len());
    let mut current: Vec<usize> = input_codes.to_vec();
    for (li, l) in model.layers.iter().enumerate() {
        let next: Vec<usize> =
            (0..l.out_width).map(|n| neuron_code(model, li, n, &current)).collect();
        codes.push(next.clone());
        current = next;
    }
    Trace { codes, input_codes: input_codes.to_vec() }
}

/// Predicted class: argmax of last-layer reconstruction values over the
/// first `num_classes` neurons (ties: lowest index, matching the Python
/// exporter and the logic decoder).
pub fn classify_codes(model: &Model, last_codes: &[usize]) -> usize {
    let q = &model.layers.last().unwrap().act;
    let mut best = 0usize;
    let mut best_v = f64::NEG_INFINITY;
    for (n, &c) in last_codes.iter().take(model.num_classes).enumerate() {
        let v = q.value_of(c);
        if v > best_v {
            best_v = v;
            best = n;
        }
    }
    best
}

/// End-to-end: raw features → class.
pub fn classify(model: &Model, features: &[f64]) -> usize {
    let codes = quantize_input(model, features);
    let tr = forward_codes(model, &codes);
    classify_codes(model, tr.codes.last().unwrap())
}

/// Accuracy on a labelled set.
pub fn accuracy(model: &Model, xs: &[Vec<f64>], ys: &[usize]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return 0.0;
    }
    let correct = xs
        .iter()
        .zip(ys)
        .filter(|(x, &y)| classify(model, x) == y)
        .count();
    correct as f64 / xs.len() as f64
}

/// Encode input codes into the circuit's primary-input bit vector (LSB-first
/// per feature, feature 0 in the lowest bits) — the wire ordering contract
/// shared with [`crate::flow`].
pub fn codes_to_bits(codes: &[usize], bits_per_code: usize) -> Vec<bool> {
    let mut out = Vec::with_capacity(codes.len() * bits_per_code);
    for &c in codes {
        for b in 0..bits_per_code {
            out.push((c >> b) & 1 == 1);
        }
    }
    out
}

/// Encode input codes straight into a packed [`BitVec`] (same wire-order
/// contract as [`codes_to_bits`], without the intermediate `Vec<bool>`) —
/// the serving path's binarization step.
pub fn codes_to_bitvec(
    codes: &[usize],
    bits_per_code: usize,
) -> crate::util::bitvec::BitVec {
    let mut v = crate::util::bitvec::BitVec::zeros(codes.len() * bits_per_code);
    let mut i = 0;
    for &c in codes {
        for b in 0..bits_per_code {
            if (c >> b) & 1 == 1 {
                v.set(i, true);
            }
            i += 1;
        }
    }
    v
}

/// Decode a bit slice back into codes (inverse of [`codes_to_bits`]).
pub fn bits_to_codes(bits: &[bool], bits_per_code: usize) -> Vec<usize> {
    assert_eq!(bits.len() % bits_per_code, 0);
    bits.chunks(bits_per_code)
        .map(|ch| {
            ch.iter()
                .enumerate()
                .map(|(b, &v)| if v { 1usize << b } else { 0 })
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{random_model, Quantizer};

    #[test]
    fn neuron_code_matches_manual_computation() {
        let mut m = random_model("t", 3, &[2], 2, 1, 5);
        // Make layer deterministic: neuron 0 reads inputs {0,2} with w=1.0.
        m.layers[0].mask[0] = vec![0, 2];
        m.layers[0].weights[0] = vec![1.0, 1.0];
        m.layers[0].bias[0] = 0.0;
        m.layers[0].act = Quantizer::pact(1, 1.0); // levels {0,1}, threshold 0.5
        // input quant: 1-bit signed uniform → levels {-1, 0}
        m.input_quant = Quantizer::sign(); // {-1,+1}
        m.validate().unwrap();
        // codes (1,_,1) → values (+1,+1) → sum 2.0 → code 1
        assert_eq!(neuron_code(&m, 0, 0, &[1, 0, 1]), 1);
        // codes (0,_,1) → -1+1 = 0 → below 0.5 → code 0
        assert_eq!(neuron_code(&m, 0, 0, &[0, 1, 1]), 0);
    }

    #[test]
    fn forward_trace_shapes() {
        let m = random_model("t", 8, &[6, 4, 3], 3, 2, 42);
        let codes = vec![1usize; 8];
        let tr = forward_codes(&m, &codes);
        assert_eq!(tr.codes.len(), 3);
        assert_eq!(tr.codes[0].len(), 6);
        assert_eq!(tr.codes[2].len(), 3);
        // all codes within range
        for (l, cs) in tr.codes.iter().enumerate() {
            let n = 1usize << m.layers[l].act.bits;
            assert!(cs.iter().all(|&c| c < n));
        }
    }

    #[test]
    fn classify_is_deterministic_and_in_range() {
        let m = random_model("t", 8, &[6, 5], 3, 2, 9);
        for s in 0..50u64 {
            let x: Vec<f64> = (0..8).map(|i| ((s as f64) * 0.1 + i as f64 * 0.3).sin()).collect();
            let c = classify(&m, &x);
            assert!(c < 5);
            assert_eq!(c, classify(&m, &x));
        }
    }

    #[test]
    fn accuracy_bounds() {
        let m = random_model("t", 4, &[4, 3], 2, 1, 17);
        let xs: Vec<Vec<f64>> = (0..30)
            .map(|i| (0..4).map(|j| ((i * 7 + j) as f64 * 0.37).cos()).collect())
            .collect();
        let ys: Vec<usize> = xs.iter().map(|x| classify(&m, x)).collect();
        assert_eq!(accuracy(&m, &xs, &ys), 1.0, "self-labels give 100%");
        let wrong: Vec<usize> = ys.iter().map(|&y| (y + 1) % 3).collect();
        assert_eq!(accuracy(&m, &xs, &wrong), 0.0);
    }

    #[test]
    fn bits_roundtrip() {
        let codes = vec![0usize, 1, 2, 3, 1];
        let bits = codes_to_bits(&codes, 2);
        assert_eq!(bits.len(), 10);
        assert_eq!(bits_to_codes(&bits, 2), codes);
        // LSB-first contract: code 2 = bits [0,1]
        assert_eq!(&bits[4..6], &[false, true]);
    }

    #[test]
    fn bitvec_encoding_matches_bool_encoding() {
        let codes = vec![5usize, 0, 3, 7, 2, 6];
        let bools = codes_to_bits(&codes, 3);
        let packed = codes_to_bitvec(&codes, 3);
        assert_eq!(packed.len(), bools.len());
        for (i, &b) in bools.iter().enumerate() {
            assert_eq!(packed.get(i), b, "bit {i}");
        }
    }

    #[test]
    fn quantize_input_standardizes() {
        let mut m = random_model("t", 2, &[2], 2, 2, 1);
        m.feature_mean = vec![10.0, -5.0];
        m.feature_std = vec![2.0, 0.5];
        m.input_quant = Quantizer::signed_uniform(2, 1.0); // levels -2,-1,0,1
        let codes = quantize_input(&m, &[10.0, -5.0]); // z = 0,0
        // z=0 → between levels -1 and 0 → code_of(0.0): thresholds at
        // -1.5,-0.5,0.5 → 0.0 maps to code 2
        assert_eq!(codes, vec![2, 2]);
        let codes2 = quantize_input(&m, &[4.0, -4.0]); // z = -3, +2
        assert_eq!(codes2, vec![0, 3]);
    }
}
