//! Crash-safe artifact store: atomic writes, generation journal, torn-file
//! quarantine.
//!
//! A compiled circuit bundle is the unit of deployment — `nullanet
//! compile` may be killed at any byte, and `serve --models` must still
//! come up with *some* intact generation or say precisely why it cannot.
//! A bare `std::fs::write` to the final path cannot promise that: a crash
//! mid-write leaves a half-JSON file that only fails at the next load.
//! This module is the single write path for bundles and native-cache
//! files, built on three primitives:
//!
//! 1. **Atomic replace** ([`atomic_write`] / [`promote`]): payload goes to
//!    a unique temp file in the destination directory, is `fsync`ed, and
//!    is published with `rename(2)` — readers see the old bytes or the
//!    new bytes, never a mixture. The parent directory is fsynced
//!    best-effort so the rename itself survives power loss.
//! 2. **Generation journal** ([`publish`]): a `<path>.journal` sidecar
//!    records the last two generations as `(gen, len, fnv64)` triples,
//!    and the displaced payload is kept at `<path>.prev`. The journal is
//!    updated *before* the payload rename, so a crash between the two
//!    steps leaves a payload matching the journal's previous entry — an
//!    older consistent state, not an inconsistency.
//! 3. **Verified load with quarantine** ([`load`]): payload bytes are
//!    checked against the journal; a file matching no recorded
//!    generation (a torn legacy write, disk corruption, tampering) is
//!    renamed to `<path>.quarantined` and the previous generation is
//!    restored when it verifies — counted in [`store_recoveries`], which
//!    the metrics report surfaces. Files with no journal load as
//!    generation 0 for compatibility; their validation is the parser's.
//!
//! The [`crate::util::fault`] point `artifact.write` sits on the temp
//! write: an injected fault truncates the temp file and returns an error
//! without renaming, which is exactly what `kill -9` mid-`compile` does.
//! The chaos suite proves no sequence of injected crashes ever makes
//! [`load`] return torn bytes.

use std::fmt;
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::fault;
use crate::util::json::Json;

/// Format tag of the `<path>.journal` sidecar.
pub const JOURNAL_FORMAT: &str = "nullanet-store-journal";
/// Journal version this build reads and writes.
pub const JOURNAL_VERSION: i64 = 1;

/// Typed failure of a store operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    /// Filesystem failure (including injected `artifact.write` faults).
    Io { path: String, msg: String },
    /// The payload matches no journaled generation and no previous
    /// generation could be restored; the torn file was moved aside.
    Torn { path: String, quarantine: String },
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io { path, msg } => write!(f, "{path}: {msg}"),
            StoreError::Torn { path, quarantine } => write!(
                f,
                "{path}: torn artifact quarantined to {quarantine} \
                 (matches no journaled generation; no recoverable previous \
                 generation)"
            ),
        }
    }
}

impl std::error::Error for StoreError {}

fn io_err(path: &str, e: impl fmt::Display) -> StoreError {
    StoreError::Io { path: path.to_string(), msg: e.to_string() }
}

static STORE_RECOVERIES: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of loads that quarantined a torn payload and
/// restored the previous generation. Joins `poison_recoveries` in the
/// metrics resilience report.
pub fn store_recoveries() -> u64 {
    STORE_RECOVERIES.load(Ordering::Relaxed)
}

/// FNV-1a 64 over raw bytes — the journal's integrity check (same
/// algorithm as [`crate::flow::artifact::model_fingerprint`], different
/// domain: file bytes, not model JSON).
pub fn fnv64(bytes: &[u8]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    format!("{h:016x}")
}

/// `<path>.journal` — generation records for `path`.
pub fn journal_path(path: &str) -> String {
    format!("{path}.journal")
}

/// `<path>.prev` — the displaced previous generation's payload.
pub fn prev_path(path: &str) -> String {
    format!("{path}.prev")
}

/// `<path>.quarantined` — where a torn payload is moved aside.
pub fn quarantine_path(path: &str) -> String {
    format!("{path}.quarantined")
}

fn temp_path(path: &str) -> String {
    static N: AtomicU64 = AtomicU64::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    format!("{path}.tmp.{}.{n}", std::process::id())
}

/// Best-effort fsync of `path`'s parent directory, so the rename that
/// just published into it survives power loss. Directory fds are a
/// Linux-ism; failures here degrade durability, not atomicity.
fn sync_parent_dir(path: &str) {
    let parent = std::path::Path::new(path).parent();
    if let Some(dir) = parent.filter(|p| !p.as_os_str().is_empty()) {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
}

/// Write `bytes` to a unique temp file next to `path` and fsync it.
/// Carries the `artifact.write` fault point: an injected fault leaves a
/// *truncated* temp file behind (the on-disk state a mid-write crash
/// produces) and reports failure without touching `path`.
fn write_temp(path: &str, bytes: &[u8]) -> Result<String, StoreError> {
    let tmp = temp_path(path);
    let mut f = std::fs::File::create(&tmp).map_err(|e| io_err(&tmp, e))?;
    if fault::should_fail("artifact.write") {
        let _ = f.write_all(&bytes[..bytes.len() / 2]);
        return Err(StoreError::Io {
            path: path.to_string(),
            msg: format!("injected fault at artifact.write (torn temp left at {tmp})"),
        });
    }
    f.write_all(bytes).map_err(|e| io_err(&tmp, e))?;
    f.sync_all().map_err(|e| io_err(&tmp, e))?;
    Ok(tmp)
}

/// Atomically replace `path` with `bytes`: temp write → fsync → rename.
/// No journal — use [`publish`] for generation-tracked artifacts. This is
/// the right call for derived caches (`.so` sources, `.meta` sidecars)
/// whose loss only costs a rebuild.
pub fn atomic_write(path: &str, bytes: &[u8]) -> Result<(), StoreError> {
    let tmp = write_temp(path, bytes)?;
    std::fs::rename(&tmp, path).map_err(|e| io_err(path, e))?;
    sync_parent_dir(path);
    Ok(())
}

/// Atomically promote an already-written file (e.g. rustc's `.so`
/// output) to its final path: fsync → rename → dir fsync.
pub fn promote(temp: &str, path: &str) -> Result<(), StoreError> {
    let f = std::fs::File::open(temp).map_err(|e| io_err(temp, e))?;
    f.sync_all().map_err(|e| io_err(temp, e))?;
    drop(f);
    std::fs::rename(temp, path).map_err(|e| io_err(path, e))?;
    sync_parent_dir(path);
    Ok(())
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Entry {
    gen: u64,
    len: u64,
    fnv: String,
}

impl Entry {
    fn of(gen: u64, bytes: &[u8]) -> Entry {
        Entry { gen, len: bytes.len() as u64, fnv: fnv64(bytes) }
    }

    fn matches(&self, bytes: &[u8]) -> bool {
        self.len == bytes.len() as u64 && self.fnv == fnv64(bytes)
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("gen", Json::int(self.gen as i64)),
            ("len", Json::int(self.len as i64)),
            ("fnv", Json::str(self.fnv.clone())),
        ])
    }

    fn from_json(j: &Json) -> Option<Entry> {
        Some(Entry {
            gen: j.get("gen")?.as_i64().filter(|&g| g >= 0)? as u64,
            len: j.get("len")?.as_i64().filter(|&l| l >= 0)? as u64,
            fnv: j.get("fnv")?.as_str()?.to_string(),
        })
    }
}

/// Read the journal for `path`. `None` when absent or unreadable — a
/// missing journal means "legacy file, no integrity claim", and a
/// corrupt journal must not brick an intact payload.
fn read_journal(path: &str) -> Option<Vec<Entry>> {
    let text = std::fs::read_to_string(journal_path(path)).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.get("format").and_then(|v| v.as_str()) != Some(JOURNAL_FORMAT) {
        return None;
    }
    if j.get("version").and_then(|v| v.as_i64()) != Some(JOURNAL_VERSION) {
        return None;
    }
    let entries = j.get("entries")?.as_arr()?;
    let parsed: Vec<Entry> = entries.iter().filter_map(Entry::from_json).collect();
    if parsed.len() == entries.len() {
        Some(parsed)
    } else {
        None
    }
}

fn write_journal(path: &str, entries: &[Entry]) -> Result<(), StoreError> {
    let j = Json::obj([
        ("format", Json::str(JOURNAL_FORMAT)),
        ("version", Json::int(JOURNAL_VERSION)),
        ("entries", Json::Arr(entries.iter().map(Entry::to_json).collect())),
    ]);
    atomic_write(&journal_path(path), j.to_string().as_bytes())
}

/// The journaled generation `path` currently claims, if any.
pub fn generation(path: &str) -> Option<u64> {
    read_journal(path)?.last().map(|e| e.gen)
}

/// A verified payload returned by [`load`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Loaded {
    /// The verified payload bytes.
    pub bytes: Vec<u8>,
    /// Journal generation the bytes matched (0 for legacy un-journaled
    /// files).
    pub generation: u64,
    /// Whether the current payload was torn and these bytes were
    /// restored from the previous generation.
    pub recovered: bool,
}

/// Publish a new generation of `path`: temp write → keep the displaced
/// payload at `<path>.prev` → journal update (old + new entries) →
/// payload rename. A crash at any step leaves `path` matching some
/// journal entry, so [`load`] always finds a consistent generation.
pub fn publish(path: &str, bytes: &[u8]) -> Result<u64, StoreError> {
    let entries = read_journal(path).unwrap_or_default();
    let current = entries.last().cloned();
    let next_gen = current.as_ref().map_or(1, |e| e.gen + 1);
    let tmp = write_temp(path, bytes)?;

    // Preserve the displaced generation before the rename clobbers it.
    // A legacy file (no journal) is journaled as generation 0 so it stays
    // loadable — and recoverable — after this publish. A payload that
    // mismatches its own journal is already torn: keep the bytes aside
    // but do not journal them as a valid generation.
    let mut new_entries: Vec<Entry> = Vec::with_capacity(2);
    if let Ok(old_bytes) = std::fs::read(path) {
        let old_entry = match current {
            Some(e) if e.matches(&old_bytes) => Some(e),
            Some(_) => None,
            None => Some(Entry::of(next_gen - 1, &old_bytes)),
        };
        let prev = prev_path(path);
        std::fs::copy(path, &prev).map_err(|e| io_err(&prev, e))?;
        if let Ok(p) = std::fs::File::open(&prev) {
            let _ = p.sync_all();
        }
        new_entries.extend(old_entry);
    }
    new_entries.push(Entry::of(next_gen, bytes));

    write_journal(path, &new_entries)?;
    std::fs::rename(&tmp, path).map_err(|e| io_err(path, e))?;
    sync_parent_dir(path);
    Ok(next_gen)
}

/// Load and verify `path` against its journal. Torn payloads are
/// quarantined to `<path>.quarantined`; if `<path>.prev` verifies
/// against the journal it is restored (and counted in
/// [`store_recoveries`]), otherwise the load fails typed with
/// [`StoreError::Torn`] — never a parse of half-written bytes.
pub fn load(path: &str) -> Result<Loaded, StoreError> {
    let bytes = std::fs::read(path).map_err(|e| io_err(path, e))?;
    let Some(entries) = read_journal(path) else {
        return Ok(Loaded { bytes, generation: 0, recovered: false });
    };
    if let Some(e) = entries.iter().find(|e| e.matches(&bytes)) {
        return Ok(Loaded { bytes, generation: e.gen, recovered: false });
    }

    // Torn: move the payload aside, then try the previous generation.
    let quarantine = quarantine_path(path);
    std::fs::rename(path, &quarantine).map_err(|e| io_err(&quarantine, e))?;
    let prev = prev_path(path);
    if let Ok(prev_bytes) = std::fs::read(&prev) {
        if let Some(e) = entries.iter().find(|e| e.matches(&prev_bytes)) {
            // Restore without consulting the fault point: the recoverer
            // is the loader, not the (possibly crashing) writer.
            let tmp = temp_path(path);
            let write_back = std::fs::write(&tmp, &prev_bytes)
                .and_then(|()| std::fs::rename(&tmp, path));
            write_back.map_err(|er| io_err(path, er))?;
            sync_parent_dir(path);
            STORE_RECOVERIES.fetch_add(1, Ordering::Relaxed);
            return Ok(Loaded { bytes: prev_bytes, generation: e.gen, recovered: true });
        }
    }
    Err(StoreError::Torn { path: path.to_string(), quarantine })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> String {
        static N: AtomicU64 = AtomicU64::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("nnt-store-test-{}-{tag}-{n}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn atomic_write_round_trips() {
        let dir = tmp_dir("aw");
        let p = format!("{dir}/x.bin");
        atomic_write(&p, b"hello").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"hello");
        atomic_write(&p, b"world!").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"world!");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn publish_journals_generations_and_keeps_prev() {
        let dir = tmp_dir("gen");
        let p = format!("{dir}/a.json");
        assert_eq!(publish(&p, b"gen-one").unwrap(), 1);
        assert_eq!(publish(&p, b"gen-two").unwrap(), 2);
        assert_eq!(generation(&p), Some(2));
        assert_eq!(std::fs::read(prev_path(&p)).unwrap(), b"gen-one");
        let l = load(&p).unwrap();
        assert_eq!((l.bytes.as_slice(), l.generation, l.recovered), (&b"gen-two"[..], 2, false));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_payload_is_quarantined_and_prev_restored() {
        let dir = tmp_dir("torn");
        let p = format!("{dir}/a.json");
        publish(&p, b"first-generation").unwrap();
        publish(&p, b"second-generation").unwrap();
        // Tear the payload the way a crashed legacy writer would.
        std::fs::write(&p, b"second-gen").unwrap();
        let before = store_recoveries();
        let l = load(&p).unwrap();
        assert!(l.recovered);
        assert_eq!(l.bytes, b"first-generation");
        assert_eq!(l.generation, 1);
        assert_eq!(store_recoveries(), before + 1);
        // The torn bytes were preserved for inspection, and the restored
        // payload now loads clean.
        assert_eq!(std::fs::read(quarantine_path(&p)).unwrap(), b"second-gen");
        assert!(!load(&p).unwrap().recovered);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_payload_without_prev_fails_typed() {
        let dir = tmp_dir("noprev");
        let p = format!("{dir}/a.json");
        publish(&p, b"only-generation").unwrap();
        std::fs::remove_file(prev_path(&p)).ok();
        std::fs::write(&p, b"only-gen").unwrap();
        let err = load(&p).unwrap_err();
        match &err {
            StoreError::Torn { quarantine, .. } => {
                assert_eq!(std::fs::read(quarantine).unwrap(), b"only-gen");
            }
            other => panic!("expected Torn, got {other:?}"),
        }
        assert!(err.to_string().contains("quarantined"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_file_without_journal_loads_as_generation_zero() {
        let dir = tmp_dir("legacy");
        let p = format!("{dir}/old.json");
        std::fs::write(&p, b"pre-store artifact").unwrap();
        let l = load(&p).unwrap();
        assert_eq!((l.generation, l.recovered), (0, false));
        assert_eq!(l.bytes, b"pre-store artifact");
        // Publishing over it journals the legacy bytes as generation 0.
        publish(&p, b"journaled now").unwrap();
        std::fs::write(&p, b"torn").unwrap();
        let l = load(&p).unwrap();
        assert!(l.recovered);
        assert_eq!(l.bytes, b"pre-store artifact");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_journal_does_not_brick_an_intact_payload() {
        let dir = tmp_dir("cj");
        let p = format!("{dir}/a.json");
        publish(&p, b"payload").unwrap();
        std::fs::write(journal_path(&p), b"{ not json").unwrap();
        let l = load(&p).unwrap();
        assert_eq!((l.bytes.as_slice(), l.generation), (&b"payload"[..], 0));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
