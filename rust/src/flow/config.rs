//! Flow configuration.

/// Options controlling the NullaNet Tiny synthesis flow. Every switch maps
/// to an ablation bench (DESIGN.md §6 A1/A3).
#[derive(Clone, Copy, Debug)]
pub struct FlowConfig {
    /// LUT input count of the target fabric (VU9P: 6).
    pub lut_k: usize,
    /// Run ESPRESSO-II two-level minimization (off → raw ISOP covers, the
    /// "no-espresso" ablation).
    pub use_espresso: bool,
    /// Run min-period retiming after mapping.
    pub retime: bool,
    /// Derive don't-cares from observed training activations (original
    /// NullaNet mode; NullaNet Tiny enumerates fully).
    pub dc_from_data: bool,
    /// Worker threads for per-neuron synthesis.
    pub jobs: usize,
    /// Area-oriented (instead of depth-oriented) LUT mapping.
    pub map_for_area: bool,
    /// Verify every neuron cone exhaustively and the full circuit by
    /// sampling after synthesis.
    pub verify: bool,
}

impl Default for FlowConfig {
    fn default() -> Self {
        FlowConfig {
            lut_k: 6,
            use_espresso: true,
            retime: true,
            dc_from_data: false,
            jobs: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            map_for_area: false,
            verify: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_flow() {
        let c = FlowConfig::default();
        assert_eq!(c.lut_k, 6);
        assert!(c.use_espresso);
        assert!(c.retime);
        assert!(!c.dc_from_data);
        assert!(c.verify);
        assert!(c.jobs >= 1);
    }
}
