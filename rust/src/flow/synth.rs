//! Per-neuron synthesis: enumeration → (ESPRESSO) → covers.
//!
//! The unit of parallel work in the flow: each neuron's truth tables are
//! minimized independently on the thread pool, then assembled into per-layer
//! AIGs by [`crate::flow::build`].

use crate::logic::cube::Cover;
use crate::logic::espresso::{minimize_tt, EspressoStats};
use crate::logic::truthtable::TruthTable;
use crate::nn::enumerate::{enumerate_neuron, NeuronFunction};
use crate::nn::model::Model;

/// Minimized function of one neuron: one SOP per output bit.
#[derive(Clone, Debug)]
pub struct SynthesizedNeuron {
    pub layer: usize,
    pub neuron: usize,
    /// Covers over the neuron's `fanin · in_bits` local variables.
    pub covers: Vec<Cover>,
    /// The enumerated ON tables (kept for verification).
    pub on: Vec<TruthTable>,
    /// DC table used.
    pub dc: TruthTable,
    /// Aggregated minimization statistics.
    pub cubes_before: usize,
    pub cubes_after: usize,
    pub espresso_iterations: usize,
}

/// Synthesize one neuron: enumerate and minimize each output bit.
pub fn synthesize_neuron(
    model: &Model,
    layer: usize,
    neuron: usize,
    observed: Option<&[bool]>,
    use_espresso: bool,
) -> SynthesizedNeuron {
    let f: NeuronFunction = enumerate_neuron(model, layer, neuron, observed);
    let mut covers = Vec::with_capacity(f.on.len());
    let mut cubes_before = 0usize;
    let mut cubes_after = 0usize;
    let mut iterations = 0usize;
    for on in &f.on {
        // Skip the (expensive) ESPRESSO loop when even an optimal SOP
        // cannot beat the Shannon mux-tree bound the hybrid synthesizer
        // will take instead: the seed ISOP is a valid cover either way.
        // ESPRESSO rarely shrinks a cover below ~40% of its ISOP, so a
        // seed 3× past the bound is hopeless — measured 1.9× flow speedup
        // on JSC-L with zero LUT-count change (EXPERIMENTS.md §Perf).
        let run_espresso = if use_espresso {
            let seed_len_bound = 3 * crate::baseline::logicnets::lut_cost_per_bit(
                on.nvars(),
                6,
            );
            TruthTable::isop(on, &f.dc).len() * 6 / 5 <= seed_len_bound
        } else {
            false
        };
        if run_espresso {
            let (cover, st): (Cover, EspressoStats) = minimize_tt(on, &f.dc);
            cubes_before += st.initial_cubes;
            cubes_after += st.final_cubes;
            iterations += st.iterations;
            covers.push(cover);
        } else {
            let cover = TruthTable::isop(on, &f.dc);
            cubes_before += cover.len();
            cubes_after += cover.len();
            covers.push(cover);
        }
    }
    SynthesizedNeuron {
        layer,
        neuron,
        covers,
        on: f.on,
        dc: f.dc,
        cubes_before,
        cubes_after,
        espresso_iterations: iterations,
    }
}

/// Verify the minimized covers against the enumerated tables:
/// `on ⊆ cover ⊆ on ∪ dc` for every output bit. Returns an error string on
/// the first violation.
pub fn verify_neuron(s: &SynthesizedNeuron) -> Result<(), String> {
    for (b, (cover, on)) in s.covers.iter().zip(&s.on).enumerate() {
        let ctt = TruthTable::from_cover(cover);
        if !on.implies(&ctt) {
            return Err(format!(
                "layer {} neuron {} bit {b}: cover misses ON minterms",
                s.layer, s.neuron
            ));
        }
        let upper = on.or(&s.dc);
        if !ctt.implies(&upper) {
            return Err(format!(
                "layer {} neuron {} bit {b}: cover exceeds ON ∪ DC",
                s.layer, s.neuron
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::random_model;

    #[test]
    fn synthesized_neuron_is_equivalent() {
        let m = random_model("t", 6, &[4, 3], 3, 2, 77);
        for layer in 0..2 {
            for neuron in 0..m.layers[layer].out_width {
                let s = synthesize_neuron(&m, layer, neuron, None, true);
                verify_neuron(&s).unwrap();
                // With no DC the cover must equal ON exactly.
                for (cover, on) in s.covers.iter().zip(&s.on) {
                    assert_eq!(&TruthTable::from_cover(cover), on);
                }
            }
        }
    }

    #[test]
    fn espresso_not_worse_than_isop() {
        let m = random_model("t", 8, &[5], 4, 2, 13);
        for neuron in 0..5 {
            let a = synthesize_neuron(&m, 0, neuron, None, true);
            let b = synthesize_neuron(&m, 0, neuron, None, false);
            let ca: usize = a.covers.iter().map(|c| c.len()).sum();
            let cb: usize = b.covers.iter().map(|c| c.len()).sum();
            assert!(ca <= cb, "espresso {ca} vs isop {cb}");
        }
    }

    #[test]
    fn dc_enables_smaller_covers() {
        let m = random_model("t", 6, &[4], 3, 2, 21);
        // Observed: only half the patterns.
        let bits = m.layers[0].mask[0].len() * m.input_quant.bits;
        let observed: Vec<bool> = (0..1usize << bits).map(|i| i % 2 == 0).collect();
        let with_dc = synthesize_neuron(&m, 0, 0, Some(&observed), true);
        let without = synthesize_neuron(&m, 0, 0, None, true);
        verify_neuron(&with_dc).unwrap();
        let a: usize = with_dc.covers.iter().map(|c| c.literal_count()).sum();
        let b: usize = without.covers.iter().map(|c| c.literal_count()).sum();
        assert!(a <= b, "DC must not increase literal cost ({a} vs {b})");
    }
}
