//! The NullaNet Tiny flow: quantized model → fixed-function combinational
//! logic (Fig. 1 of the paper).
//!
//! * [`config`] — flow switches (every one has an ablation bench)
//! * [`synth`] — per-neuron enumeration + ESPRESSO
//! * [`build`] — layer AIGs, LUT mapping, stitching, retiming, verification
//! * [`artifact`] — persistent compiled-circuit files (`nullanet compile` /
//!   `--circuit`), fingerprint-bound to the model
//! * [`store`] — crash-safe artifact store: atomic replace, generation
//!   journal, torn-file quarantine (every bundle/cache write goes here)

pub mod artifact;
pub mod build;
pub mod config;
pub mod store;
pub mod synth;

pub use artifact::ArtifactError;
pub use build::{circuit_accuracy, run_flow, FlowResult};
pub use config::FlowConfig;
