//! Whole-model flow: per-neuron synthesis → per-layer AIG → LUT mapping →
//! stitching → retiming → verification — Fig. 1's logic-minimization module
//! end to end.
//!
//! Each layer becomes one AIG whose inputs are the layer's input bits and
//! whose outputs are its neurons' activation-code bits; structural hashing
//! inside the layer shares logic *across neurons*. Layers are mapped to
//! 6-LUTs independently (register boundaries must not be crossed by LUT
//! cones), stitched into one flat [`PipelinedCircuit`] with one stage per
//! layer, and finally retimed to minimum period.

use std::sync::Arc;

use crate::error::NnError;
use crate::flow::config::FlowConfig;
use crate::flow::synth::{synthesize_neuron, verify_neuron, SynthesizedNeuron};
use crate::logic::aig::Aig;
use crate::logic::mapper::{map_aig, MapConfig};
use crate::logic::netlist::{LutNetlist, PipelinedCircuit, Sig};
use crate::logic::opt::{self, OptStats};
use crate::logic::retime::retime_min_period;
use crate::nn::enumerate::{check_layer_enum_bounds, observed_patterns};
use crate::nn::eval::{bits_to_codes, codes_to_bits, forward_codes, quantize_input, Trace};
use crate::nn::model::Model;
use crate::util::threadpool::ThreadPool;
use crate::util::timer::StageTimer;

/// Everything the flow produced for one model.
pub struct FlowResult {
    /// The final (retimed) pipelined circuit.
    pub circuit: PipelinedCircuit,
    /// Circuit before retiming (for the A3 ablation).
    pub circuit_preretime: PipelinedCircuit,
    /// Aggregate ESPRESSO statistics.
    pub total_cubes_before: usize,
    pub total_cubes_after: usize,
    /// Aggregate compile-time netlist-optimizer statistics (summed over the
    /// per-layer [`crate::logic::opt::optimize`] passes).
    pub opt: OptStats,
    /// Per-stage wall-clock of the flow (Fig. 1 stage log).
    pub timer: StageTimer,
    /// Number of neurons synthesized.
    pub neurons: usize,
}

/// Run the full flow on a model. `dc_traces` supplies training inputs when
/// `config.dc_from_data` is set.
pub fn run_flow(
    model: &Model,
    config: &FlowConfig,
    dc_traces: Option<&[Vec<f64>]>,
) -> Result<FlowResult, NnError> {
    model.validate().map_err(NnError::Flow)?;

    // Enumeration feasibility, checked up front: every neuron's
    // fanin · in_bits must fit the 2^MAX_ENUM_BITS tables that both the
    // DC observation pass and the exhaustive enumeration allocate. A
    // wide-fanin model must come back as a typed flow error here, not as
    // an OOM in `observed_patterns` or an assert deep in a worker thread.
    for l in 0..model.layers.len() {
        check_layer_enum_bounds(model, l).map_err(NnError::Flow)?;
    }
    let mut timer = StageTimer::new();

    // ---- optional data-derived don't-cares ----
    let observed: Option<Vec<Vec<Vec<bool>>>> = if config.dc_from_data {
        let xs = dc_traces
            .ok_or_else(|| NnError::Flow("dc_from_data requires training inputs".into()))?;
        Some(
            timer
                .time("observe", || -> Result<Vec<Vec<Vec<bool>>>, String> {
                    let traces: Vec<Trace> = xs
                        .iter()
                        .map(|x| forward_codes(model, &quantize_input(model, x)))
                        .collect();
                    (0..model.layers.len())
                        .map(|l| observed_patterns(model, l, &traces))
                        .collect()
                })
                .map_err(NnError::Flow)?,
        )
    } else {
        None
    };

    // ---- per-neuron synthesis (parallel) ----
    let jobs: Vec<(usize, usize)> = model
        .layers
        .iter()
        .enumerate()
        .flat_map(|(l, layer)| (0..layer.out_width).map(move |n| (l, n)))
        .collect();
    let neurons = jobs.len();
    let model_arc = Arc::new(model.clone());
    let observed_arc = Arc::new(observed);
    let use_espresso = config.use_espresso;
    let synthesized: Vec<SynthesizedNeuron> = timer.time("enumerate+espresso", || {
        let pool = ThreadPool::new(config.jobs);
        let model = Arc::clone(&model_arc);
        let obs = Arc::clone(&observed_arc);
        pool.par_map(jobs, move |(l, n)| {
            let o = obs.as_ref().as_ref().map(|per_layer| per_layer[l][n].as_slice());
            synthesize_neuron(&model, l, n, o, use_espresso)
        })
    });

    if config.verify {
        timer
            .time("verify-covers", || -> Result<(), String> {
                for s in &synthesized {
                    verify_neuron(s)?;
                }
                Ok(())
            })
            .map_err(NnError::Flow)?;
    }

    // ---- per-layer AIG + mapping ----
    let map_cfg = MapConfig {
        k: config.lut_k,
        sort_by_area: config.map_for_area,
        ..Default::default()
    };
    let mut layer_netlists: Vec<LutNetlist> = Vec::with_capacity(model.layers.len());
    let mut preopt_netlists: Vec<LutNetlist> = Vec::new();
    let mut opt_total = OptStats::default();
    timer.time("aig+map", || {
        for (l, layer) in model.layers.iter().enumerate() {
            let in_bits_per = model.in_quant_of_layer(l).bits;
            let out_bits_per = layer.act.bits;
            let num_in_bits = layer.in_width * in_bits_per;
            let mut aig = Aig::new();
            let input_lits: Vec<_> = (0..num_in_bits).map(|_| aig.add_input()).collect();
            let mut out_lits = vec![0u32; layer.out_width * out_bits_per];
            for s in synthesized.iter().filter(|s| s.layer == l) {
                // Map cover variable i·in_bits_per + b → global input bit
                // mask[i]·in_bits_per + b.
                let mask = &layer.mask[s.neuron];
                let var_lits: Vec<_> = mask
                    .iter()
                    .flat_map(|&src| {
                        (0..in_bits_per).map(move |b| src * in_bits_per + b)
                    })
                    .map(|w| input_lits[w])
                    .collect();
                for (b, cover) in s.covers.iter().enumerate() {
                    // Hybrid synthesis: a minimized SOP is the right
                    // structure for the simple functions trained, pruned
                    // neurons compute (few cubes after ESPRESSO), but dense
                    // functions are cheaper as a Shannon mux tree over the
                    // raw table (the LogicNets bound). Estimate mapped LUTs
                    // for both and take the smaller: an SOP maps to roughly
                    // one LUT per cube plus an OR tree (×6/5), a mux tree to
                    // `lut_cost_per_bit` exactly.
                    let sop_lut_est = cover.len() * 6 / 5;
                    let mux_luts = crate::baseline::logicnets::lut_cost_per_bit(
                        cover.nvars(),
                        config.lut_k,
                    );
                    let lit = if cover.nvars() <= config.lut_k || sop_lut_est <= mux_luts
                    {
                        aig.from_cover(cover, &var_lits)
                    } else {
                        mux_tree(&mut aig, &s.on[b], &var_lits)
                    };
                    out_lits[s.neuron * out_bits_per + b] = lit;
                }
            }
            for lit in out_lits {
                aig.add_output(lit);
            }
            let mapped = map_aig(&aig.sweep(), &map_cfg);
            // Compile-time netlist optimizer, per layer (stage boundaries
            // must survive, so cross-layer sharing is left to the purely
            // combinational simulator compile): constant folding,
            // structural dedup, dead-LUT sweep. Every persisted artifact
            // and emitted netlist shrinks, not just the serving engine.
            let (optimized, ostats) = opt::optimize(&mapped.netlist);
            opt_total.absorb(&ostats);
            if config.verify {
                preopt_netlists.push(mapped.netlist);
            }
            layer_netlists.push(optimized);
        }
    });

    // ---- SAT proof that the optimizer preserved each layer ----
    // The sampled/exhaustive differential checks below only cover the final
    // stitched circuit; this proves every `opt::optimize` output equivalent
    // to its pre-optimization input at full input width.
    if config.verify {
        timer
            .time("verify-opt-cec", || -> Result<(), String> {
                for (l, (pre, post)) in
                    preopt_netlists.iter().zip(&layer_netlists).enumerate()
                {
                    match crate::logic::cec::check_netlists(pre, post) {
                        Ok(crate::logic::cec::CecResult::Equivalent) => {}
                        Ok(crate::logic::cec::CecResult::Inequivalent {
                            assignment,
                            output,
                        }) => {
                            let bits: String = assignment
                                .iter()
                                .map(|&b| if b { '1' } else { '0' })
                                .collect();
                            return Err(format!(
                                "layer {l}: optimizer changed output {output} \
                                 (witness inputs, bit 0 first: {bits})"
                            ));
                        }
                        Err(e) => return Err(format!("layer {l}: cec: {e}")),
                    }
                }
                Ok(())
            })
            .map_err(NnError::Flow)?;
    }

    // ---- stitch layers into one pipelined circuit ----
    let (flat, stages) = timer.time("stitch", || stitch_layers(model, &layer_netlists));
    let circuit_preretime = PipelinedCircuit {
        netlist: flat,
        stage_of_lut: stages,
        num_stages: model.layers.len() as u32,
    };
    circuit_preretime
        .check_stages()
        .map_err(|e| NnError::Flow(format!("stitch: {e}")))?;

    // ---- retime ----
    let circuit = if config.retime {
        timer.time("retime", || retime_min_period(&circuit_preretime).0)
    } else {
        circuit_preretime.clone()
    };

    // ---- verification against the quantized NN ----
    if config.verify {
        timer.time("verify-circuit", || verify_circuit(model, &circuit, 512, 0xC0DE))?;
    }

    let total_cubes_before = synthesized.iter().map(|s| s.cubes_before).sum();
    let total_cubes_after = synthesized.iter().map(|s| s.cubes_after).sum();
    Ok(FlowResult {
        circuit,
        circuit_preretime,
        total_cubes_before,
        total_cubes_after,
        opt: opt_total,
        timer,
        neurons,
    })
}

/// Shannon mux-tree construction of a dense table over `var_lits` (the
/// fallback arm of hybrid synthesis). Memoized on sub-table equality so
/// shared cofactors collapse; structural hashing inside the AIG dedupes the
/// rest.
fn mux_tree(
    aig: &mut Aig,
    table: &crate::logic::truthtable::TruthTable,
    var_lits: &[u32],
) -> u32 {
    use crate::logic::truthtable::TruthTable;
    use std::collections::HashMap;
    fn rec(
        aig: &mut Aig,
        t: &TruthTable,
        lits: &[u32],
        memo: &mut HashMap<TruthTable, u32>,
    ) -> u32 {
        if t.is_zero() {
            return crate::logic::aig::LIT_FALSE;
        }
        if t.is_ones() {
            return crate::logic::aig::LIT_TRUE;
        }
        if let Some(&l) = memo.get(t) {
            return l;
        }
        let top = t.nvars() - 1;
        let (c0, c1) = t.cofactors(top);
        // Restrict away the (now-irrelevant) top variable (word-level).
        let c0r = c0.shrink_top();
        let c1r = c1.shrink_top();
        let lo = rec(aig, &c0r, &lits[..top], memo);
        let hi = rec(aig, &c1r, &lits[..top], memo);
        let out = aig.mux(lits[top], hi, lo);
        memo.insert(t.clone(), out);
        out
    }
    let mut memo = HashMap::new();
    rec(aig, table, var_lits, &mut memo)
}

/// Combine per-layer netlists into one flat netlist with a stage per layer.
/// Inverted inter-layer signals are absorbed into consumer LUT tables.
fn stitch_layers(model: &Model, layers: &[LutNetlist]) -> (LutNetlist, Vec<u32>) {
    let mut flat = LutNetlist::new(model.input_bits());
    let mut stages: Vec<u32> = Vec::new();
    // wire map: current layer's input wire -> (flat signal, inverted)
    let mut wires: Vec<(Sig, bool)> = (0..model.input_bits())
        .map(|i| (Sig::Input(i as u32), false))
        .collect();

    for (l, nl) in layers.iter().enumerate() {
        assert_eq!(nl.num_inputs, wires.len(), "layer {l} input width mismatch");
        // local LUT index -> flat signal (with inversion always false: we
        // rewrite tables instead)
        let mut local: Vec<Sig> = Vec::with_capacity(nl.luts.len());
        for lut in &nl.luts {
            let mut table = lut.table.clone();
            let mut inputs: Vec<Sig> = Vec::with_capacity(lut.inputs.len());
            for (v, s) in lut.inputs.iter().enumerate() {
                let (sig, inv) = match s {
                    Sig::Input(w) => wires[*w as usize],
                    Sig::Lut(j) => (local[*j as usize], false),
                    Sig::Const(b) => (Sig::Const(*b), false),
                };
                if inv {
                    table = table.invert_var(v);
                }
                inputs.push(sig);
            }
            let sig = flat.add_lut(inputs, table);
            local.push(sig);
            stages.push(l as u32);
        }
        // next layer's wires = this layer's outputs
        wires = nl
            .outputs
            .iter()
            .map(|(s, inv)| match s {
                Sig::Input(w) => {
                    let (sig, winv) = wires[*w as usize];
                    (sig, winv ^ inv)
                }
                Sig::Lut(j) => (local[*j as usize], *inv),
                Sig::Const(b) => (Sig::Const(*b), *inv),
            })
            .collect();
    }
    for (sig, inv) in wires {
        flat.add_output(sig, inv);
    }
    (flat, stages)
}

/// Sample `n` random feature vectors; check the circuit's output codes match
/// the exact integer NN on every one.
pub fn verify_circuit(
    model: &Model,
    circuit: &PipelinedCircuit,
    n: usize,
    seed: u64,
) -> Result<(), NnError> {
    use crate::util::prng::Xoshiro256;
    let mut rng = Xoshiro256::new(seed);
    let sim = crate::logic::sim::CompiledNetlist::compile(&circuit.netlist);
    let out_bits_per = model.layers.last().unwrap().act.bits;
    for i in 0..n {
        let x: Vec<f64> = (0..model.input_features)
            .map(|_| 3.0 * rng.next_gaussian())
            .collect();
        let in_codes = quantize_input(model, &x);
        let tr = forward_codes(model, &in_codes);
        let want = tr.codes.last().unwrap();
        let in_bits = codes_to_bits(&in_codes, model.input_quant.bits);
        let got_bits = sim.run_batch(&[in_bits]).pop().unwrap();
        let got = bits_to_codes(&got_bits, out_bits_per);
        if &got != want {
            return Err(NnError::Flow(format!(
                "circuit mismatch on sample {i}: got {got:?}, want {want:?}"
            )));
        }
    }
    Ok(())
}

/// Classify a batch of feature vectors with the logic circuit; returns
/// predictions. Offline evaluation path (accuracy sweeps, ablations); the
/// serving path uses [`classify_packed`] on packed simulator output.
pub fn classify_batch(
    model: &Model,
    sim: &crate::logic::sim::CompiledNetlist,
    xs: &[Vec<f64>],
) -> Vec<usize> {
    let in_b = model.input_quant.bits;
    let out_b = model.layers.last().unwrap().act.bits;
    let samples: Vec<Vec<bool>> = xs
        .iter()
        .map(|x| codes_to_bits(&quantize_input(model, x), in_b))
        .collect();
    let outs = sim.run_batch(&samples);
    outs.iter()
        .map(|bits| {
            let codes = bits_to_codes(bits, out_b);
            crate::nn::eval::classify_codes(model, &codes)
        })
        .collect()
}

/// Classify every sample of a packed simulator output batch, decoding the
/// last layer's activation codes straight from the packed words — no
/// per-sample buffers anywhere (the serving hot path's reply side).
/// Tie-breaking matches [`crate::nn::eval::classify_codes`] (first max).
pub fn classify_packed(
    model: &Model,
    outputs: &crate::util::bitvec::PackedBatch,
) -> Vec<usize> {
    let last = model.layers.last().unwrap();
    let out_b = last.act.bits;
    // Real check, not debug_assert: this is a public entry point on the
    // serving path, and a width mismatch must fail loudly in release builds
    // too (PR 1 policy), never decode garbage lanes.
    assert_eq!(
        outputs.num_signals(),
        last.out_width * out_b,
        "classify_packed: batch carries {} output signals, model expects {} ({} neurons × {} bits)",
        outputs.num_signals(),
        last.out_width * out_b,
        last.out_width,
        out_b
    );
    classify_packed_words(model, outputs.words(), outputs.num_samples())
}

/// [`classify_packed`] over raw group-major output words (as produced by
/// [`crate::logic::sim::CompiledNetlist::run_packed_into`] and
/// [`crate::logic::sim::ShardRunner::run`]) — the zero-allocation serving
/// path decodes the engine's reusable buffer without ever materializing a
/// `PackedBatch`. Lanes at or beyond `samples` in the last group are
/// ignored, so tail-lane garbage cannot leak into predictions.
pub fn classify_packed_words(model: &Model, words: &[u64], samples: usize) -> Vec<usize> {
    let last = model.layers.last().unwrap();
    let q = &last.act;
    let out_b = q.bits;
    let signals = last.out_width * out_b;
    assert_eq!(
        words.len(),
        samples.div_ceil(64) * signals,
        "classify_packed_words: {} words for {} samples × {} output signals",
        words.len(),
        samples,
        signals
    );
    // The code → value table (2^bits entries) is exactly the quantizer's
    // level array; bind it once instead of calling `q.value_of(code)` per
    // class per sample.
    let values: &[f64] = &q.levels;
    (0..samples)
        .map(|s| {
            let base = (s >> 6) * signals;
            let lane = s & 63;
            let mut best = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            for n in 0..model.num_classes {
                let mut code = 0usize;
                for b in 0..out_b {
                    if (words[base + n * out_b + b] >> lane) & 1 == 1 {
                        code |= 1 << b;
                    }
                }
                let v = values[code];
                if v > best_v {
                    best_v = v;
                    best = n;
                }
            }
            best
        })
        .collect()
}

/// Accuracy of the circuit on a labelled dataset.
pub fn circuit_accuracy(
    model: &Model,
    circuit: &PipelinedCircuit,
    xs: &[Vec<f64>],
    ys: &[usize],
) -> f64 {
    let sim = crate::logic::sim::CompiledNetlist::compile(&circuit.netlist);
    let preds = classify_batch(model, &sim, xs);
    let correct = preds.iter().zip(ys).filter(|(p, y)| p == y).count();
    correct as f64 / ys.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::random_model;

    fn tiny_model(seed: u64) -> Model {
        random_model("tiny", 5, &[4, 3], 2, 1, seed)
    }

    #[test]
    fn flow_produces_verified_circuit() {
        let m = tiny_model(42);
        let cfg = FlowConfig { jobs: 2, ..Default::default() };
        let r = run_flow(&m, &cfg, None).unwrap();
        assert!(r.circuit.netlist.num_luts() > 0);
        assert_eq!(r.circuit.num_stages, 2);
        assert!(r.circuit.check_stages().is_ok());
        assert_eq!(r.neurons, 7);
        // Exhaustive over all 2^5 input-bit patterns (5 features × 1 bit).
        let sim = crate::logic::sim::CompiledNetlist::compile(&r.circuit.netlist);
        for m_bits in 0..1u64 << 5 {
            let in_codes: Vec<usize> =
                (0..5).map(|i| ((m_bits >> i) & 1) as usize).collect();
            let tr = forward_codes(&m, &in_codes);
            let want = tr.codes.last().unwrap();
            let in_bools: Vec<bool> = (0..5).map(|i| (m_bits >> i) & 1 == 1).collect();
            let got_bits = sim.run_batch(&[in_bools]).pop().unwrap();
            let got = bits_to_codes(&got_bits, m.layers[1].act.bits);
            assert_eq!(&got, want, "m_bits={m_bits}");
        }
    }

    #[test]
    fn retime_does_not_change_function() {
        let m = tiny_model(7);
        let base = FlowConfig { retime: false, jobs: 1, ..Default::default() };
        let rt = FlowConfig { retime: true, jobs: 1, ..Default::default() };
        let a = run_flow(&m, &base, None).unwrap();
        let b = run_flow(&m, &rt, None).unwrap();
        // same netlist function; retimed depth ≤ original
        assert!(
            b.circuit.stats().max_stage_depth <= a.circuit.stats().max_stage_depth
        );
        for bits in 0..32u64 {
            assert_eq!(a.circuit.eval(bits), b.circuit.eval(bits));
        }
    }

    #[test]
    fn espresso_reduces_or_matches_luts() {
        let m = random_model("cmp", 6, &[5, 3], 3, 2, 99);
        let with = FlowConfig { use_espresso: true, jobs: 1, ..Default::default() };
        let without = FlowConfig { use_espresso: false, jobs: 1, ..Default::default() };
        let a = run_flow(&m, &with, None).unwrap();
        let b = run_flow(&m, &without, None).unwrap();
        assert!(a.total_cubes_after <= b.total_cubes_after);
        // LUT count usually improves; must never be dramatically worse.
        // (Slack covers mapping noise plus the compile-time netlist
        // optimizer, which now runs on both sides and can shift the
        // comparison by a couple of LUTs either way.)
        assert!(
            a.circuit.netlist.num_luts() <= b.circuit.netlist.num_luts() + 4,
            "espresso {} vs isop {}",
            a.circuit.netlist.num_luts(),
            b.circuit.netlist.num_luts()
        );
    }

    #[test]
    fn dc_from_data_flow_stays_consistent_on_observed_inputs() {
        let m = tiny_model(3);
        let xs: Vec<Vec<f64>> = (0..40)
            .map(|i| (0..5).map(|j| ((i * 3 + j) as f64 * 0.7).sin()).collect())
            .collect();
        let cfg = FlowConfig { dc_from_data: true, verify: false, jobs: 1, ..Default::default() };
        let r = run_flow(&m, &cfg, Some(&xs)).unwrap();
        // On the observed inputs the circuit must match the NN exactly
        // (DCs only free unobserved patterns).
        let sim = crate::logic::sim::CompiledNetlist::compile(&r.circuit.netlist);
        for x in &xs {
            let in_codes = quantize_input(&m, x);
            let tr = forward_codes(&m, &in_codes);
            let want = tr.codes.last().unwrap();
            let bits = codes_to_bits(&in_codes, m.input_quant.bits);
            let got_bits = sim.run_batch(&[bits]).pop().unwrap();
            let got = bits_to_codes(&got_bits, m.layers[1].act.bits);
            assert_eq!(&got, want);
        }
    }

    #[test]
    fn circuit_accuracy_matches_nn_accuracy() {
        let m = tiny_model(11);
        let cfg = FlowConfig { jobs: 1, ..Default::default() };
        let r = run_flow(&m, &cfg, None).unwrap();
        let xs: Vec<Vec<f64>> = (0..60)
            .map(|i| (0..5).map(|j| ((i + j) as f64 * 0.31).cos()).collect())
            .collect();
        let ys: Vec<usize> = xs.iter().map(|x| crate::nn::eval::classify(&m, x)).collect();
        // Logic is bit-exact ⇒ same predictions ⇒ 100% agreement.
        assert_eq!(circuit_accuracy(&m, &r.circuit, &xs, &ys), 1.0);
    }

    #[test]
    fn wide_fanin_model_is_a_typed_flow_error_not_a_panic() {
        // fanin 21 × 1 input bit = 21 enumeration variables > MAX_ENUM_BITS.
        // Both flow entry paths must reject it before any 2^21 allocation:
        // the plain flow (the old path panicked in enumerate_neuron's
        // assert on a worker thread) and the DC-from-data flow (the old
        // path allocated the observation tables unchecked).
        let m = random_model("wide", 21, &[2], 21, 1, 5);
        let err = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap_err();
        assert!(matches!(err, NnError::Flow(_)), "{err}");
        assert!(err.to_string().contains("fanin 21"), "{err}");

        let xs: Vec<Vec<f64>> = vec![vec![0.0; 21]; 4];
        let cfg = FlowConfig { dc_from_data: true, jobs: 1, ..Default::default() };
        let err = run_flow(&m, &cfg, Some(&xs)).unwrap_err();
        assert!(matches!(err, NnError::Flow(_)), "{err}");
    }

    #[test]
    #[should_panic(expected = "classify_packed")]
    fn classify_packed_rejects_wrong_width() {
        let m = tiny_model(1);
        // 1 packed signal; the model's last layer decodes 3 neurons × 3 bits.
        let outputs = crate::util::bitvec::PackedBatch::with_capacity(1, 0);
        let _ = classify_packed(&m, &outputs);
    }

    #[test]
    fn stage_log_has_expected_stages() {
        let m = tiny_model(5);
        let r = run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        let stages = r.timer.stages().to_vec();
        assert!(stages.iter().any(|s| s.contains("espresso")));
        assert!(stages.iter().any(|s| s == "aig+map"));
        assert!(stages.iter().any(|s| s == "stitch"));
        assert!(stages.iter().any(|s| s == "retime"));
    }
}
