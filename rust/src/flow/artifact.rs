//! Persistent compiled-circuit artifacts.
//!
//! The synthesized fixed-function logic *is* the deployable inference
//! artifact, so it must be savable: this module serializes a
//! [`PipelinedCircuit`] to a versioned JSON file bound to the model it was
//! compiled from by a fingerprint. `nullanet compile` writes one;
//! `serve`/`emit`/`verify --circuit` load it back — turning server
//! cold-start from a full enumerate→ESPRESSO→map→retime run into a file
//! load.
//!
//! Format (version 1, built on [`crate::util::json`]):
//!
//! ```text
//! {
//!   "format": "nullanet-circuit", "version": 1,
//!   "model": "jsc-s", "fingerprint": "<fnv1a64 of the model JSON>",
//!   "model_spec": { …the model's own JSON… },
//!   "num_inputs": N, "num_stages": S,
//!   "luts":    [{"k": 2, "in": [sig codes], "tt": "<hex>", "stage": 0}, …],
//!   "outputs": [[sig code, inverted], …]
//! }
//! ```
//!
//! Signal codes are [`Sig::to_code`]'s dense encoding (also used by the
//! compiled simulator). Loading validates format, version, fingerprint,
//! topological order, LUT arity, and the stage assignment — every failure
//! is a typed [`ArtifactError`], never a panic.
//!
//! `model_spec` embeds the full model JSON, making the artifact a
//! **self-contained named-model bundle**: [`load_bundle`] returns both the
//! model and its circuit from one file, which is what lets a
//! [`crate::coordinator::registry::ModelRegistry`] scan a directory of
//! artifacts and serve each under its model name without any side-channel
//! `.model.json` lookup. The fingerprint field is recomputed from the
//! embedded spec on load, so a bundle whose model and circuit were spliced
//! from different files is rejected. (Pre-bundle artifacts without
//! `model_spec` still load via [`load_circuit`] + an externally supplied
//! model.)

use std::fmt;

use crate::logic::netlist::{LutNetlist, PipelinedCircuit, Sig};
use crate::logic::truthtable::TruthTable;
use crate::nn::model::Model;
use crate::util::bitvec::BitVec;
use crate::util::json::Json;

/// Format tag every artifact carries.
pub const FORMAT: &str = "nullanet-circuit";
/// Artifact version this build reads and writes.
pub const VERSION: i64 = 1;

/// Typed failure of artifact save/load.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactError {
    /// Filesystem failure reading or writing the artifact.
    Io { path: String, msg: String },
    /// The file is not valid JSON.
    Parse(String),
    /// The file is not a circuit artifact (format tag mismatch).
    Format(String),
    /// The artifact version is not supported by this build.
    Version { found: i64, supported: i64 },
    /// The artifact was compiled from a different model.
    FingerprintMismatch { expected: String, found: String },
    /// Structurally invalid circuit (fields, topology, stages, widths).
    Invalid(String),
    /// The parsed circuit failed the structural lint
    /// ([`crate::logic::check::lint_circuit`]) — it would miscompute if
    /// served. `From<ArtifactError> for NnError` surfaces this as
    /// `NnError::Check`.
    Check(crate::logic::check::CheckError),
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Io { path, msg } => write!(f, "{path}: {msg}"),
            ArtifactError::Parse(m) => write!(f, "{m}"),
            ArtifactError::Format(m) => write!(f, "{m}"),
            ArtifactError::Version { found, supported } => write!(
                f,
                "unsupported artifact version {found} (this build reads {supported})"
            ),
            ArtifactError::FingerprintMismatch { expected, found } => write!(
                f,
                "artifact was compiled from a different model \
                 (fingerprint {found}, model is {expected})"
            ),
            ArtifactError::Invalid(m) => write!(f, "invalid circuit: {m}"),
            ArtifactError::Check(e) => write!(f, "circuit failed structural lint: {e}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

fn invalid(msg: impl Into<String>) -> ArtifactError {
    ArtifactError::Invalid(msg.into())
}

/// FNV-1a 64-bit fingerprint of a model's canonical JSON form. Binds an
/// artifact to exactly the weights/quantizers it was synthesized from (the
/// emitter's object keys are ordered, so the form is deterministic).
pub fn model_fingerprint(model: &Model) -> String {
    let text = model.to_json().to_string();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    format!("{h:016x}")
}

/// Serialize a circuit (with the fingerprint of the model it realizes).
pub fn circuit_to_json(circuit: &PipelinedCircuit, model: &Model) -> Json {
    let nl = &circuit.netlist;
    let luts: Vec<Json> = nl
        .luts
        .iter()
        .zip(&circuit.stage_of_lut)
        .map(|(lut, &stage)| {
            Json::obj([
                ("k", Json::int(lut.arity() as i64)),
                (
                    "in",
                    Json::Arr(
                        lut.inputs
                            .iter()
                            .map(|s| Json::int(s.to_code(nl.num_inputs) as i64))
                            .collect(),
                    ),
                ),
                ("tt", Json::str(lut.table.bits().to_hex())),
                ("stage", Json::int(stage as i64)),
            ])
        })
        .collect();
    let outputs: Vec<Json> = nl
        .outputs
        .iter()
        .map(|(s, inv)| {
            Json::Arr(vec![
                Json::int(s.to_code(nl.num_inputs) as i64),
                Json::Bool(*inv),
            ])
        })
        .collect();
    Json::obj([
        ("format", Json::str(FORMAT)),
        ("version", Json::int(VERSION)),
        ("model", Json::str(model.name.clone())),
        ("fingerprint", Json::str(model_fingerprint(model))),
        ("model_spec", model.to_json()),
        ("num_inputs", Json::int(nl.num_inputs as i64)),
        ("num_stages", Json::int(circuit.num_stages as i64)),
        ("luts", Json::Arr(luts)),
        ("outputs", Json::Arr(outputs)),
    ])
}

/// Validate the format tag and version of an artifact JSON.
fn check_header(j: &Json) -> Result<(), ArtifactError> {
    let tag = j.get("format").and_then(|v| v.as_str()).unwrap_or("");
    if tag != FORMAT {
        return Err(ArtifactError::Format(format!(
            "not a {FORMAT} artifact (format tag '{tag}')"
        )));
    }
    let version = j.get("version").and_then(|v| v.as_i64()).unwrap_or(-1);
    if version != VERSION {
        return Err(ArtifactError::Version { found: version, supported: VERSION });
    }
    Ok(())
}

/// Parse a self-contained bundle: the embedded `model_spec` plus the
/// circuit compiled from it. The artifact's `fingerprint` field is checked
/// against a fingerprint *recomputed from the embedded model*, so a file
/// whose model and circuit halves were spliced together from different
/// artifacts is rejected, never served.
pub fn bundle_from_json(j: &Json) -> Result<(Model, PipelinedCircuit), ArtifactError> {
    check_header(j)?;
    let spec = j.get("model_spec").ok_or_else(|| {
        invalid(
            "artifact has no embedded model (model_spec); recompile it with a \
             current `nullanet compile`, or serve it with an explicit --model \
             + --circuit pair",
        )
    })?;
    let model = Model::from_json(spec)
        .map_err(|e| invalid(format!("embedded model_spec: {e}")))?;
    let circuit = circuit_from_json(j, &model)?;
    Ok((model, circuit))
}

/// Parse and validate a circuit artifact against `model` (the fingerprint
/// must match and the circuit must be structurally sound).
pub fn circuit_from_json(j: &Json, model: &Model) -> Result<PipelinedCircuit, ArtifactError> {
    check_header(j)?;
    let found = j
        .get("fingerprint")
        .and_then(|v| v.as_str())
        .unwrap_or("")
        .to_string();
    let expected = model_fingerprint(model);
    if found != expected {
        return Err(ArtifactError::FingerprintMismatch { expected, found });
    }

    let req = |key: &str| j.req(key).map_err(invalid);
    let num_inputs = req("num_inputs")?
        .as_usize()
        .ok_or_else(|| invalid("num_inputs must be a non-negative integer"))?;
    if num_inputs != model.input_bits() {
        return Err(invalid(format!(
            "circuit has {num_inputs} inputs, model packs {} input bits",
            model.input_bits()
        )));
    }
    let num_stages = req("num_stages")?
        .as_usize()
        .ok_or_else(|| invalid("num_stages must be a non-negative integer"))?
        as u32;

    let luts_json = req("luts")?
        .as_arr()
        .ok_or_else(|| invalid("luts must be an array"))?;
    let mut nl = LutNetlist::new(num_inputs);
    let mut stages: Vec<u32> = Vec::with_capacity(luts_json.len());
    for (idx, lj) in luts_json.iter().enumerate() {
        let err = |m: String| invalid(format!("LUT {idx}: {m}"));
        let k = lj
            .req("k")
            .map_err(&err)?
            .as_usize()
            .ok_or_else(|| err("k must be a non-negative integer".into()))?;
        if k > 6 {
            return Err(err(format!("arity {k} exceeds the k ≤ 6 fabric")));
        }
        let codes = lj.req("in").map_err(&err)?.to_usize_vec().map_err(&err)?;
        if codes.len() != k {
            return Err(err(format!("{} input codes for arity {k}", codes.len())));
        }
        // Topological order: a LUT may only reference constants, inputs,
        // and strictly earlier LUTs.
        let limit = 2 + num_inputs + idx;
        let mut inputs = Vec::with_capacity(k);
        for &c in &codes {
            if c >= limit {
                return Err(err(format!("input code {c} breaks topological order")));
            }
            inputs.push(Sig::from_code(c as u32, num_inputs));
        }
        let hex = lj
            .req("tt")
            .map_err(&err)?
            .as_str()
            .ok_or_else(|| err("tt must be a hex string".into()))?;
        let bits = BitVec::from_hex(1usize << k, hex)
            .ok_or_else(|| err(format!("bad truth table '{hex}' for arity {k}")))?;
        nl.add_lut(inputs, TruthTable::from_bits(k, bits));
        let stage = lj
            .req("stage")
            .map_err(&err)?
            .as_usize()
            .ok_or_else(|| err("stage must be a non-negative integer".into()))?;
        stages.push(stage as u32);
    }

    let outs = req("outputs")?
        .as_arr()
        .ok_or_else(|| invalid("outputs must be an array"))?;
    // The circuit's outputs are the last layer's activation bits; the
    // fingerprint only covers the model, so the output count must be
    // validated here or a tampered artifact would panic the serving path.
    let last = model.layers.last().ok_or_else(|| invalid("model has no layers"))?;
    let want_outputs = last.out_width * last.act.bits;
    if outs.len() != want_outputs {
        return Err(invalid(format!(
            "circuit has {} outputs, model decodes {want_outputs} \
             ({} neurons × {} bits)",
            outs.len(),
            last.out_width,
            last.act.bits
        )));
    }
    let sig_limit = 2 + num_inputs + nl.num_luts();
    for (i, oj) in outs.iter().enumerate() {
        let pair = oj
            .as_arr()
            .ok_or_else(|| invalid(format!("output {i} must be [code, inverted]")))?;
        let (code, inv) = match pair {
            [c, v] => (
                c.as_usize()
                    .ok_or_else(|| invalid(format!("output {i}: bad signal code")))?,
                v.as_bool()
                    .ok_or_else(|| invalid(format!("output {i}: bad inversion flag")))?,
            ),
            _ => return Err(invalid(format!("output {i} must be [code, inverted]"))),
        };
        if code >= sig_limit {
            return Err(invalid(format!("output {i}: signal code {code} out of range")));
        }
        nl.add_output(Sig::from_code(code as u32, num_inputs), inv);
    }

    let circuit = PipelinedCircuit { netlist: nl, stage_of_lut: stages, num_stages };
    // Full structural lint — cycles, dangling signals, arity/table widths,
    // stage soundness. The field-level checks above catch malformed JSON;
    // this catches well-formed JSON describing a circuit that would
    // miscompute.
    crate::logic::check::lint_circuit(&circuit).map_err(ArtifactError::Check)?;
    Ok(circuit)
}

/// Write a circuit artifact (pretty-printed for inspectability) through
/// the crash-safe store: write-to-temp → fsync → atomic rename, with a
/// generation entry journaled before the payload is published. A crash at
/// any instruction leaves either the previous generation or the new one —
/// never a torn file that a later `load_circuit` would half-parse.
pub fn save_circuit(
    path: &str,
    circuit: &PipelinedCircuit,
    model: &Model,
) -> Result<(), ArtifactError> {
    let text = circuit_to_json(circuit, model).to_pretty_string();
    crate::flow::store::publish(path, text.as_bytes())
        .map(|_generation| ())
        .map_err(|e| ArtifactError::Io { path: path.to_string(), msg: e.to_string() })
}

/// Load a circuit artifact and check it against `model`.
pub fn load_circuit(path: &str, model: &Model) -> Result<PipelinedCircuit, ArtifactError> {
    let j = parse_file(path)?;
    circuit_from_json(&j, model)
}

/// Load a self-contained bundle: the embedded model and its circuit.
/// This is the registry's named-model handle — one file, one servable
/// model, no external `.model.json` needed.
pub fn load_bundle(path: &str) -> Result<(Model, PipelinedCircuit), ArtifactError> {
    let j = parse_file(path)?;
    bundle_from_json(&j)
}

/// Where the native-codegen `.so` for a circuit bundle lives: next to the
/// bundle, `<bundle stem>.native.so`. Keeping the shared object beside the
/// artifact (rather than in a temp dir) means a registry restart finds the
/// cached build, and deleting a bundle directory removes every derived
/// file with it. The `.so` itself is validated on load — embedded model
/// fingerprint plus a rustc-version sidecar — so a stale or foreign file
/// at this path is rejected and rebuilt, never trusted.
pub fn native_so_path(bundle_path: &str) -> String {
    let stem = bundle_path.strip_suffix(".json").unwrap_or(bundle_path);
    format!("{stem}.native.so")
}

fn parse_file(path: &str) -> Result<Json, ArtifactError> {
    // The store detects torn payloads against the generation journal,
    // quarantines them, and restores the previous generation when one
    // survives — a recovered load is a notice (and a counter bump), not an
    // error. Only an unrecoverable tear or real I/O failure surfaces here.
    let loaded = crate::flow::store::load(path)
        .map_err(|e| ArtifactError::Io { path: path.to_string(), msg: e.to_string() })?;
    if loaded.recovered {
        eprintln!(
            "artifact store: {path} was torn; quarantined it and restored \
             generation {}",
            loaded.generation
        );
    }
    let text = String::from_utf8_lossy(&loaded.bytes);
    Json::parse(&text).map_err(|e| ArtifactError::Parse(format!("{path}: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::random_model;

    #[test]
    fn native_so_path_sits_next_to_the_bundle() {
        assert_eq!(native_so_path("models/a.circuit.json"), "models/a.circuit.native.so");
        assert_eq!(native_so_path("plain"), "plain.native.so");
    }

    fn flow_circuit(seed: u64) -> (Model, PipelinedCircuit) {
        let m = random_model("art", 5, &[4, 3], 2, 1, seed);
        let r =
            run_flow(&m, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        (m, r.circuit)
    }

    #[test]
    fn roundtrip_preserves_structure_and_function() {
        let (m, circuit) = flow_circuit(11);
        let text = circuit_to_json(&circuit, &m).to_pretty_string();
        let back = circuit_from_json(&Json::parse(&text).unwrap(), &m).unwrap();
        assert_eq!(back.num_stages, circuit.num_stages);
        assert_eq!(back.stage_of_lut, circuit.stage_of_lut);
        assert_eq!(back.netlist.num_luts(), circuit.netlist.num_luts());
        assert_eq!(back.stats(), circuit.stats());
        for bits in 0..(1u64 << 5) {
            assert_eq!(back.eval(bits), circuit.eval(bits), "bits={bits}");
        }
    }

    #[test]
    fn file_roundtrip() {
        let (m, circuit) = flow_circuit(3);
        let path = "/tmp/nnt_artifact_test.circuit.json";
        save_circuit(path, &circuit, &m).unwrap();
        let back = load_circuit(path, &m).unwrap();
        assert_eq!(back.stats(), circuit.stats());
        // Saving went through the store: the generation journal exists.
        assert_eq!(crate::flow::store::generation(path), Some(1));
        for p in [
            path.to_string(),
            crate::flow::store::journal_path(path),
            crate::flow::store::prev_path(path),
        ] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn torn_artifact_recovers_previous_generation() {
        // Two published generations, then the payload is torn mid-file (a
        // crash between write and rename can't produce this through the
        // store, but a disk-level tear can). Loading quarantines the torn
        // bytes and restores generation 1 — the request path never sees a
        // parse panic.
        let (m, circuit) = flow_circuit(31);
        let (m2, circuit2) = flow_circuit(32);
        let path = "/tmp/nnt_artifact_torn_test.circuit.json";
        save_circuit(path, &circuit, &m).unwrap();
        save_circuit(path, &circuit2, &m2).unwrap();
        assert_eq!(crate::flow::store::generation(path), Some(2));
        let text = std::fs::read_to_string(path).unwrap();
        std::fs::write(path, &text[..text.len() / 2]).unwrap();
        // `.prev` holds generation 1 (the circuit compiled from `m`), so
        // that is what recovery hands back.
        let back = load_circuit(path, &m).unwrap();
        assert_eq!(back.stats(), circuit.stats());
        for p in [
            path.to_string(),
            crate::flow::store::journal_path(path),
            crate::flow::store::prev_path(path),
            crate::flow::store::quarantine_path(path),
        ] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn fingerprint_is_stable_and_weight_sensitive() {
        let m = random_model("fp", 4, &[3], 2, 1, 9);
        assert_eq!(model_fingerprint(&m), model_fingerprint(&m.clone()));
        let mut m2 = m.clone();
        m2.layers[0].weights[0][0] += 0.25;
        assert_ne!(model_fingerprint(&m), model_fingerprint(&m2));
    }

    #[test]
    fn mismatched_model_is_rejected() {
        let (m, circuit) = flow_circuit(5);
        let other = random_model("art", 5, &[4, 3], 2, 1, 6); // same shape, other weights
        let j = circuit_to_json(&circuit, &m);
        let err = circuit_from_json(&j, &other).unwrap_err();
        assert!(matches!(err, ArtifactError::FingerprintMismatch { .. }), "{err}");
    }

    #[test]
    fn version_and_format_are_gated() {
        let (m, circuit) = flow_circuit(7);
        let j = circuit_to_json(&circuit, &m);
        let Json::Obj(o) = j else { panic!("artifact must be an object") };

        let mut wrong_version = o.clone();
        wrong_version.insert("version".into(), Json::int(99));
        let err = circuit_from_json(&Json::Obj(wrong_version), &m).unwrap_err();
        assert_eq!(err, ArtifactError::Version { found: 99, supported: VERSION });

        let mut wrong_format = o.clone();
        wrong_format.insert("format".into(), Json::str("something-else"));
        let err = circuit_from_json(&Json::Obj(wrong_format), &m).unwrap_err();
        assert!(matches!(err, ArtifactError::Format(_)), "{err}");
    }

    #[test]
    fn corrupt_topology_is_a_typed_error_not_a_panic() {
        let (m, circuit) = flow_circuit(13);
        let j = circuit_to_json(&circuit, &m);
        let Json::Obj(mut o) = j else { panic!() };
        // Point the first LUT's first input at itself (forward reference).
        let Some(Json::Arr(luts)) = o.get_mut("luts") else { panic!() };
        if luts.is_empty() {
            return; // degenerate constant-only netlist; nothing to corrupt
        }
        let self_code = 2 + m.input_bits(); // code of LUT 0
        if let Json::Obj(lut0) = &mut luts[0] {
            if let Some(Json::Arr(ins)) = lut0.get_mut("in") {
                if ins.is_empty() {
                    return; // degenerate constant-only netlist; nothing to corrupt
                }
                ins[0] = Json::int(self_code as i64);
            }
        }
        let err = circuit_from_json(&Json::Obj(o), &m).unwrap_err();
        assert!(matches!(err, ArtifactError::Invalid(_)), "{err}");
    }

    #[test]
    fn truncated_outputs_are_rejected_not_panicked() {
        // The fingerprint covers only the model, so a tampered "outputs"
        // array stays fingerprint-valid — the loader must catch it.
        let (m, circuit) = flow_circuit(21);
        let j = circuit_to_json(&circuit, &m);
        let Json::Obj(mut o) = j else { panic!() };
        let Some(Json::Arr(outs)) = o.get_mut("outputs") else { panic!() };
        outs.pop();
        let err = circuit_from_json(&Json::Obj(o), &m).unwrap_err();
        assert!(matches!(err, ArtifactError::Invalid(_)), "{err}");
        assert!(err.to_string().contains("outputs"), "{err}");
    }

    #[test]
    fn bundle_roundtrip_recovers_model_and_circuit() {
        let (m, circuit) = flow_circuit(17);
        let path = "/tmp/nnt_bundle_test.circuit.json";
        save_circuit(path, &circuit, &m).unwrap();
        let (back_model, back_circuit) = load_bundle(path).unwrap();
        assert_eq!(back_model.name, m.name);
        assert_eq!(model_fingerprint(&back_model), model_fingerprint(&m));
        assert_eq!(back_circuit.stats(), circuit.stats());
        for bits in 0..(1u64 << 5) {
            assert_eq!(back_circuit.eval(bits), circuit.eval(bits), "bits={bits}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bundle_without_embedded_model_is_rejected() {
        let (m, circuit) = flow_circuit(19);
        let j = circuit_to_json(&circuit, &m);
        let Json::Obj(mut o) = j else { panic!() };
        o.remove("model_spec");
        let err = bundle_from_json(&Json::Obj(o)).unwrap_err();
        assert!(matches!(err, ArtifactError::Invalid(_)), "{err}");
        assert!(err.to_string().contains("model_spec"), "{err}");
    }

    #[test]
    fn spliced_bundle_fails_the_fingerprint_check() {
        // Splice: circuit from one model, model_spec from another. The
        // recomputed fingerprint of the embedded spec no longer matches the
        // artifact's fingerprint field.
        let (m, circuit) = flow_circuit(23);
        let other = random_model("art", 5, &[4, 3], 2, 1, 24);
        let j = circuit_to_json(&circuit, &m);
        let Json::Obj(mut o) = j else { panic!() };
        o.insert("model_spec".into(), other.to_json());
        let err = bundle_from_json(&Json::Obj(o)).unwrap_err();
        assert!(matches!(err, ArtifactError::FingerprintMismatch { .. }), "{err}");
    }

    #[test]
    fn lint_failing_artifact_is_a_check_error() {
        // Field-level parsing succeeds (every value well-typed and in
        // range), but the described circuit is unservable: zero pipeline
        // stages. The structural lint must reject it as a typed Check
        // error, which `NnError::from` surfaces as `NnError::Check`.
        let (m, circuit) = flow_circuit(29);
        let j = circuit_to_json(&circuit, &m);
        let Json::Obj(mut o) = j else { panic!() };
        o.insert("num_stages".into(), Json::int(0));
        let err = circuit_from_json(&Json::Obj(o), &m).unwrap_err();
        assert!(matches!(err, ArtifactError::Check(_)), "{err}");
        let top: crate::NnError = err.into();
        assert!(matches!(top, crate::NnError::Check(_)), "{top}");
    }

    #[test]
    fn missing_file_is_io_error() {
        let m = random_model("io", 4, &[3], 2, 1, 1);
        let err = load_circuit("/tmp/does_not_exist_nnt.circuit.json", &m).unwrap_err();
        assert!(matches!(err, ArtifactError::Io { .. }), "{err}");
    }
}
