//! Multi-model serving registry with live hot-swap.
//!
//! NullaNet Tiny compiles each DNN into *one* fixed-function circuit, so a
//! multi-workload deployment is inherently multi-circuit: one compiled
//! artifact per model, hosted side by side. [`ModelRegistry`] owns N
//! independent engine stacks — each a [`RouterBuilder`]-constructed
//! [`Router`] keyed by model name — and routes every classify request to
//! one of them (an explicit name, or the registry's default when the
//! request names none, which is what keeps every single-model client
//! working unchanged).
//!
//! Models come from self-contained circuit bundles
//! ([`crate::flow::artifact::load_bundle`]): [`ModelRegistry::load_dir`]
//! scans a directory of `*.json` artifacts at startup, and
//! [`ModelRegistry::load_path`] loads one more at run time — the TCP
//! server's `{"cmd":"load"}` admin command.
//!
//! ## Hot-swap drain protocol
//!
//! [`ModelRegistry::install`] replaces a model's router behind an `Arc`
//! swap without dropping in-flight requests:
//!
//! 1. The replacement router goes into the map under a write lock; from
//!    this instant every *new* lookup gets the new engine.
//! 2. The lock is released, then the old router is drained:
//!    `Router::shutdown` closes its batcher — which flushes any queued
//!    requests immediately (no max-wait stall; see
//!    [`crate::coordinator::batcher::Batcher::close`]) — and joins the
//!    dispatcher, so every reply already submitted is delivered before the
//!    old engine (and the artifact it serves) is released.
//! 3. A submitter that raced the swap — it looked up the old `Arc` before
//!    step 1 but submitted after the close — is rejected by the closed
//!    batcher with its request intact; [`ModelRegistry::classify`]
//!    re-fetches from the map and resubmits on the replacement. No reply
//!    is dropped, none is misrouted.
//!
//! Unload follows the same drain, minus the replacement.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::{BatchPolicy, Reply, ReplyNotify};
use crate::coordinator::router::{Policy, Router, RouterBuilder, SubmitRejection};
use crate::error::NnError;
use crate::flow::artifact;
use crate::util::bitvec::BitVec;
use crate::util::sync::{mpsc, RwLock};

/// How the registry builds an engine stack for each loaded bundle.
#[derive(Clone, Copy, Debug)]
pub struct RegistryConfig {
    /// Batch flush policy applied to every loaded model's router.
    pub batch_policy: BatchPolicy,
    /// Shard workers per logic engine.
    pub workers: usize,
    /// Engine policy for every loaded model's router. `Policy::Native`
    /// degrades per-model to the interpreter when codegen is unavailable
    /// (see [`crate::coordinator::router`]).
    pub policy: Policy,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        RegistryConfig {
            batch_policy: BatchPolicy::default(),
            workers: 1,
            policy: Policy::Logic,
        }
    }
}

/// Diagnostic snapshot of one registered model (the `models` admin
/// command).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Registry key (usually the model's own name).
    pub name: String,
    /// Engine label replies carry ("logic" / "pjrt").
    pub engine: &'static str,
    /// Feature width the model expects.
    pub features: usize,
    /// Current batcher queue depth.
    pub depth: usize,
    /// `(LUTs before, after)` the compile-time netlist optimizer, when the
    /// model's engine evaluates a compiled circuit.
    pub lut_counts: Option<(usize, usize)>,
    /// Whether unnamed classify requests route here.
    pub default: bool,
    /// Artifact path the model was loaded from, when it came from one.
    pub source: Option<String>,
}

struct Entry {
    router: Arc<Router>,
    source: Option<String>,
}

struct RegState {
    models: BTreeMap<String, Entry>,
    /// Target of classify requests that name no model.
    default: Option<String>,
}

/// N independent engine stacks behind one name→router map. See the module
/// docs for the hot-swap drain protocol.
pub struct ModelRegistry {
    config: RegistryConfig,
    state: RwLock<RegState>,
}

impl ModelRegistry {
    /// Empty registry; loaded models get engine stacks per `config`.
    pub fn new(config: RegistryConfig) -> ModelRegistry {
        ModelRegistry {
            config,
            state: RwLock::named(
                "registry.state",
                RegState { models: BTreeMap::new(), default: None },
            ),
        }
    }

    /// Single-model registry around an externally built router (any engine
    /// policy), with default [`RegistryConfig`] for later live loads — a
    /// convenience for tests and embedders; the CLI threads its own tuning
    /// through [`ModelRegistry::new`] + [`ModelRegistry::install`] instead.
    /// The model is the default, so existing clients that never send a
    /// `"model"` field keep working unchanged.
    pub fn with_default(name: &str, router: Router) -> ModelRegistry {
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install(name, router, None)
            .expect("a freshly created registry lock cannot be poisoned");
        reg
    }

    /// Number of registered models. Diagnostic read: recovers through a
    /// poisoned lock rather than failing an admin poll.
    pub fn len(&self) -> usize {
        self.state.read().models.len()
    }

    /// True when no model is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered names (sorted — the map is a `BTreeMap`).
    pub fn names(&self) -> Vec<String> {
        self.state.read().models.keys().cloned().collect()
    }

    /// Name unnamed classify requests route to, if any.
    pub fn default_name(&self) -> Option<String> {
        self.state.read().default.clone()
    }

    /// Point unnamed classify requests at `name`.
    pub fn set_default(&self, name: &str) -> Result<(), NnError> {
        let mut s = self.state.write_checked()?;
        if !s.models.contains_key(name) {
            return Err(no_such_model(name, &s.models));
        }
        s.default = Some(name.to_string());
        Ok(())
    }

    /// Resolve a model name (or the default) to its router.
    pub fn get(&self, name: Option<&str>) -> Result<Arc<Router>, NnError> {
        let s = self.state.read_checked()?;
        let key = match name {
            Some(n) => n,
            None => s.default.as_deref().ok_or_else(|| {
                NnError::Config(
                    "no default model loaded; name one with {\"model\": …}".into(),
                )
            })?,
        };
        match s.models.get(key) {
            Some(e) => Ok(Arc::clone(&e.router)),
            None => Err(no_such_model(key, &s.models)),
        }
    }

    /// Install (or hot-swap) `router` under `name`. New lookups see the
    /// replacement the moment the map lock is released; the displaced
    /// router — if any — is then drained (close + join) so every reply
    /// already in flight on it is delivered before this call returns.
    ///
    /// Only the first model installed into an *empty* registry becomes the
    /// default. In particular, after the default model is unloaded, a later
    /// install does NOT grab the default — unnamed traffic keeps failing
    /// until [`ModelRegistry::set_default`] re-points it deliberately
    /// (silently re-routing legacy clients to a different model would
    /// return wrong predictions with no indication anything changed).
    ///
    /// Errs only when the registry lock was poisoned by a panicked thread
    /// ([`NnError::Sync`]) — the map was not modified and the router was
    /// not installed.
    pub fn install(
        &self,
        name: &str,
        router: Router,
        source: Option<String>,
    ) -> Result<(), NnError> {
        let entry = Entry { router: Arc::new(router), source };
        let displaced = {
            let mut s = self.state.write_checked()?;
            let was_empty = s.models.is_empty();
            let old = s.models.insert(name.to_string(), entry);
            if was_empty {
                s.default = Some(name.to_string());
            }
            old
        };
        if let Some(old) = displaced {
            // Outside the lock: the drain can serve final batches while new
            // traffic already flows to the replacement.
            old.router.shutdown();
        }
        Ok(())
    }

    /// Build the registry-standard engine stack for a loaded bundle and
    /// install it — the one place the startup scan and the live `load`
    /// admin command both go through, so their routers can never diverge.
    fn build_and_install(
        &self,
        key: &str,
        model: crate::nn::model::Model,
        circuit: crate::logic::netlist::PipelinedCircuit,
        source: String,
    ) -> Result<(), NnError> {
        // Artifact loads already lint on parse; this re-check also covers
        // circuits handed in directly (flow output, tests), so nothing
        // structurally unsound can ever be installed behind a route.
        crate::logic::check::lint_circuit(&circuit)?;
        // Native codegen caches its compiled `.so` next to the bundle it
        // came from, keyed by model fingerprint + rustc version, so a
        // registry restart reuses the build instead of re-invoking rustc.
        let router = RouterBuilder::new(model)
            .circuit(circuit.netlist)
            .engine(self.config.policy)
            .native_cache(artifact::native_so_path(&source))
            .batch_policy(self.config.batch_policy)
            .workers(self.config.workers)
            .build()?;
        self.install(key, router, Some(source))
    }

    /// Load one circuit bundle and register it. `name` overrides the
    /// bundle's model name as the registry key; loading onto an existing
    /// key hot-swaps it. Returns the resolved key.
    pub fn load_path(&self, path: &str, name: Option<&str>) -> Result<String, NnError> {
        let (model, circuit) = artifact::load_bundle(path)?;
        let key = name.unwrap_or(&model.name).to_string();
        self.build_and_install(&key, model, circuit, path.to_string())?;
        Ok(key)
    }

    /// Scan `dir` for `*.json` circuit bundles and register every one
    /// (sorted by file name, so the startup default — the first loaded —
    /// is deterministic). Files that are JSON but not circuit artifacts
    /// (e.g. `.model.json` files sharing the directory) are skipped with a
    /// notice; a genuinely broken artifact, a bundle without an embedded
    /// model, and two bundles claiming the same model name are startup
    /// errors. Returns the registered names in load order.
    pub fn load_dir(&self, dir: &str) -> Result<Vec<String>, NnError> {
        let mut paths: Vec<String> = std::fs::read_dir(dir)
            .map_err(|e| NnError::Config(format!("--models {dir}: {e}")))?
            .filter_map(|entry| {
                let p = entry.ok()?.path();
                let file = p.file_name()?.to_str()?;
                if p.is_file() && file.ends_with(".json") {
                    Some(p.to_str()?.to_string())
                } else {
                    None
                }
            })
            .collect();
        paths.sort();
        let mut loaded = Vec::new();
        for path in &paths {
            match artifact::load_bundle(path) {
                Ok((model, circuit)) => {
                    if self.state.read_checked()?.models.contains_key(&model.name) {
                        return Err(NnError::Config(format!(
                            "--models {dir}: two artifacts provide model \
                             '{}' (second: {path})",
                            model.name
                        )));
                    }
                    let key = model.name.clone();
                    self.build_and_install(&key, model, circuit, path.clone())?;
                    loaded.push(key);
                }
                // Not a circuit artifact at all (wrong format tag): other
                // JSON routinely shares artifact directories. Everything
                // else — bad version, corrupt circuit, missing embedded
                // model — is a real broken artifact and fails the scan.
                Err(artifact::ArtifactError::Format(_)) => {
                    eprintln!("--models {dir}: skipping {path} (not a circuit artifact)");
                }
                Err(e) => {
                    return Err(NnError::Artifact(e));
                }
            }
        }
        Ok(loaded)
    }

    /// Remove `name` and drain its router (close + join: queued requests
    /// are flushed and replied to before the engine is released). If it
    /// was the default, unnamed requests now fail until another default is
    /// set — deliberate, rather than silently re-pointing clients at a
    /// different model.
    pub fn unload(&self, name: &str) -> Result<(), NnError> {
        let removed = {
            let mut s = self.state.write_checked()?;
            let removed = s
                .models
                .remove(name)
                .ok_or_else(|| no_such_model(name, &s.models))?;
            if s.default.as_deref() == Some(name) {
                s.default = None;
            }
            removed
        };
        removed.router.shutdown();
        Ok(())
    }

    /// Submit one classify request to the named (or default) model. Checks
    /// the feature width (a protocol error, not a panic) and retries
    /// through hot-swaps: a submit rejected by a draining router re-fetches
    /// the live replacement from the map and **reuses the already-binarized
    /// bits** ([`Router::try_submit_bits`]) whenever the replacement serves
    /// the same input quantization — the common hot-swap case (same model,
    /// recompiled circuit) — so racing a drain costs no double quantize.
    pub fn classify(
        &self,
        name: Option<&str>,
        features: &[f64],
    ) -> Result<mpsc::Receiver<Reply>, NnError> {
        self.classify_with(name, features, None, None, false)
    }

    /// [`classify`](Self::classify) with the nonblocking front end's extra
    /// context: `deadline` rides the request into the batcher, which sheds
    /// it unevaluated once past (the receiver observes a disconnect that
    /// the submitter surfaces as [`NnError::Deadline`]); `notify` fires
    /// once the reply is resolved (sent, dropped, or shed); `pipelined`
    /// marks a request that arrived on a connection with replies still in
    /// flight (counted per model).
    pub fn classify_with(
        &self,
        name: Option<&str>,
        features: &[f64],
        deadline: Option<Instant>,
        notify: Option<ReplyNotify>,
        pipelined: bool,
    ) -> Result<mpsc::Receiver<Reply>, NnError> {
        // Bounded, not `loop`: every retry means the mapped router was
        // found closed, which a swap/unload always follows by replacing or
        // removing the map entry — so a second closed hit is already
        // pathological (an external caller shut a router down without
        // going through the registry). Never spin forever on that.
        let mut prepared: Option<(BitVec, Arc<Router>)> = None;
        for _ in 0..64 {
            let router = self.get(name)?;
            if features.len() != router.input_features() {
                return Err(NnError::Config(format!(
                    "features: expected {} values, got {}",
                    router.input_features(),
                    features.len()
                )));
            }
            let bits = match prepared.take() {
                // Bits binarized for the displaced router stay valid when
                // the replacement packs the same way: same packed/numeric
                // mode, same input quantizer, same circuit-input width. A
                // swap that changed any of those re-binarizes.
                Some((bits, old))
                    if old.wants_packed() == router.wants_packed()
                        && old.model().input_quant == router.model().input_quant
                        && bits.len() == router.model().input_bits() =>
                {
                    bits
                }
                _ => router.binarize(features),
            };
            match router.try_submit_bits(bits, features, deadline, notify.clone()) {
                Ok(rx) => {
                    Self::count_pipelined(&router, pipelined);
                    return Ok(rx);
                }
                // Raced a hot-swap: this router closed between the map read
                // and the submit. The swap already installed (or removed)
                // its replacement — re-resolve (`get` errors out if the
                // model is gone) and carry the bits to the retry.
                Err(SubmitRejection::Closed(bits)) => prepared = Some((bits, router)),
                // Admission control is NOT retried: the queue is full, and
                // an immediate resubmit would amplify the overload. Typed
                // so the server replies with the overload frame / field.
                Err(SubmitRejection::Overloaded(_)) => {
                    return Err(Self::overload_error(name, &router));
                }
            }
        }
        Err(NnError::Config(format!(
            "model '{}' is shutting down",
            name.unwrap_or("<default>")
        )))
    }

    /// Submit one classify request whose circuit-input bits arrived
    /// **already packed** — the binary-frame fast path: no float parse, no
    /// quantize, just a width check and the queue. Only packed-input
    /// (logic) engines can serve it: a numeric engine needs the raw
    /// feature vector the frame deliberately does not carry. Retries
    /// through hot-swaps exactly like [`classify`](Self::classify),
    /// reusing the same bits (any same-width replacement accepts them —
    /// the wire format *is* the packed representation).
    pub fn classify_bits(
        &self,
        name: Option<&str>,
        bits: BitVec,
        deadline: Option<Instant>,
        notify: Option<ReplyNotify>,
        pipelined: bool,
    ) -> Result<mpsc::Receiver<Reply>, NnError> {
        let mut bits = bits;
        for _ in 0..64 {
            let router = self.get(name)?;
            if !router.wants_packed() || router.wants_features() {
                return Err(NnError::Config(format!(
                    "model '{}' runs a numeric or mirror engine that needs \
                     raw feature vectors; binary frames carry packed bits \
                     only — use the JSON protocol's features field",
                    name.unwrap_or("<default>")
                )));
            }
            if bits.len() != router.model().input_bits() {
                return Err(NnError::Config(format!(
                    "bits: expected {} circuit-input bits, got {}",
                    router.model().input_bits(),
                    bits.len()
                )));
            }
            match router.try_submit_bits(bits, &[], deadline, notify.clone()) {
                Ok(rx) => {
                    Self::count_pipelined(&router, pipelined);
                    return Ok(rx);
                }
                Err(SubmitRejection::Closed(b)) => bits = b,
                Err(SubmitRejection::Overloaded(_)) => {
                    return Err(Self::overload_error(name, &router));
                }
            }
        }
        Err(NnError::Config(format!(
            "model '{}' is shutting down",
            name.unwrap_or("<default>")
        )))
    }

    fn count_pipelined(router: &Router, pipelined: bool) {
        if pipelined {
            router
                .metrics()
                .pipelined_requests
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    }

    fn overload_error(name: Option<&str>, router: &Router) -> NnError {
        NnError::Overload(format!(
            "model '{}' queue is at its depth cap ({}); back off and resubmit",
            name.unwrap_or("<default>"),
            router.batch_policy().max_depth
        ))
    }

    /// Snapshot the map under the read lock and drop it before touching
    /// any router: rendering depths/metrics takes per-batcher mutexes and
    /// formats histograms, and a writer-waiting `RwLock` would block every
    /// `classify`'s `get()` behind an admin poll for that whole duration.
    fn snapshot(&self) -> Vec<(String, Arc<Router>, bool, Option<String>)> {
        let s = self.state.read();
        s.models
            .iter()
            .map(|(name, e)| {
                (
                    name.clone(),
                    Arc::clone(&e.router),
                    s.default.as_deref() == Some(name.as_str()),
                    e.source.clone(),
                )
            })
            .collect()
    }

    /// Snapshot of every registered model (sorted by name).
    pub fn infos(&self) -> Vec<ModelInfo> {
        self.snapshot()
            .into_iter()
            .map(|(name, router, default, source)| ModelInfo {
                name,
                engine: router.engine_name(),
                features: router.input_features(),
                depth: router.depth(),
                lut_counts: router.lut_counts(),
                default,
                source,
            })
            .collect()
    }

    /// Total queued requests across all models.
    pub fn depth_total(&self) -> usize {
        self.snapshot().iter().map(|(_, router, _, _)| router.depth()).sum()
    }

    /// Per-model metrics report (one section per model, sorted by name).
    pub fn metrics_report(&self) -> String {
        let snap = self.snapshot();
        if snap.is_empty() {
            return "no models loaded".to_string();
        }
        let sections: Vec<String> = snap
            .into_iter()
            .map(|(name, router, default, _)| {
                let tag = if default { " (default)" } else { "" };
                format!(
                    "model '{name}'{tag} [engine {}]\n{}",
                    router.engine_name(),
                    router.metrics().report()
                )
            })
            .collect();
        sections.join("\n")
    }

    /// Drain every router (server shutdown). The registry stays usable —
    /// models can be reloaded — but all current engines stop.
    /// Recovers through a poisoned lock: shutdown must always proceed.
    pub fn shutdown_all(&self) {
        let drained: Vec<Entry> = {
            let mut s = self.state.write();
            s.default = None;
            std::mem::take(&mut s.models).into_values().collect()
        };
        for e in drained {
            e.router.shutdown();
        }
    }
}

fn no_such_model(name: &str, models: &BTreeMap<String, Entry>) -> NnError {
    let known: Vec<&str> = models.keys().map(String::as_str).collect();
    NnError::Config(if known.is_empty() {
        format!("no model named '{name}' (none loaded)")
    } else {
        format!("no model named '{name}' (loaded: {})", known.join(", "))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::{random_model, Model};
    use std::time::Duration;

    fn make_router(model: &Model) -> Router {
        let r = run_flow(model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        RouterBuilder::new(model.clone())
            .circuit(r.circuit.netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            })
            .workers(1)
            .build()
            .unwrap()
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn default_routing_and_named_routing() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 1);
        let b = random_model("b", 5, &[4, 3], 2, 1, 2);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        reg.install("b", make_router(&b), None).unwrap();
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(reg.default_name().as_deref(), Some("a"));

        let x: Vec<f64> = (0..5).map(|j| (j as f64 * 0.4).sin()).collect();
        // Unnamed → default (a); named → the named model.
        let ra = reg
            .classify(None, &x)
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(ra.class, crate::nn::eval::classify(&a, &x));
        let rb = reg
            .classify(Some("b"), &x)
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(rb.class, crate::nn::eval::classify(&b, &x));
        reg.shutdown_all();
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn unknown_model_and_wrong_width_are_typed_errors() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 3);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        let err = reg.classify(Some("nope"), &[0.0; 5]).unwrap_err();
        assert!(err.to_string().contains("no model named 'nope'"), "{err}");
        let err = reg.classify(Some("a"), &[0.0; 4]).unwrap_err();
        assert!(err.to_string().contains("expected 5"), "{err}");
        reg.shutdown_all();
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn install_rejects_a_structurally_unsound_circuit() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 3);
        let r = run_flow(&a, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let mut circuit = r.circuit;
        circuit.num_stages = 0; // tamper: no pipeline stages
        let reg = ModelRegistry::new(RegistryConfig::default());
        let err = reg
            .build_and_install("a", a, circuit, "test".into())
            .unwrap_err();
        assert!(matches!(err, NnError::Check(_)), "{err}");
        assert!(reg.is_empty());
    }

    /// A `Policy::Native` registry serves bit-exactly through
    /// `build_and_install` whether or not codegen is actually available on
    /// this host — the router degrades to the interpreter per model — and
    /// the `.so` cache lands next to the bundle source path.
    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn native_policy_registry_serves_through_build_and_install() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 23);
        let r = run_flow(&a, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let reg = ModelRegistry::new(RegistryConfig {
            policy: Policy::Native,
            ..Default::default()
        });
        let source = std::env::temp_dir()
            .join(format!("nnt-reg-native-{}.circuit.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        reg.build_and_install("a", a.clone(), r.circuit, source.clone()).unwrap();
        let x: Vec<f64> = (0..5).map(|j| (j as f64 * 0.3).cos()).collect();
        let reply = reg
            .classify(Some("a"), &x)
            .unwrap()
            .recv_timeout(Duration::from_secs(10))
            .unwrap();
        assert_eq!(reply.class, crate::nn::eval::classify(&a, &x));
        reg.shutdown_all();
        let so = artifact::native_so_path(&source);
        for p in [so.clone(), format!("{so}.rs"), format!("{so}.meta")] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn empty_registry_has_no_default() {
        let reg = ModelRegistry::new(RegistryConfig::default());
        assert!(reg.is_empty());
        let err = reg.classify(None, &[0.0]).unwrap_err();
        assert!(err.to_string().contains("no default model"), "{err}");
    }

    /// Every registry lock path that needs no synthesized router — read,
    /// checked-read, and checked-write — on an empty map. This is the
    /// subset the Miri CI job runs (the tests above are gated out there:
    /// full synthesis is ~100× slower under the interpreter).
    #[test]
    fn error_paths_exercise_every_lock_path_without_models() {
        let reg = ModelRegistry::new(RegistryConfig::default());
        assert!(reg.names().is_empty());
        assert_eq!(reg.default_name(), None);
        assert!(reg.infos().is_empty());
        let err = reg.set_default("nope").unwrap_err();
        assert!(err.to_string().contains("no model named 'nope'"), "{err}");
        let err = reg.unload("nope").unwrap_err();
        assert!(err.to_string().contains("no model named 'nope'"), "{err}");
        let err = reg.get(Some("nope")).unwrap_err();
        assert!(err.to_string().contains("no model named 'nope'"), "{err}");
        reg.shutdown_all();
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn unload_clears_default_and_drains() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 7);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        // A reply in flight when unload starts must still be delivered:
        // unload drains (close-flush + join) before returning.
        let rx = reg.classify(Some("a"), &[0.1; 5]).unwrap();
        reg.unload("a").unwrap();
        rx.recv_timeout(Duration::from_secs(5))
            .expect("in-flight reply must survive unload");
        assert!(reg.is_empty());
        assert_eq!(reg.default_name(), None);
        assert!(reg.unload("a").is_err(), "double unload is an error");
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn classify_bits_serves_prepacked_requests_bit_exactly() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 17);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        let x: Vec<f64> = (0..5).map(|j| (j as f64 * 0.7).sin()).collect();
        // Pack the way a binary-frame client would, then submit bits only.
        let bits = reg.get(Some("a")).unwrap().binarize(&x);
        let reply = reg
            .classify_bits(Some("a"), bits, None, None, false)
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(reply.class, crate::nn::eval::classify(&a, &x));
        // Width mismatches are typed protocol errors, not panics.
        let err = reg
            .classify_bits(Some("a"), BitVec::zeros(3), None, None, false)
            .unwrap_err();
        assert!(err.to_string().contains("circuit-input bits"), "{err}");
        reg.shutdown_all();
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn overload_surfaces_as_a_typed_error_not_a_retry_spin() {
        // Deterministic induction: max_batch higher than the depth cap and
        // a long max_wait park the dispatcher on the age timer, so the
        // first two submits sit in the queue and the third MUST hit the
        // cap — no timing dependence.
        let a = random_model("a", 5, &[4, 3], 2, 1, 19);
        let r = run_flow(&a, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let router = RouterBuilder::new(a.clone())
            .circuit(r.circuit.netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_secs(5),
                max_depth: 2,
            })
            .workers(1)
            .build()
            .unwrap();
        let reg = ModelRegistry::with_default("a", router);
        let rx1 = reg.classify_with(Some("a"), &[0.1; 5], None, None, false).unwrap();
        let rx2 = reg.classify_with(Some("a"), &[0.2; 5], None, None, false).unwrap();
        let err = reg
            .classify_with(Some("a"), &[0.3; 5], None, None, false)
            .expect_err("third submit must trip the depth-2 cap");
        assert!(matches!(&err, NnError::Overload(_)), "{err}");
        assert!(err.to_string().contains("depth cap (2)"), "{err}");
        let m = reg.get(Some("a")).unwrap().metrics();
        use std::sync::atomic::Ordering;
        assert_eq!(m.rejected_overload.load(Ordering::Relaxed), 1);
        assert_eq!(m.queue_depth_high_watermark.load(Ordering::Relaxed), 2);
        // Shutdown close-flushes the parked queue: both admitted replies
        // are still delivered.
        reg.shutdown_all();
        rx1.recv_timeout(Duration::from_secs(5)).expect("admitted reply 1 delivered");
        rx2.recv_timeout(Duration::from_secs(5)).expect("admitted reply 2 delivered");
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn infos_surface_optimizer_lut_counts() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 21);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        let infos = reg.infos();
        let (pre, post) = infos[0].lut_counts.expect("logic engine reports LUT counts");
        assert!(post <= pre, "optimizer must not add LUTs ({pre} → {post})");
        reg.shutdown_all();
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn classify_retry_is_bounded_on_an_externally_closed_router() {
        // An external shutdown (not via the registry) leaves a closed
        // router in the map: classify must exercise the bits-reuse retry
        // loop and give up with a typed error, not spin forever.
        let a = random_model("a", 5, &[4, 3], 2, 1, 33);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        reg.get(Some("a")).unwrap().shutdown();
        let err = reg.classify(Some("a"), &[0.0; 5]).unwrap_err();
        assert!(err.to_string().contains("shutting down"), "{err}");
        reg.shutdown_all();
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn install_hot_swaps_and_drains_the_old_router() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 9);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        let old = reg.get(Some("a")).unwrap();
        // Submit on the old router, then swap: the reply must arrive.
        let rx = reg.classify(Some("a"), &[0.2; 5]).unwrap();
        reg.install("a", make_router(&a), None).unwrap();
        let reply = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("in-flight reply must survive the swap");
        assert_eq!(reply.class, crate::nn::eval::classify(&a, &[0.2; 5]));
        // The displaced router is drained: direct submits are rejected.
        assert!(old.try_submit(&[0.2; 5]).is_none(), "old router must be closed");
        // The replacement serves.
        let reply = reg
            .classify(Some("a"), &[0.3; 5])
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(reply.class, crate::nn::eval::classify(&a, &[0.3; 5]));
        reg.shutdown_all();
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn install_after_default_unload_does_not_steal_default() {
        // Unloading the default leaves unnamed traffic failing; a later
        // install (e.g. a routine recompile reload of another model) must
        // NOT silently become the default and serve legacy clients wrong
        // predictions — only an explicit set_default re-points them.
        let a = random_model("a", 5, &[4, 3], 2, 1, 13);
        let b = random_model("b", 5, &[4, 3], 2, 1, 14);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        reg.install("b", make_router(&b), None).unwrap();
        reg.unload("a").unwrap();
        assert_eq!(reg.default_name(), None);
        reg.install("b", make_router(&b), None).unwrap(); // hot-swap reload of 'b'
        assert_eq!(reg.default_name(), None, "install must not grab the default");
        let err = reg.classify(None, &[0.0; 5]).unwrap_err();
        assert!(err.to_string().contains("no default model"), "{err}");
        // Empty registry resets: the next install is a fresh start and may
        // become the default again.
        reg.unload("b").unwrap();
        reg.install("a", make_router(&a), None).unwrap();
        assert_eq!(reg.default_name().as_deref(), Some("a"));
        reg.shutdown_all();
    }

    #[test]
    #[cfg_attr(miri, ignore = "full synthesis is too slow under Miri")]
    fn set_default_switches_unnamed_traffic() {
        let a = random_model("a", 5, &[4, 3], 2, 1, 11);
        let b = random_model("b", 5, &[4, 3], 2, 1, 12);
        let reg = ModelRegistry::new(RegistryConfig::default());
        reg.install("a", make_router(&a), None).unwrap();
        reg.install("b", make_router(&b), None).unwrap();
        assert!(reg.set_default("nope").is_err());
        reg.set_default("b").unwrap();
        let x = [0.5; 5];
        let reply = reg
            .classify(None, &x)
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert_eq!(reply.class, crate::nn::eval::classify(&b, &x));
        let infos = reg.infos();
        assert_eq!(infos.len(), 2);
        assert!(!infos[0].default && infos[1].default);
        reg.shutdown_all();
    }
}
