//! The serving coordinator (L3): dynamic batching, engine routing, TCP
//! server, and metrics — the layer that turns the synthesized combinational
//! logic into a deployable inference service.
//!
//! * [`batcher`] — queue + flush policy (max batch / max wait); flushes
//!   bit-packed [`batcher::Batch`]es the logic engine consumes directly
//! * [`router`] — logic vs PJRT engine dispatch, compare mode, multi-worker
//!   packed evaluation on one shared compiled netlist
//! * [`server`] — JSON-lines TCP front end
//! * [`metrics`] — latency histograms, counters

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batch, BatchPolicy, Batcher};
pub use router::{PjrtSpec, Policy, Router};
