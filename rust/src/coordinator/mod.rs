//! The serving coordinator (L3): dynamic batching, pluggable inference
//! engines, TCP server, and metrics — the layer that turns the synthesized
//! combinational logic into a deployable inference service.
//!
//! * [`batcher`] — queue + flush policy (max batch / max wait); flushes
//!   bit-packed [`batcher::Batch`]es the engines consume directly
//! * [`engine`] — the [`engine::InferenceEngine`] trait and its
//!   implementations: packed logic, PJRT numeric, and the mirror combinator
//! * [`router`] — [`router::RouterBuilder`] assembles an engine stack and
//!   runs the backend-agnostic dispatch loop
//! * [`registry`] — [`registry::ModelRegistry`]: N named engine stacks in
//!   one process, loaded from circuit bundles, with live hot-swap
//! * [`server`] — TCP front end (model routing + admin commands): JSON
//!   lines and the length-prefixed binary protocol on one port, blocking
//!   or epoll event-loop accept paths
//! * [`frame`] — the versioned binary wire format ([`frame::decode`] /
//!   encode), parsed incrementally from partial reads
//! * [`metrics`] — latency histograms, counters (reported per model)

pub mod batcher;
pub mod engine;
pub mod frame;
pub mod metrics;
pub mod registry;
pub mod router;
pub mod server;

pub use batcher::{Batch, BatchPolicy, Batcher, ReplyNotify, SubmitError};
pub use frame::Frame;
pub use engine::{
    EngineError, InferenceEngine, MirrorEngine, NativeCodegenEngine, PackedLogicEngine,
    PjrtNumericEngine,
};
pub use registry::{ModelInfo, ModelRegistry, RegistryConfig};
pub use router::{PjrtSpec, Policy, Router, RouterBuilder, SubmitRejection};
