//! Serving metrics: latency histogram + counters.
//!
//! Lock-free on the record path (atomic bucket counters); percentile reads
//! are approximate to bucket resolution — the standard histogram trade-off
//! every serving stack makes.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency histogram from 100 ns to ~100 s.
pub struct LatencyHistogram {
    /// Bucket i covers [100ns · 1.5^i, 100ns · 1.5^(i+1)).
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

const BASE_NS: f64 = 100.0;
const GROWTH: f64 = 1.5;
const NBUCKETS: usize = 52;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns as f64 <= BASE_NS {
            return 0;
        }
        let b = ((ns as f64 / BASE_NS).ln() / GROWTH.ln()).floor() as usize;
        b.min(NBUCKETS - 1)
    }

    /// Record one latency sample.
    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency (ns).
    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate percentile (ns), `q ∈ (0,1)`.
    pub fn percentile_ns(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // Bucket 0 actually spans [0, BASE·G): every sub-BASE_NS
                // sample lands there, so the log-midpoint formula (≈122 ns)
                // would *overstate* sub-100ns packed-logic latencies. Clamp
                // its representative to BASE_NS.
                if i == 0 {
                    return BASE_NS;
                }
                // Geometric midpoint of bucket i, √(lo·hi) = BASE·G^(i+½):
                // the unbiased representative of a log-spaced bucket. The
                // upper edge would bias every percentile high by up to ×G.
                return BASE_NS * GROWTH.powf(i as f64 + 0.5);
            }
        }
        BASE_NS * GROWTH.powi(NBUCKETS as i32)
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs p99={:.1}µs",
            self.count(),
            self.mean_ns() / 1e3,
            self.percentile_ns(0.50) / 1e3,
            self.percentile_ns(0.95) / 1e3,
            self.percentile_ns(0.99) / 1e3,
        )
    }
}

/// Serving counters shared across coordinator threads.
#[derive(Default)]
pub struct Metrics {
    /// End-to-end request latency.
    pub request_latency: LatencyHistogram,
    /// Batch-execution latency (per flushed batch).
    pub batch_latency: LatencyHistogram,
    /// Requests served by the logic engine.
    pub logic_requests: AtomicU64,
    /// Requests served by the PJRT engine.
    pub numeric_requests: AtomicU64,
    /// Batches flushed.
    pub batches: AtomicU64,
    /// Requests whose engines disagreed (mirror/compare mode).
    pub disagreements: AtomicU64,
    /// Requests dropped because an engine failed on their batch.
    pub engine_failures: AtomicU64,
    /// Requests whose mirror *shadow* failed (the primary still replied).
    pub shadow_failures: AtomicU64,
    /// Requests rejected by admission control (queue at its depth cap).
    pub rejected_overload: AtomicU64,
    /// Deepest the batch queue has ever been (samples queued at once).
    pub queue_depth_high_watermark: AtomicU64,
    /// Requests that arrived on a connection that already had requests in
    /// flight — the event loop's per-connection pipelining at work.
    pub pipelined_requests: AtomicU64,
    /// Requests shed because their deadline expired before evaluation —
    /// the batcher dropped them without burning a batch slot.
    pub deadline_expired: AtomicU64,
    /// Times this model's engine dropped a tier on the native→SIMD→scalar
    /// fallback ladder (at construction or permanently mid-serve).
    pub fallback_downgrades: AtomicU64,
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the queue depth observed after an enqueue; keeps the
    /// high-watermark monotone without a lock.
    pub fn observe_queue_depth(&self, depth: u64) {
        self.queue_depth_high_watermark.fetch_max(depth, Ordering::Relaxed);
    }

    /// Render a human-readable report. The resilience line joins this
    /// model's own shed/downgrade counters with the two process-wide
    /// recovery counters (store restores and lock-poison heals) so one
    /// read shows every degradation the stack has absorbed.
    pub fn report(&self) -> String {
        format!(
            "requests: logic={} numeric={} batches={} disagreements={} failures={} \
             shadow-failures={}\n\
             admission: rejected_overload={} queue_depth_high_watermark={} \
             pipelined_requests={}\n\
             resilience: deadline_expired={} fallback_downgrades={} \
             store_recoveries={} poison_recoveries={}\n\
             request latency: {}\n\
             batch latency:   {}",
            self.logic_requests.load(Ordering::Relaxed),
            self.numeric_requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.disagreements.load(Ordering::Relaxed),
            self.engine_failures.load(Ordering::Relaxed),
            self.shadow_failures.load(Ordering::Relaxed),
            self.rejected_overload.load(Ordering::Relaxed),
            self.queue_depth_high_watermark.load(Ordering::Relaxed),
            self.pipelined_requests.load(Ordering::Relaxed),
            self.deadline_expired.load(Ordering::Relaxed),
            self.fallback_downgrades.load(Ordering::Relaxed),
            crate::flow::store::store_recoveries(),
            crate::util::sync::poison_recoveries(),
            self.request_latency.summary(),
            self.batch_latency.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1_000); // 1µs .. 1ms uniform
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_ns(0.5);
        let p95 = h.percentile_ns(0.95);
        let p99 = h.percentile_ns(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // p50 of uniform 1µs..1ms ≈ 500µs; the bucket midpoint lands within
        // a ×√1.5 factor of the true value (tighter than the old upper-edge
        // estimate, which could overshoot by ×1.5).
        assert!((300_000.0..900_000.0).contains(&p50), "p50={p50}");
    }

    #[test]
    fn percentile_returns_bucket_midpoint_not_upper_edge() {
        let h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record_ns(1_000); // all samples in one bucket
        }
        let p50 = h.percentile_ns(0.5);
        // bucket_of(1000) = 5: [759.4 ns, 1139.1 ns); geometric midpoint
        // ≈ 930 ns. The seed returned the upper edge (≈1139 ns), biasing
        // every percentile high.
        assert!(p50 > 759.0 && p50 < 1139.0, "p50={p50} must sit inside the bucket");
        assert!((p50 - 930.0).abs() < 5.0, "p50={p50} should be the geometric midpoint");
    }

    #[test]
    fn bucket_zero_representative_is_base_ns() {
        // Bucket 0 spans [0, 150 ns); its geometric "midpoint" (~122 ns)
        // overstated sub-100ns latencies. The representative is pinned to
        // BASE_NS for every percentile.
        let h = LatencyHistogram::new();
        for ns in [0u64, 10, 50, 99, 100] {
            h.record_ns(ns);
        }
        assert_eq!(h.percentile_ns(0.5), 100.0);
        assert_eq!(h.percentile_ns(0.99), 100.0);
        // Ordering still holds once later buckets appear.
        h.record_ns(10_000);
        assert!(h.percentile_ns(0.5) <= h.percentile_ns(0.99));
        assert_eq!(h.percentile_ns(0.5), 100.0);
    }

    #[test]
    fn mean_exact() {
        let h = LatencyHistogram::new();
        h.record_ns(100);
        h.record_ns(300);
        assert_eq!(h.mean_ns(), 200.0);
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.percentile_ns(0.99), 0.0);
    }

    #[test]
    fn extreme_values_clamped() {
        let h = LatencyHistogram::new();
        h.record_ns(u64::MAX);
        h.record_ns(0);
        assert_eq!(h.count(), 2);
        assert!(h.percentile_ns(0.99).is_finite());
    }

    #[test]
    fn metrics_report_format() {
        let m = Metrics::new();
        m.logic_requests.fetch_add(5, Ordering::Relaxed);
        m.request_latency.record_ns(1000);
        let r = m.report();
        assert!(r.contains("logic=5"));
        assert!(r.contains("p99"));
        assert!(r.contains("rejected_overload=0"));
    }

    #[test]
    fn queue_depth_watermark_is_monotone_max() {
        let m = Metrics::new();
        m.observe_queue_depth(3);
        m.observe_queue_depth(7);
        m.observe_queue_depth(5);
        assert_eq!(m.queue_depth_high_watermark.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn admission_counters_surface_in_report() {
        let m = Metrics::new();
        m.rejected_overload.fetch_add(2, Ordering::Relaxed);
        m.pipelined_requests.fetch_add(9, Ordering::Relaxed);
        m.observe_queue_depth(64);
        let r = m.report();
        assert!(r.contains("rejected_overload=2"));
        assert!(r.contains("queue_depth_high_watermark=64"));
        assert!(r.contains("pipelined_requests=9"));
    }

    #[test]
    fn resilience_counters_surface_in_report() {
        let m = Metrics::new();
        m.deadline_expired.fetch_add(4, Ordering::Relaxed);
        m.fallback_downgrades.fetch_add(1, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("resilience: deadline_expired=4 fallback_downgrades=1"));
        // The process-wide recovery counters are monotone, so pin the key
        // names, not the values (other tests may have bumped them).
        assert!(r.contains("store_recoveries="));
        assert!(r.contains("poison_recoveries="));
        // The resilience line sits between admission and latency lines.
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[1].starts_with("admission:"));
        assert!(lines[2].starts_with("resilience:"));
        assert!(lines[3].starts_with("request latency:"));
    }
}
