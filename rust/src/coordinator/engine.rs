//! Pluggable inference engines behind one trait.
//!
//! The router used to hard-code its two backends behind a `Policy` match;
//! [`InferenceEngine`] is the seam that replaces it. An engine consumes a
//! flushed batch — bit-packed circuit inputs ([`PackedBatch`]) and, when it
//! asks for them, the raw feature vectors — and returns one predicted class
//! per sample. The dispatcher in [`crate::coordinator::router`] is
//! backend-agnostic: it calls [`dispatch`] and never inspects which engine
//! it is driving.
//!
//! Engines shipped here:
//!
//! * [`PackedLogicEngine`] — the paper's artifact: one shared
//!   `Arc<CompiledNetlist>` evaluated bit-parallel, multi-lane-group
//!   batches sharded across an owned [`ThreadPool`].
//! * [`PjrtNumericEngine`] — the AOT-compiled XLA executable (numeric
//!   reference; stub build fails construction cleanly).
//! * [`MirrorEngine`] — a combinator replacing the old ad-hoc
//!   `Policy::Compare` arm: replies from the primary engine, shadows every
//!   batch onto a second engine, and records disagreements/failures on an
//!   injected [`Metrics`] handle.
//!
//! Construction is fallible ([`EngineError`]) and happens *before* the
//! router accepts traffic, so a missing HLO artifact is a typed build
//! error, not a dispatcher panic that strands every submitter.

use std::fmt;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::coordinator::metrics::Metrics;
use crate::flow::build::classify_packed_words;
use crate::logic::codegen::{self, CacheOutcome, NativeLib};
use crate::logic::netlist::LutNetlist;
use crate::logic::sim::{CompiledNetlist, ShardRunner, SimScratch};
use crate::nn::model::Model;
use crate::runtime::PjrtEngine;
use crate::util::bitvec::{mask_group_tail, PackedBatch};
use crate::util::threadpool::ThreadPool;

/// Typed failure of an inference engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The engine could not be built (missing artifact, absent backend,
    /// incompatible circuit, …). Returned from `RouterBuilder::build`.
    Construction(String),
    /// The engine cannot serve this request shape (e.g. a packed batch
    /// handed to a numeric-only engine).
    Unsupported(String),
    /// Inference itself failed at run time.
    Inference(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Construction(m) => write!(f, "engine construction: {m}"),
            EngineError::Unsupported(m) => write!(f, "unsupported request: {m}"),
            EngineError::Inference(m) => write!(f, "inference failed: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// A serving backend: classifies whole batches.
///
/// Engines live on the dispatcher thread for the router's whole lifetime
/// (so they may own non-`Send` native handles) and take `&mut self` (so
/// they may own per-engine scratch state without interior mutability).
pub trait InferenceEngine {
    /// Short engine label carried on every [`crate::coordinator::batcher::Reply`].
    fn name(&self) -> &'static str;

    /// True when the router must retain each request's raw feature vector
    /// so [`InferenceEngine::classify_features`] can see it.
    fn wants_features(&self) -> bool {
        false
    }

    /// True when the router must quantize/binarize features into packed
    /// circuit-input bits at submit time. Numeric-only engines return
    /// `false` to skip that dead work.
    fn wants_packed(&self) -> bool {
        true
    }

    /// Classify every sample of a bit-packed batch.
    fn classify_packed_batch(&mut self, batch: &PackedBatch)
        -> Result<Vec<usize>, EngineError>;

    /// Shared-batch variant: engines that shard the batch across worker
    /// threads override this to share it zero-copy (the router's dispatch
    /// path always calls it). The default delegates to the borrowed entry
    /// point.
    fn classify_packed_shared(
        &mut self,
        batch: &Arc<PackedBatch>,
    ) -> Result<Vec<usize>, EngineError> {
        self.classify_packed_batch(batch.as_ref())
    }

    /// Numeric-features entry point: classify from the raw feature vectors
    /// (`xs[s]` belongs to lane `s` of `batch`). The default delegates to
    /// the packed path; numeric engines override it.
    fn classify_features(
        &mut self,
        batch: &PackedBatch,
        xs: &[Vec<f64>],
    ) -> Result<Vec<usize>, EngineError> {
        let _ = xs;
        self.classify_packed_batch(batch)
    }

    /// Shared-batch variant of [`InferenceEngine::classify_features`]:
    /// combinators override it so a packed sub-engine can still share the
    /// batch zero-copy. Default delegates to the borrowed entry point.
    fn classify_features_shared(
        &mut self,
        batch: &Arc<PackedBatch>,
        xs: &[Vec<f64>],
    ) -> Result<Vec<usize>, EngineError> {
        self.classify_features(batch.as_ref(), xs)
    }

    /// `(LUTs before, LUTs after)` the compile-time netlist optimizer, for
    /// engines that evaluate a compiled circuit. Surfaced per model by the
    /// serving `depth` admin command; `None` for numeric engines.
    fn lut_counts(&self) -> Option<(usize, usize)> {
        None
    }
}

/// Drive one batch through an engine: the features entry point when the
/// engine wants raw features and the batch carries them, the shared packed
/// entry point otherwise. This is the router's whole dispatch logic.
pub fn dispatch(
    engine: &mut dyn InferenceEngine,
    batch: &Arc<PackedBatch>,
    features: Option<&[Vec<f64>]>,
) -> Result<Vec<usize>, EngineError> {
    match features {
        Some(xs) if engine.wants_features() => engine.classify_features_shared(batch, xs),
        _ => engine.classify_packed_shared(batch),
    }
}

/// Shared construction-time validation for circuit-evaluating engines: the
/// circuit must pack exactly the model's input bits, stay within the k ≤ 6
/// fabric, and expose the output words the model's argmax decode reads.
fn validate_circuit(model: &Model, netlist: &LutNetlist) -> Result<(), EngineError> {
    if netlist.num_inputs != model.input_bits() {
        return Err(EngineError::Construction(format!(
            "circuit has {} inputs but model '{}' packs {} input bits",
            netlist.num_inputs,
            model.name,
            model.input_bits()
        )));
    }
    if netlist.max_arity() > 6 {
        return Err(EngineError::Construction(format!(
            "circuit contains a {}-input LUT; the compiled simulator supports k ≤ 6",
            netlist.max_arity()
        )));
    }
    let last = model
        .layers
        .last()
        .ok_or_else(|| EngineError::Construction("model has no layers".into()))?;
    let want_outputs = last.out_width * last.act.bits;
    if netlist.outputs.len() != want_outputs {
        return Err(EngineError::Construction(format!(
            "circuit has {} outputs but model '{}' decodes {want_outputs} \
             ({} neurons × {} bits)",
            netlist.outputs.len(),
            model.name,
            last.out_width,
            last.act.bits
        )));
    }
    Ok(())
}

/// The combinational-logic engine: an immutable compiled netlist shared
/// across shard workers, classifying straight from packed output words.
///
/// The steady-state serving path is **allocation-free for scratch and
/// output buffers**: the inline (single-group) path reuses one
/// [`SimScratch`] and one group-major word `Vec`, and the sharded path's
/// [`ShardRunner`] keeps a per-worker scratch pool plus one persistent
/// output buffer that shards write disjoint ranges of directly.
/// [`PackedLogicEngine::alloc_stats`] is the test hook that pins this.
pub struct PackedLogicEngine {
    sim: Arc<CompiledNetlist>,
    pool: Option<ThreadPool>,
    /// Inline-path scratch (single-group batches / no pool).
    scratch: SimScratch,
    /// Inline-path output words, reused across batches.
    out_words: Vec<u64>,
    /// Inline-path output-buffer capacity growths (test hook).
    inline_grows: usize,
    /// Sharded-path persistent state (scratch pool + output buffer).
    runner: ShardRunner,
    model: Arc<Model>,
    metrics: Arc<Metrics>,
}

impl PackedLogicEngine {
    /// Compile `netlist` and size the shard pool. With `workers ≥ 2`,
    /// batches spanning multiple 64-sample lane groups are evaluated in
    /// parallel on the one shared compiled netlist.
    pub fn new(
        model: Arc<Model>,
        netlist: &LutNetlist,
        workers: usize,
        metrics: Arc<Metrics>,
    ) -> Result<PackedLogicEngine, EngineError> {
        validate_circuit(&model, netlist)?;
        let sim = Arc::new(CompiledNetlist::compile(netlist));
        let scratch = sim.make_scratch();
        let runner = ShardRunner::new(&sim);
        let pool = (workers > 1).then(|| ThreadPool::new(workers));
        Ok(PackedLogicEngine {
            sim,
            pool,
            scratch,
            out_words: Vec::new(),
            inline_grows: 0,
            runner,
            model,
            metrics,
        })
    }

    fn check_width(&self, batch: &PackedBatch) -> Result<(), EngineError> {
        if batch.num_signals() != self.sim.num_inputs() {
            return Err(EngineError::Inference(format!(
                "batch packs {} signals for a {}-input circuit",
                batch.num_signals(),
                self.sim.num_inputs()
            )));
        }
        Ok(())
    }

    /// Evaluate on the inline (single-scratch) path into the persistent
    /// output buffer; returns the group-major output words. Associated
    /// function over the individual fields so the returned borrow is tied
    /// to `out_words` alone (the caller still needs `self.model` and
    /// `self.metrics` while holding it).
    fn run_inline<'a>(
        sim: &CompiledNetlist,
        scratch: &mut SimScratch,
        out_words: &'a mut Vec<u64>,
        inline_grows: &mut usize,
        batch: &PackedBatch,
    ) -> &'a [u64] {
        let need = batch.num_groups() * sim.num_outputs();
        if out_words.capacity() < need {
            *inline_grows += 1;
        }
        sim.run_packed_into(batch, scratch, out_words);
        out_words
    }

    /// Zero-allocation test hook: `(shard scratches ever created,
    /// output-buffer capacity growths across both paths)`. Both counters
    /// stabilize after the first batches of the steady-state size — pinned
    /// by `packed_engine_reuses_buffers_across_batches` and documented in
    /// `rust/DESIGN.md` §Serving.
    pub fn alloc_stats(&self) -> (usize, usize) {
        let (created, grows) = self.runner.alloc_stats();
        (created, grows + self.inline_grows)
    }
}

impl InferenceEngine for PackedLogicEngine {
    fn name(&self) -> &'static str {
        "logic"
    }

    fn classify_packed_batch(
        &mut self,
        batch: &PackedBatch,
    ) -> Result<Vec<usize>, EngineError> {
        self.check_width(batch)?;
        if self.pool.is_some() && batch.num_groups() >= 2 {
            // Sharding needs a shareable handle; only direct callers of the
            // borrowed entry point pay this copy — the router's dispatch
            // path goes through `classify_packed_shared` and never does.
            let shared = Arc::new(batch.clone());
            return self.classify_packed_shared(&shared);
        }
        let n = batch.num_samples();
        let words = Self::run_inline(
            &self.sim,
            &mut self.scratch,
            &mut self.out_words,
            &mut self.inline_grows,
            batch,
        );
        let preds = classify_packed_words(&self.model, words, n);
        self.metrics.logic_requests.fetch_add(n as u64, Ordering::Relaxed);
        Ok(preds)
    }

    fn classify_packed_shared(
        &mut self,
        batch: &Arc<PackedBatch>,
    ) -> Result<Vec<usize>, EngineError> {
        self.check_width(batch)?;
        let n = batch.num_samples();
        let words: &[u64] = match &self.pool {
            Some(pool) if batch.num_groups() >= 2 => {
                self.runner.run(&self.sim, pool, batch)
            }
            _ => Self::run_inline(
                &self.sim,
                &mut self.scratch,
                &mut self.out_words,
                &mut self.inline_grows,
                batch,
            ),
        };
        let preds = classify_packed_words(&self.model, words, n);
        self.metrics.logic_requests.fetch_add(n as u64, Ordering::Relaxed);
        Ok(preds)
    }

    fn lut_counts(&self) -> Option<(usize, usize)> {
        let s = self.sim.opt_stats();
        Some((s.luts_before, s.luts_after))
    }
}

/// The native codegen engine: the circuit lowered to straight-line machine
/// code by `logic::codegen` — emitted as branch-free Rust, built with
/// `rustc` as a `cdylib`, loaded through dependency-free `dlopen` shims,
/// and cached keyed by model fingerprint + rustc version.
///
/// Construction fails with a typed [`EngineError::Construction`] whenever
/// any rung is missing (no `rustc` on the host, non-Linux `dlopen` stub,
/// build failure); the router's `Policy::Native` arm then falls back to
/// the SIMD interpreter ([`PackedLogicEngine`]) — the ladder documented in
/// `rust/DESIGN.md` §Engine-API.
///
/// The ladder also holds **mid-serve**: the engine retains the compiled
/// interpreter it was built from, and a native-library failure after
/// construction (simulated by the `engine.eval` fault point; in the wild,
/// an `.so` unlinked out from under a hot-swap) triggers a *permanent*
/// per-model downgrade to the interpreter tier — counted in
/// `fallback_downgrades`, labelled on every subsequent reply, and
/// bit-exact by the differential suite — instead of erroring (and
/// dropping) every subsequent batch.
pub struct NativeCodegenEngine {
    lib: NativeLib,
    /// The compiled interpreter the library was generated from — the
    /// fallback tier, retained so a mid-serve downgrade needs no rebuild.
    sim: CompiledNetlist,
    /// Interpreter-tier scratch (unused until a downgrade).
    scratch: SimScratch,
    /// Set once by [`NativeCodegenEngine::downgrade`]; never cleared — a
    /// library that failed once is not trusted again.
    downgraded: bool,
    /// Output words, group-major, reused across batches.
    out_words: Vec<u64>,
    /// `(LUTs before, LUTs after)` optimization — the generated code
    /// evaluates exactly the post-optimizer netlist.
    luts: (usize, usize),
    model: Arc<Model>,
    metrics: Arc<Metrics>,
}

impl NativeCodegenEngine {
    /// Compile `netlist`, lower it to native code, and load the library.
    /// `cache_path` is where the `.so` is cached (next to the circuit
    /// bundle when serving from one); `None` uses a fingerprint-keyed path
    /// under the temp dir. A stale cache (fingerprint, rustc version, or
    /// shape mismatch) is rejected and rebuilt, with a notice on stderr.
    pub fn new(
        model: Arc<Model>,
        netlist: &LutNetlist,
        cache_path: Option<&str>,
        metrics: Arc<Metrics>,
    ) -> Result<NativeCodegenEngine, EngineError> {
        validate_circuit(&model, netlist)?;
        let sim = CompiledNetlist::compile(netlist);
        let fp = crate::flow::artifact::model_fingerprint(&model);
        let so_path = match cache_path {
            Some(p) => p.to_string(),
            None => codegen::default_cache_path(&fp),
        };
        let (lib, outcome) = codegen::load_or_build(&sim, &fp, &so_path)
            .map_err(|e| EngineError::Construction(e.to_string()))?;
        match outcome {
            CacheOutcome::Cached => {
                eprintln!("native engine: loaded cached {so_path}");
            }
            CacheOutcome::Rebuilt(reason) => {
                eprintln!("native engine: rebuilt {so_path} ({reason})");
            }
        }
        let s = sim.opt_stats();
        let scratch = sim.make_scratch();
        Ok(NativeCodegenEngine {
            lib,
            scratch,
            downgraded: false,
            out_words: Vec::new(),
            luts: (s.luts_before, s.luts_after),
            model,
            metrics,
            sim,
        })
    }

    /// Whether this engine has permanently dropped to the interpreter tier.
    pub fn is_downgraded(&self) -> bool {
        self.downgraded
    }

    /// Permanently drop this model to the interpreter tier. Idempotent in
    /// effect but only ever called on the first native failure.
    fn downgrade(&mut self, why: &str) {
        self.downgraded = true;
        self.metrics.fallback_downgrades.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "native engine: library failure mid-serve ({why}); model '{}' permanently \
             downgraded to the interpreter tier",
            self.model.name
        );
    }

    fn classify(&mut self, batch: &PackedBatch) -> Result<Vec<usize>, EngineError> {
        if batch.num_signals() != self.lib.num_inputs() {
            return Err(EngineError::Inference(format!(
                "batch packs {} signals for a {}-input native circuit",
                batch.num_signals(),
                self.lib.num_inputs()
            )));
        }
        let n = batch.num_samples();
        if !self.downgraded && crate::util::fault::should_fail("engine.eval") {
            // The only runtime failure a straight-line `.so` can exhibit is
            // the catastrophic kind (unmapped library, torn relocation) that
            // a test cannot survive observing directly — so the fault point
            // stands in for it here, and the response is the real one: stop
            // trusting the library, permanently.
            self.downgrade("injected fault at engine.eval");
        }
        if self.downgraded {
            // Interpreter tier: same netlist, same packing, bit-exact with
            // the native library by the differential suite.
            self.sim.run_packed_into(batch, &mut self.scratch, &mut self.out_words);
            let preds = classify_packed_words(&self.model, &self.out_words, n);
            self.metrics.logic_requests.fetch_add(n as u64, Ordering::Relaxed);
            return Ok(preds);
        }
        let groups = batch.num_groups();
        let no = self.lib.num_outputs();
        self.out_words.clear();
        self.out_words.resize(groups * no, 0);
        self.lib.eval_groups(batch.words(), groups, &mut self.out_words);
        mask_group_tail(&mut self.out_words, no, n);
        let preds = classify_packed_words(&self.model, &self.out_words, n);
        self.metrics.logic_requests.fetch_add(n as u64, Ordering::Relaxed);
        Ok(preds)
    }
}

impl InferenceEngine for NativeCodegenEngine {
    fn name(&self) -> &'static str {
        // The downgrade is visible on every reply, not only in the
        // counters: clients see which tier actually served them.
        if self.downgraded {
            "native>interp"
        } else {
            "native"
        }
    }

    fn classify_packed_batch(
        &mut self,
        batch: &PackedBatch,
    ) -> Result<Vec<usize>, EngineError> {
        self.classify(batch)
    }

    fn lut_counts(&self) -> Option<(usize, usize)> {
        Some(self.luts)
    }
}

/// The PJRT numeric engine: classifies from raw feature vectors via the
/// AOT-compiled XLA executable.
pub struct PjrtNumericEngine {
    engine: PjrtEngine,
    num_classes: usize,
    metrics: Arc<Metrics>,
}

impl PjrtNumericEngine {
    /// Load and compile the HLO artifact described by `spec`. In the
    /// default (stub) build this always returns a construction error.
    pub fn new(
        spec: &crate::coordinator::router::PjrtSpec,
        num_classes: usize,
        metrics: Arc<Metrics>,
    ) -> Result<PjrtNumericEngine, EngineError> {
        let engine =
            PjrtEngine::load(&spec.hlo_path, spec.batch, spec.in_features, spec.out_width)
                .map_err(|e| EngineError::Construction(e.to_string()))?;
        Ok(PjrtNumericEngine { engine, num_classes, metrics })
    }
}

impl InferenceEngine for PjrtNumericEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn wants_features(&self) -> bool {
        true
    }

    fn wants_packed(&self) -> bool {
        false
    }

    fn classify_packed_batch(
        &mut self,
        _batch: &PackedBatch,
    ) -> Result<Vec<usize>, EngineError> {
        Err(EngineError::Unsupported(
            "the PJRT engine needs raw feature vectors, not packed circuit bits".into(),
        ))
    }

    fn classify_features(
        &mut self,
        _batch: &PackedBatch,
        xs: &[Vec<f64>],
    ) -> Result<Vec<usize>, EngineError> {
        let preds = self
            .engine
            .classify_all(xs, self.num_classes)
            .map_err(|e| EngineError::Inference(e.to_string()))?;
        self.metrics
            .numeric_requests
            .fetch_add(xs.len() as u64, Ordering::Relaxed);
        Ok(preds)
    }
}

/// Mirror combinator: reply from `primary`, shadow every batch onto
/// `shadow`, and record per-sample disagreements (and shadow failures) on
/// the injected [`Metrics`] handle. Replaces the old ad-hoc
/// `Policy::Compare` arm — and composes: any two engines can be mirrored.
pub struct MirrorEngine {
    primary: Box<dyn InferenceEngine>,
    shadow: Box<dyn InferenceEngine>,
    metrics: Arc<Metrics>,
}

impl MirrorEngine {
    /// Mirror `shadow` behind `primary`.
    pub fn new(
        primary: Box<dyn InferenceEngine>,
        shadow: Box<dyn InferenceEngine>,
        metrics: Arc<Metrics>,
    ) -> MirrorEngine {
        MirrorEngine { primary, shadow, metrics }
    }

    fn record_shadow(
        &self,
        primary: &[usize],
        shadow: Result<Vec<usize>, EngineError>,
    ) {
        match shadow {
            Ok(s) => {
                let dis =
                    primary.iter().zip(&s).filter(|(a, b)| a != b).count() as u64;
                self.metrics.disagreements.fetch_add(dis, Ordering::Relaxed);
            }
            Err(e) => {
                // The primary already served these requests: count on the
                // shadow-only counter, not `engine_failures` (dropped
                // requests).
                self.metrics
                    .shadow_failures
                    .fetch_add(primary.len() as u64, Ordering::Relaxed);
                eprintln!("mirror: shadow engine '{}' failed: {e}", self.shadow.name());
            }
        }
    }
}

impl InferenceEngine for MirrorEngine {
    /// Replies carry the primary engine's label.
    fn name(&self) -> &'static str {
        self.primary.name()
    }

    /// LUT counts come from the primary (the engine that serves replies).
    fn lut_counts(&self) -> Option<(usize, usize)> {
        self.primary.lut_counts()
    }

    fn wants_features(&self) -> bool {
        self.primary.wants_features() || self.shadow.wants_features()
    }

    fn wants_packed(&self) -> bool {
        self.primary.wants_packed() || self.shadow.wants_packed()
    }

    fn classify_packed_batch(
        &mut self,
        batch: &PackedBatch,
    ) -> Result<Vec<usize>, EngineError> {
        let preds = self.primary.classify_packed_batch(batch)?;
        // Without retained features only a packed-capable shadow can run.
        if !self.shadow.wants_features() {
            let shadow = self.shadow.classify_packed_batch(batch);
            self.record_shadow(&preds, shadow);
        }
        Ok(preds)
    }

    fn classify_packed_shared(
        &mut self,
        batch: &Arc<PackedBatch>,
    ) -> Result<Vec<usize>, EngineError> {
        let preds = self.primary.classify_packed_shared(batch)?;
        if !self.shadow.wants_features() {
            let shadow = self.shadow.classify_packed_shared(batch);
            self.record_shadow(&preds, shadow);
        }
        Ok(preds)
    }

    fn classify_features(
        &mut self,
        batch: &PackedBatch,
        xs: &[Vec<f64>],
    ) -> Result<Vec<usize>, EngineError> {
        let primary = if self.primary.wants_features() {
            self.primary.classify_features(batch, xs)
        } else {
            self.primary.classify_packed_batch(batch)
        };
        let preds = primary?;
        let shadow = if self.shadow.wants_features() {
            self.shadow.classify_features(batch, xs)
        } else {
            self.shadow.classify_packed_batch(batch)
        };
        self.record_shadow(&preds, shadow);
        Ok(preds)
    }

    /// The router's Compare path: a packed primary (logic) must not pay a
    /// batch copy just because the shadow wanted features.
    fn classify_features_shared(
        &mut self,
        batch: &Arc<PackedBatch>,
        xs: &[Vec<f64>],
    ) -> Result<Vec<usize>, EngineError> {
        let primary = if self.primary.wants_features() {
            self.primary.classify_features_shared(batch, xs)
        } else {
            self.primary.classify_packed_shared(batch)
        };
        let preds = primary?;
        let shadow = if self.shadow.wants_features() {
            self.shadow.classify_features_shared(batch, xs)
        } else {
            self.shadow.classify_packed_shared(batch)
        };
        self.record_shadow(&preds, shadow);
        Ok(preds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::random_model;

    #[test]
    fn packed_logic_engine_matches_the_quantized_nn() {
        let model = random_model("eng", 6, &[4, 3], 2, 1, 17);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let metrics = Arc::new(Metrics::new());
        let model = Arc::new(model);
        let mut engine = PackedLogicEngine::new(
            Arc::clone(&model),
            &r.circuit.netlist,
            2,
            Arc::clone(&metrics),
        )
        .unwrap();
        assert_eq!(engine.name(), "logic");
        assert!(!engine.wants_features());
        assert!(engine.wants_packed());

        let xs: Vec<Vec<f64>> = (0..130)
            .map(|i| (0..6).map(|j| ((i * 3 + j) as f64 * 0.29).sin()).collect())
            .collect();
        let mut batch = PackedBatch::with_capacity(model.input_bits(), xs.len());
        for x in &xs {
            let codes = crate::nn::eval::quantize_input(&model, x);
            let bits = crate::nn::eval::codes_to_bitvec(&codes, model.input_quant.bits);
            batch.push_sample(&bits);
        }
        let preds = engine.classify_packed_batch(&batch).unwrap();
        for (x, p) in xs.iter().zip(&preds) {
            assert_eq!(*p, crate::nn::eval::classify(&model, x));
        }
        assert_eq!(metrics.logic_requests.load(Ordering::Relaxed), 130);
    }

    #[test]
    fn packed_engine_reuses_buffers_across_batches() {
        // The zero-allocation claim (ISSUE 5): scratch and output buffers
        // must be reused across steady-state batches on both the inline
        // and the sharded path.
        let model = random_model("all", 6, &[4, 3], 2, 1, 23);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let model = Arc::new(model);
        let mut engine = PackedLogicEngine::new(
            Arc::clone(&model),
            &r.circuit.netlist,
            2,
            Arc::new(Metrics::new()),
        )
        .unwrap();

        let make_batch = |n: usize, seed: usize| {
            let mut b = PackedBatch::with_capacity(model.input_bits(), n);
            for i in 0..n {
                let x: Vec<f64> =
                    (0..6).map(|j| ((i * 3 + j + seed) as f64 * 0.37).sin()).collect();
                let codes = crate::nn::eval::quantize_input(&model, &x);
                b.push_sample(&crate::nn::eval::codes_to_bitvec(
                    &codes,
                    model.input_quant.bits,
                ));
            }
            Arc::new(b)
        };

        // Warm up both paths: a multi-group batch (sharded) and a
        // single-group batch (inline).
        let big = make_batch(300, 0);
        let small = make_batch(40, 1);
        engine.classify_packed_shared(&big).unwrap();
        engine.classify_packed_shared(&small).unwrap();
        let warm = engine.alloc_stats();
        for round in 0..6 {
            let preds = engine.classify_packed_shared(&big).unwrap();
            assert_eq!(preds.len(), 300, "round {round}");
            engine.classify_packed_shared(&small).unwrap();
        }
        let steady = engine.alloc_stats();
        assert_eq!(
            steady.1, warm.1,
            "steady-state batches must not grow the output buffers"
        );
        // Scratches are bounded by peak shard concurrency (2 here: the big
        // batch splits into 2 ranges), never by the batch count — 12 more
        // batches must not have added a scratch per batch.
        assert!(steady.0 <= 2, "scratch count {} exceeds shard concurrency", steady.0);
    }

    #[test]
    fn logic_engine_reports_optimizer_lut_counts() {
        let model = random_model("lc", 6, &[4, 3], 2, 1, 31);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let engine = PackedLogicEngine::new(
            Arc::new(model),
            &r.circuit.netlist,
            1,
            Arc::new(Metrics::new()),
        )
        .unwrap();
        let (pre, post) = engine.lut_counts().expect("logic engine has LUT counts");
        assert_eq!(pre, r.circuit.netlist.num_luts());
        assert!(post <= pre, "optimizer must not add LUTs");
    }

    #[test]
    fn logic_engine_rejects_mismatched_circuit() {
        let model = random_model("mis", 6, &[4, 3], 2, 1, 1);
        let other = random_model("oth", 8, &[4, 3], 2, 1, 2);
        let r = run_flow(&other, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let err = PackedLogicEngine::new(
            Arc::new(model),
            &r.circuit.netlist,
            1,
            Arc::new(Metrics::new()),
        )
        .err()
        .expect("input-width mismatch must fail construction");
        assert!(matches!(err, EngineError::Construction(_)), "{err}");
    }

    #[test]
    fn native_engine_rejects_mismatched_circuit() {
        // Validation runs before any rustc/dlopen work, so this is
        // deterministic on every host.
        let model = random_model("nm", 6, &[4, 3], 2, 1, 1);
        let other = random_model("no", 8, &[4, 3], 2, 1, 2);
        let r = run_flow(&other, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let err = NativeCodegenEngine::new(
            Arc::new(model),
            &r.circuit.netlist,
            None,
            Arc::new(Metrics::new()),
        )
        .err()
        .expect("input-width mismatch must fail construction");
        assert!(matches!(err, EngineError::Construction(_)), "{err}");
    }

    #[test]
    fn native_engine_fails_typed_when_the_cache_dir_is_unwritable() {
        // The fallback contract: whatever rung of the ladder is missing
        // (here the cache path; elsewhere rustc or dlopen), construction
        // is a typed error the router can catch — never a panic.
        let model = random_model("nf", 6, &[4, 3], 2, 1, 3);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let err = NativeCodegenEngine::new(
            Arc::new(model),
            &r.circuit.netlist,
            Some("/nonexistent-nnt-dir/x.so"),
            Arc::new(Metrics::new()),
        )
        .err()
        .expect("unwritable cache must fail construction");
        assert!(matches!(err, EngineError::Construction(_)), "{err}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns rustc and dlopens — not a Miri workload
    fn mirror_pins_native_bit_exact_against_logic() {
        if !codegen::rustc_available() {
            eprintln!("skipping: rustc or dlopen unavailable on this host");
            return;
        }
        let model = random_model("nat", 6, &[5, 3], 2, 1, 29);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let metrics = Arc::new(Metrics::new());
        let model = Arc::new(model);
        let so = std::env::temp_dir()
            .join(format!("nnt-engine-test-{}.so", std::process::id()));
        let so = so.to_string_lossy().into_owned();
        let native = NativeCodegenEngine::new(
            Arc::clone(&model),
            &r.circuit.netlist,
            Some(&so),
            Arc::clone(&metrics),
        )
        .unwrap();
        let logic = PackedLogicEngine::new(
            Arc::clone(&model),
            &r.circuit.netlist,
            2,
            Arc::clone(&metrics),
        )
        .unwrap();
        let mut mirror =
            MirrorEngine::new(Box::new(native), Box::new(logic), Arc::clone(&metrics));
        assert_eq!(mirror.name(), "native");

        let xs: Vec<Vec<f64>> = (0..200)
            .map(|i| (0..6).map(|j| ((i * 5 + j) as f64 * 0.31).sin()).collect())
            .collect();
        let mut batch = PackedBatch::with_capacity(model.input_bits(), xs.len());
        for x in &xs {
            let codes = crate::nn::eval::quantize_input(&model, x);
            let bits = crate::nn::eval::codes_to_bitvec(&codes, model.input_quant.bits);
            batch.push_sample(&bits);
        }
        let preds = mirror.classify_packed_batch(&batch).unwrap();
        for (x, p) in xs.iter().zip(&preds) {
            assert_eq!(*p, crate::nn::eval::classify(&model, x));
        }
        // The shadow interpreter saw every sample and never disagreed.
        assert_eq!(metrics.disagreements.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.shadow_failures.load(Ordering::Relaxed), 0);
        let _ = std::fs::remove_file(&so);
        let _ = std::fs::remove_file(format!("{so}.rs"));
        let _ = std::fs::remove_file(format!("{so}.meta"));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns rustc and dlopens — not a Miri workload
    fn native_downgrade_is_permanent_visible_and_bit_exact() {
        if !codegen::rustc_available() {
            eprintln!("skipping: rustc or dlopen unavailable on this host");
            return;
        }
        let model = random_model("dwn", 6, &[5, 3], 2, 1, 31);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let metrics = Arc::new(Metrics::new());
        let model = Arc::new(model);
        let so = std::env::temp_dir()
            .join(format!("nnt-engine-downgrade-{}.so", std::process::id()));
        let so = so.to_string_lossy().into_owned();
        let mut native = NativeCodegenEngine::new(
            Arc::clone(&model),
            &r.circuit.netlist,
            Some(&so),
            Arc::clone(&metrics),
        )
        .unwrap();
        let mut batch = PackedBatch::with_capacity(model.input_bits(), 64);
        let xs: Vec<Vec<f64>> = (0..64)
            .map(|i| (0..6).map(|j| ((i * 7 + j) as f64 * 0.17).cos()).collect())
            .collect();
        for x in &xs {
            let codes = crate::nn::eval::quantize_input(&model, x);
            let bits = crate::nn::eval::codes_to_bitvec(&codes, model.input_quant.bits);
            batch.push_sample(&bits);
        }
        let before = native.classify_packed_batch(&batch).unwrap();
        assert_eq!(native.name(), "native");
        assert!(!native.is_downgraded());

        // Force the mid-serve downgrade directly (the fault-injected path
        // is exercised by the chaos suite under --cfg nnt_fault).
        native.downgrade("test-forced");
        assert!(native.is_downgraded());
        assert_eq!(native.name(), "native>interp", "tier must be visible per-reply");
        assert_eq!(metrics.fallback_downgrades.load(Ordering::Relaxed), 1);

        // Interpreter tier serves the same batch bit-exactly, permanently.
        for _ in 0..2 {
            let after = native.classify_packed_batch(&batch).unwrap();
            assert_eq!(before, after, "downgrade must stay bit-exact");
            assert!(native.is_downgraded(), "downgrade is permanent");
        }
        assert_eq!(metrics.fallback_downgrades.load(Ordering::Relaxed), 1);
        let _ = std::fs::remove_file(&so);
        let _ = std::fs::remove_file(format!("{so}.rs"));
        let _ = std::fs::remove_file(format!("{so}.meta"));
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn pjrt_engine_construction_fails_cleanly_in_stub_build() {
        let spec = crate::coordinator::router::PjrtSpec {
            hlo_path: "artifacts/none.hlo.txt".into(),
            batch: 64,
            in_features: 6,
            out_width: 3,
        };
        let err = PjrtNumericEngine::new(&spec, 3, Arc::new(Metrics::new()))
            .err()
            .expect("stub build must not construct a PJRT engine");
        assert!(matches!(err, EngineError::Construction(_)), "{err}");
        assert!(err.to_string().contains("PJRT backend unavailable"), "{err}");
    }

    /// Fixed-output fake engine for mirror tests.
    struct Fixed {
        label: &'static str,
        pred: usize,
        fail: bool,
    }

    impl InferenceEngine for Fixed {
        fn name(&self) -> &'static str {
            self.label
        }
        fn classify_packed_batch(
            &mut self,
            batch: &PackedBatch,
        ) -> Result<Vec<usize>, EngineError> {
            if self.fail {
                return Err(EngineError::Inference("boom".into()));
            }
            Ok(vec![self.pred; batch.num_samples()])
        }
    }

    fn three_sample_batch() -> PackedBatch {
        let mut b = PackedBatch::with_capacity(2, 3);
        for s in 0..3 {
            b.push_sample_bools(&[s % 2 == 0, s == 1]);
        }
        b
    }

    #[test]
    fn mirror_counts_disagreements_and_replies_from_primary() {
        let metrics = Arc::new(Metrics::new());
        let mut mirror = MirrorEngine::new(
            Box::new(Fixed { label: "a", pred: 1, fail: false }),
            Box::new(Fixed { label: "b", pred: 2, fail: false }),
            Arc::clone(&metrics),
        );
        assert_eq!(mirror.name(), "a");
        let preds = mirror.classify_packed_batch(&three_sample_batch()).unwrap();
        assert_eq!(preds, vec![1, 1, 1], "mirror must reply from the primary");
        assert_eq!(metrics.disagreements.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn mirror_survives_shadow_failure() {
        let metrics = Arc::new(Metrics::new());
        let mut mirror = MirrorEngine::new(
            Box::new(Fixed { label: "a", pred: 0, fail: false }),
            Box::new(Fixed { label: "b", pred: 0, fail: true }),
            Arc::clone(&metrics),
        );
        let preds = mirror.classify_packed_batch(&three_sample_batch()).unwrap();
        assert_eq!(preds, vec![0, 0, 0]);
        assert_eq!(metrics.disagreements.load(Ordering::Relaxed), 0);
        // Shadow-only failures must not count as dropped requests.
        assert_eq!(metrics.shadow_failures.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.engine_failures.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn dispatch_routes_on_wants_features() {
        // A packed-only engine ignores offered features.
        let mut fixed = Fixed { label: "a", pred: 4, fail: false };
        let batch = Arc::new(three_sample_batch());
        let xs = vec![vec![0.0]; 3];
        let preds = dispatch(&mut fixed, &batch, Some(&xs)).unwrap();
        assert_eq!(preds, vec![4, 4, 4]);
    }

    #[test]
    fn mirror_shares_packed_batches_with_both_engines() {
        let metrics = Arc::new(Metrics::new());
        let mut mirror = MirrorEngine::new(
            Box::new(Fixed { label: "a", pred: 1, fail: false }),
            Box::new(Fixed { label: "b", pred: 1, fail: false }),
            Arc::clone(&metrics),
        );
        let batch = Arc::new(three_sample_batch());
        let preds = dispatch(&mut mirror, &batch, None).unwrap();
        assert_eq!(preds, vec![1, 1, 1]);
        assert_eq!(metrics.disagreements.load(Ordering::Relaxed), 0);
    }
}
