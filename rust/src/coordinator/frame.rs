//! Length-prefixed binary wire frames for the serving front end.
//!
//! The JSON-lines protocol re-parses floats and re-binarizes on every
//! request — fine for `nc`, fatal for the latency budget the paper buys
//! with fixed-function logic. A binary frame carries **pre-binarized**
//! packed `u64` feature words end to end, so the server-side cost of a
//! classify request is a bounds check plus a word scatter into the
//! [`PackedBatch`] the engine consumes (no float parse, no quantize).
//!
//! ## Frame layout (version 1, all integers little-endian)
//!
//! ```text
//! offset size field
//! 0      1    magic       0xF5 (never a JSON first byte — see sniffing)
//! 1      1    version     0x01
//! 2      1    type        1 = CLASSIFY_REQ   2 = CLASSIFY_RESP
//!                         3 = ERROR          4 = OVERLOAD
//!                         5 = DEADLINE       6 = CLASSIFY_REQ_DL
//! 3      1    name_len M  model-name bytes (0 = default model)
//! 4      4    payload_len P = bytes after this 12-byte header
//! 8      2    samples S
//! 10     2    bits B      circuit-input bits per sample (requests only)
//! 12     M    model name  UTF-8
//! 12+M   …    body        REQ:  S × ceil(B/64) × 8 bytes of u64 words,
//!                               sample-major, LSB-first within a word
//!                         REQ_DL: u32 deadline budget in ms (LE), then
//!                               the same word layout as REQ
//!                         RESP: S × 2 bytes of u16 class ids
//!                         ERROR/OVERLOAD/DEADLINE: UTF-8 message
//! ```
//!
//! `P` must equal `M + body-size` exactly; a frame longer than
//! [`MAX_FRAME_PAYLOAD`] is rejected before any buffering decision, so a
//! hostile length prefix cannot balloon a connection buffer. Bits at or
//! beyond `B` in a sample's last word must be zero (the [`BitVec`] tail
//! invariant the batcher's word-scatter fast path relies on) — stray bits
//! are a protocol error, not silently masked.
//!
//! ## Protocol sniffing
//!
//! The magic byte `0xF5` is not valid UTF-8 as a first byte, so it can
//! never begin a JSON-lines request (`{`, whitespace, or any printable
//! text). The server sniffs the first byte of each connection and routes
//! it to the JSON or binary state machine — both protocols share one port
//! and every pre-existing JSON client keeps working unchanged. See
//! `rust/DESIGN.md` §Serving-v2 for why sniffing beat a version-negotiation
//! handshake.
//!
//! ## Incremental parsing
//!
//! [`decode`] is a pure function over an accumulation buffer: it returns
//! `Ok(None)` while the buffer holds only a partial frame, and
//! `Ok(Some((frame, consumed)))` once a whole frame is available — the
//! caller drains `consumed` bytes and calls again, so any byte-split
//! across reads (one syscall delivering half a header, ten frames, or a
//! frame and a half) parses identically. Fatal errors ([`FrameError`])
//! mean the stream is unsynchronized and the connection must be dropped
//! after a best-effort error frame.

use std::fmt;

use crate::util::bitvec::{BitVec, PackedBatch};

/// First byte of every binary frame. `0xF5` is a UTF-8 continuation-range
/// byte, so no JSON-lines request can ever start with it.
pub const MAGIC: u8 = 0xF5;

/// Wire-format version this module speaks.
pub const VERSION: u8 = 1;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 12;

/// Hard cap on one frame's payload — same budget as the JSON path's
/// per-line cap, enforced straight off the length prefix so a hostile
/// header cannot grow the connection buffer without bound.
pub const MAX_FRAME_PAYLOAD: usize = 1 << 20;

/// Hard cap on samples per classify request frame.
pub const MAX_SAMPLES: usize = 4096;

/// Frame type tags (byte 2).
pub const TYPE_CLASSIFY_REQ: u8 = 1;
/// Classify response: `S` u16 class ids.
pub const TYPE_CLASSIFY_RESP: u8 = 2;
/// Typed protocol/engine error; connection stays usable unless the stream
/// itself is unsynchronized.
pub const TYPE_ERROR: u8 = 3;
/// Typed admission-control rejection: the model's queue is full. Distinct
/// from [`TYPE_ERROR`] so clients can back off instead of treating
/// overload as a malformed request.
pub const TYPE_OVERLOAD: u8 = 4;
/// Typed deadline rejection: the request's latency budget elapsed before
/// an engine evaluated it, so it was shed unanswered. Distinct from
/// [`TYPE_OVERLOAD`] — retrying an expired request verbatim is pointless;
/// the client should raise its budget or reduce load.
pub const TYPE_DEADLINE: u8 = 5;
/// Classify request carrying a deadline budget: identical to
/// [`TYPE_CLASSIFY_REQ`] except the body starts with a `u32`
/// little-endian millisecond budget before the sample words.
pub const TYPE_CLASSIFY_REQ_DL: u8 = 6;

/// Words per sample for a `bits`-wide circuit input.
#[inline]
pub fn words_per_sample(bits: u16) -> usize {
    (bits as usize).div_ceil(64)
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Classify `words.len() / ceil(bits/64)` samples on `model` (or the
    /// default). `words` is sample-major: each sample's `ceil(bits/64)`
    /// LSB-first words are contiguous. `deadline_ms` is the optional
    /// latency budget from a [`TYPE_CLASSIFY_REQ_DL`] frame; requests
    /// still queued when it elapses are shed with a [`Frame::Deadline`]
    /// reply instead of being evaluated late.
    ClassifyReq {
        model: Option<String>,
        bits: u16,
        words: Vec<u64>,
        deadline_ms: Option<u32>,
    },
    /// Per-sample predicted classes, in request sample order.
    ClassifyResp { classes: Vec<u16> },
    /// Protocol or engine error.
    Error { message: String },
    /// Admission-control rejection (queue full) — resubmit after backoff.
    Overload { message: String },
    /// Deadline rejection — the request's budget elapsed before
    /// evaluation. Raise the budget or reduce load; a verbatim retry of
    /// an already-late request only wastes queue capacity.
    Deadline { message: String },
}

impl Frame {
    /// Samples carried by a classify request (0 for other frame types).
    pub fn num_samples(&self) -> usize {
        match self {
            Frame::ClassifyReq { bits, words, .. } => {
                words.len() / words_per_sample(*bits)
            }
            Frame::ClassifyResp { classes } => classes.len(),
            _ => 0,
        }
    }
}

/// Why a byte stream failed to parse as a frame. Every variant is fatal
/// for the connection: the stream is unsynchronized past the bad header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// First byte was not [`MAGIC`] (the caller should have sniffed JSON).
    BadMagic(u8),
    /// Unsupported wire-format version.
    BadVersion(u8),
    /// Unknown frame type tag.
    BadType(u8),
    /// Length prefix exceeds [`MAX_FRAME_PAYLOAD`].
    Oversized(u32),
    /// Length prefix disagrees with the header's own field arithmetic.
    LengthMismatch { expected: usize, got: usize },
    /// Classify request with more than [`MAX_SAMPLES`] samples.
    TooManySamples(u16),
    /// Classify request with a zero-bit sample width or zero samples.
    EmptyRequest,
    /// A sample word has bits set at or beyond the declared width.
    StrayBits { sample: usize },
    /// Model name is not valid UTF-8.
    BadName,
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic(b) => write!(f, "bad magic byte 0x{b:02X}"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported frame version {v} (speak {VERSION})")
            }
            FrameError::BadType(t) => write!(f, "unknown frame type {t}"),
            FrameError::Oversized(n) => write!(
                f,
                "frame payload {n} bytes exceeds the {MAX_FRAME_PAYLOAD}-byte cap"
            ),
            FrameError::LengthMismatch { expected, got } => write!(
                f,
                "length prefix says {got} payload bytes, header fields imply {expected}"
            ),
            FrameError::TooManySamples(s) => {
                write!(f, "{s} samples exceeds the {MAX_SAMPLES}-sample frame cap")
            }
            FrameError::EmptyRequest => {
                write!(f, "classify request needs ≥ 1 sample of ≥ 1 bit")
            }
            FrameError::StrayBits { sample } => write!(
                f,
                "sample {sample} has bits set past the declared width"
            ),
            FrameError::BadName => write!(f, "model name is not valid UTF-8"),
        }
    }
}

impl std::error::Error for FrameError {}

#[inline]
fn u16_le(b: &[u8]) -> u16 {
    u16::from_le_bytes([b[0], b[1]])
}

#[inline]
fn u32_le(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

/// Incrementally decode the first complete frame in `buf`.
///
/// * `Ok(None)` — `buf` holds only a partial frame; read more bytes and
///   call again (nothing is consumed).
/// * `Ok(Some((frame, consumed)))` — drain `consumed` bytes; more frames
///   may follow in the remainder (pipelining).
/// * `Err(_)` — the stream is unsynchronized; drop the connection.
///
/// Every header invariant — magic, version, type, the payload cap, and
/// the exact length arithmetic — is checked *before* the payload is
/// touched, so a truncated or hostile length prefix costs nothing.
pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, FrameError> {
    if buf.is_empty() {
        return Ok(None);
    }
    if buf[0] != MAGIC {
        return Err(FrameError::BadMagic(buf[0]));
    }
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    if buf[1] != VERSION {
        return Err(FrameError::BadVersion(buf[1]));
    }
    let ftype = buf[2];
    let name_len = buf[3] as usize;
    let payload = u32_le(&buf[4..8]);
    if payload as usize > MAX_FRAME_PAYLOAD {
        return Err(FrameError::Oversized(payload));
    }
    let payload = payload as usize;
    let samples = u16_le(&buf[8..10]);
    let bits = u16_le(&buf[10..12]);
    // Validate the length arithmetic from header fields alone — before
    // waiting for (or trusting) the payload bytes.
    let body = match ftype {
        TYPE_CLASSIFY_REQ | TYPE_CLASSIFY_REQ_DL => {
            if samples == 0 || bits == 0 {
                return Err(FrameError::EmptyRequest);
            }
            if samples as usize > MAX_SAMPLES {
                return Err(FrameError::TooManySamples(samples));
            }
            let prefix = if ftype == TYPE_CLASSIFY_REQ_DL { 4 } else { 0 };
            prefix + samples as usize * words_per_sample(bits) * 8
        }
        TYPE_CLASSIFY_RESP => samples as usize * 2,
        TYPE_ERROR | TYPE_OVERLOAD | TYPE_DEADLINE => {
            payload.saturating_sub(name_len)
        }
        t => return Err(FrameError::BadType(t)),
    };
    let expected = name_len + body;
    if payload != expected {
        return Err(FrameError::LengthMismatch { expected, got: payload });
    }
    let total = HEADER_LEN + payload;
    if buf.len() < total {
        return Ok(None);
    }
    let name_bytes = &buf[HEADER_LEN..HEADER_LEN + name_len];
    let body_bytes = &buf[HEADER_LEN + name_len..total];
    let frame = match ftype {
        TYPE_CLASSIFY_REQ | TYPE_CLASSIFY_REQ_DL => {
            let model = if name_len == 0 {
                None
            } else {
                Some(
                    std::str::from_utf8(name_bytes)
                        .map_err(|_| FrameError::BadName)?
                        .to_string(),
                )
            };
            let (deadline_ms, word_bytes) = if ftype == TYPE_CLASSIFY_REQ_DL {
                (Some(u32_le(&body_bytes[..4])), &body_bytes[4..])
            } else {
                (None, body_bytes)
            };
            let wps = words_per_sample(bits);
            let mut words = Vec::with_capacity(samples as usize * wps);
            for chunk in word_bytes.chunks_exact(8) {
                words.push(u64::from_le_bytes(chunk.try_into().expect("chunks_exact(8)")));
            }
            // The batcher's word-scatter fast path assumes the BitVec tail
            // invariant; enforce it on the wire instead of masking, so a
            // client bug surfaces as a typed error, not silent truncation.
            let tail = bits as usize & 63;
            if tail != 0 {
                for (s, sample) in words.chunks_exact(wps).enumerate() {
                    if sample[wps - 1] >> tail != 0 {
                        return Err(FrameError::StrayBits { sample: s });
                    }
                }
            }
            Frame::ClassifyReq { model, bits, words, deadline_ms }
        }
        TYPE_CLASSIFY_RESP => {
            let classes =
                body_bytes.chunks_exact(2).map(u16_le).collect::<Vec<u16>>();
            Frame::ClassifyResp { classes }
        }
        t => {
            let message = String::from_utf8_lossy(body_bytes).into_owned();
            match t {
                TYPE_ERROR => Frame::Error { message },
                TYPE_OVERLOAD => Frame::Overload { message },
                _ => Frame::Deadline { message },
            }
        }
    };
    Ok(Some((frame, total)))
}

fn header(ftype: u8, name_len: u8, payload: u32, samples: u16, bits: u16) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0] = MAGIC;
    h[1] = VERSION;
    h[2] = ftype;
    h[3] = name_len;
    h[4..8].copy_from_slice(&payload.to_le_bytes());
    h[8..10].copy_from_slice(&samples.to_le_bytes());
    h[10..12].copy_from_slice(&bits.to_le_bytes());
    h
}

/// Encode a classify request. `words` is sample-major
/// (`ceil(bits/64)` LSB-first words per sample); its length fixes the
/// sample count. Panics on arithmetic the wire format cannot carry
/// (encoders are in-process clients/tests — a wire peer can only produce
/// [`FrameError`]s, never panics).
pub fn encode_classify_req(model: Option<&str>, bits: u16, words: &[u64]) -> Vec<u8> {
    encode_req(model, bits, words, None)
}

/// Encode a classify request carrying a `deadline_ms` latency budget
/// ([`TYPE_CLASSIFY_REQ_DL`]). Same layout and panics as
/// [`encode_classify_req`] plus the 4-byte budget prefix.
pub fn encode_classify_req_deadline(
    model: Option<&str>,
    bits: u16,
    words: &[u64],
    deadline_ms: u32,
) -> Vec<u8> {
    encode_req(model, bits, words, Some(deadline_ms))
}

fn encode_req(
    model: Option<&str>,
    bits: u16,
    words: &[u64],
    deadline_ms: Option<u32>,
) -> Vec<u8> {
    assert!(bits > 0, "encode_classify_req: zero-bit samples");
    let wps = words_per_sample(bits);
    assert_eq!(words.len() % wps, 0, "words must be a whole number of samples");
    let samples = words.len() / wps;
    assert!(
        (1..=MAX_SAMPLES).contains(&samples),
        "encode_classify_req: {samples} samples (cap {MAX_SAMPLES})"
    );
    let name = model.unwrap_or("").as_bytes();
    assert!(name.len() <= u8::MAX as usize, "model name exceeds 255 bytes");
    let prefix = if deadline_ms.is_some() { 4 } else { 0 };
    let payload = name.len() + prefix + words.len() * 8;
    let ftype = if deadline_ms.is_some() {
        TYPE_CLASSIFY_REQ_DL
    } else {
        TYPE_CLASSIFY_REQ
    };
    let mut out = Vec::with_capacity(HEADER_LEN + payload);
    out.extend_from_slice(&header(
        ftype,
        name.len() as u8,
        payload as u32,
        samples as u16,
        bits,
    ));
    out.extend_from_slice(name);
    if let Some(ms) = deadline_ms {
        out.extend_from_slice(&ms.to_le_bytes());
    }
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

/// Encode a classify response (one u16 class per request sample).
pub fn encode_classify_resp(classes: &[u16]) -> Vec<u8> {
    assert!(classes.len() <= u16::MAX as usize, "class count exceeds u16");
    let payload = classes.len() * 2;
    let mut out = Vec::with_capacity(HEADER_LEN + payload);
    out.extend_from_slice(&header(
        TYPE_CLASSIFY_RESP,
        0,
        payload as u32,
        classes.len() as u16,
        0,
    ));
    for c in classes {
        out.extend_from_slice(&c.to_le_bytes());
    }
    out
}

fn encode_message(ftype: u8, message: &str) -> Vec<u8> {
    // Truncate pathological messages instead of failing the reply path.
    let msg = &message.as_bytes()[..message.len().min(MAX_FRAME_PAYLOAD)];
    let mut out = Vec::with_capacity(HEADER_LEN + msg.len());
    out.extend_from_slice(&header(ftype, 0, msg.len() as u32, 0, 0));
    out.extend_from_slice(msg);
    out
}

/// Encode a typed error frame.
pub fn encode_error(message: &str) -> Vec<u8> {
    encode_message(TYPE_ERROR, message)
}

/// Encode a typed overload (admission-control) rejection frame.
pub fn encode_overload(message: &str) -> Vec<u8> {
    encode_message(TYPE_OVERLOAD, message)
}

/// Encode a typed deadline rejection frame — the request's latency
/// budget elapsed while it was still queued, so it was shed unevaluated.
pub fn encode_deadline(message: &str) -> Vec<u8> {
    encode_message(TYPE_DEADLINE, message)
}

/// Scatter a decoded classify request straight into a [`PackedBatch`] —
/// the "bounds check plus a word scatter" the module docs promise. The
/// decode layer already validated widths and the tail invariant.
pub fn request_into_packed(bits: u16, words: &[u64]) -> PackedBatch {
    let wps = words_per_sample(bits);
    let samples = words.len() / wps;
    let mut packed = PackedBatch::with_capacity(bits as usize, samples);
    for sample in words.chunks_exact(wps) {
        packed.push_sample_words(sample);
    }
    packed
}

/// One sample of a decoded classify request as a [`BitVec`] in the
/// batcher's native format (the decode layer already enforced the tail
/// invariant).
pub fn sample_bits(bits: u16, words: &[u64], sample: usize) -> BitVec {
    let wps = words_per_sample(bits);
    BitVec::from_words(bits as usize, words[sample * wps..(sample + 1) * wps].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_words(samples: usize, bits: u16, seed: u64) -> Vec<u64> {
        let wps = words_per_sample(bits);
        let mut rng = crate::util::prng::Xoshiro256::new(seed);
        let mut words = Vec::with_capacity(samples * wps);
        for _ in 0..samples {
            for w in 0..wps {
                let mut v = rng.next_u64();
                if w == wps - 1 && bits as usize & 63 != 0 {
                    v &= (1u64 << (bits as usize & 63)) - 1;
                }
                words.push(v);
            }
        }
        words
    }

    #[test]
    fn classify_req_round_trips() {
        for (samples, bits) in [(1usize, 6u16), (3, 64), (5, 70), (64, 1)] {
            let words = req_words(samples, bits, 42);
            let enc = encode_classify_req(Some("jsc-s"), bits, &words);
            let (frame, consumed) = decode(&enc).unwrap().expect("complete frame");
            assert_eq!(consumed, enc.len());
            match frame {
                Frame::ClassifyReq { model, bits: b, words: w, deadline_ms } => {
                    assert_eq!(model.as_deref(), Some("jsc-s"));
                    assert_eq!(b, bits);
                    assert_eq!(w, words);
                    assert_eq!(deadline_ms, None);
                }
                f => panic!("wrong frame {f:?}"),
            }
        }
    }

    #[test]
    fn deadline_classify_req_round_trips() {
        for (samples, bits, budget) in [(1usize, 6u16, 0u32), (3, 70, 25), (2, 64, u32::MAX)] {
            let words = req_words(samples, bits, 99);
            let enc = encode_classify_req_deadline(Some("jsc-s"), bits, &words, budget);
            assert_eq!(enc[2], TYPE_CLASSIFY_REQ_DL);
            let (frame, consumed) = decode(&enc).unwrap().expect("complete frame");
            assert_eq!(consumed, enc.len());
            match frame {
                Frame::ClassifyReq { model, bits: b, words: w, deadline_ms } => {
                    assert_eq!(model.as_deref(), Some("jsc-s"));
                    assert_eq!(b, bits);
                    assert_eq!(w, words);
                    assert_eq!(deadline_ms, Some(budget));
                }
                f => panic!("wrong frame {f:?}"),
            }
        }
    }

    #[test]
    fn default_model_is_empty_name() {
        let enc = encode_classify_req(None, 8, &[0xA5]);
        let (frame, _) = decode(&enc).unwrap().unwrap();
        assert!(matches!(frame, Frame::ClassifyReq { model: None, .. }));
    }

    #[test]
    fn partial_header_and_partial_payload_return_none() {
        let enc = encode_classify_req(Some("m"), 12, &[0x0FFF, 0x0ABC]);
        for cut in 0..enc.len() {
            assert_eq!(
                decode(&enc[..cut]).unwrap(),
                None,
                "prefix of {cut} bytes must be incomplete, not an error"
            );
        }
        assert!(decode(&enc).unwrap().is_some());
    }

    #[test]
    fn pipelined_frames_decode_in_sequence() {
        let mut buf = encode_classify_req(None, 6, &[0b101010]);
        buf.extend_from_slice(&encode_classify_req(Some("b"), 6, &[0b111]));
        let (f1, n1) = decode(&buf).unwrap().unwrap();
        assert_eq!(f1.num_samples(), 1);
        let (f2, n2) = decode(&buf[n1..]).unwrap().unwrap();
        assert_eq!(n1 + n2, buf.len());
        assert!(matches!(f2, Frame::ClassifyReq { model: Some(m), .. } if m == "b"));
    }

    #[test]
    fn resp_error_and_overload_round_trip() {
        let enc = encode_classify_resp(&[3, 0, 65535]);
        let (f, _) = decode(&enc).unwrap().unwrap();
        assert_eq!(f, Frame::ClassifyResp { classes: vec![3, 0, 65535] });

        let enc = encode_error("no model named 'x'");
        let (f, _) = decode(&enc).unwrap().unwrap();
        assert_eq!(f, Frame::Error { message: "no model named 'x'".into() });

        let enc = encode_overload("queue full (depth 64)");
        let (f, _) = decode(&enc).unwrap().unwrap();
        assert_eq!(f, Frame::Overload { message: "queue full (depth 64)".into() });

        let enc = encode_deadline("deadline exceeded: shed after 5 ms");
        let (f, _) = decode(&enc).unwrap().unwrap();
        assert_eq!(
            f,
            Frame::Deadline { message: "deadline exceeded: shed after 5 ms".into() }
        );
    }

    #[test]
    fn bad_magic_version_type_are_typed_errors() {
        let good = encode_classify_req(None, 6, &[1]);
        let mut bad = good.clone();
        bad[0] = b'{';
        assert_eq!(decode(&bad), Err(FrameError::BadMagic(b'{')));
        let mut bad = good.clone();
        bad[1] = 9;
        assert_eq!(decode(&bad), Err(FrameError::BadVersion(9)));
        let mut bad = good.clone();
        bad[2] = 77;
        assert_eq!(decode(&bad), Err(FrameError::BadType(77)));
    }

    #[test]
    fn hostile_length_prefix_is_rejected_from_the_header_alone() {
        let mut enc = encode_classify_req(None, 6, &[1]);
        // Claim a 64 MiB payload: must be rejected without buffering it.
        enc[4..8].copy_from_slice(&(64u32 << 20).to_le_bytes());
        assert_eq!(decode(&enc[..HEADER_LEN]), Err(FrameError::Oversized(64 << 20)));
        // Length prefix that disagrees with S × W × 8.
        let mut enc = encode_classify_req(None, 6, &[1]);
        enc[4..8].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(decode(&enc), Err(FrameError::LengthMismatch { .. })));
    }

    #[test]
    fn zero_sample_and_oversized_sample_counts_are_rejected() {
        let mut enc = encode_classify_req(None, 6, &[1]);
        enc[8..10].copy_from_slice(&0u16.to_le_bytes());
        assert_eq!(decode(&enc), Err(FrameError::EmptyRequest));
        let mut enc = encode_classify_req(None, 6, &[1]);
        enc[8..10].copy_from_slice(&(MAX_SAMPLES as u16 + 1).to_le_bytes());
        assert_eq!(decode(&enc), Err(FrameError::TooManySamples(MAX_SAMPLES as u16 + 1)));
    }

    #[test]
    fn stray_bits_past_the_width_are_a_protocol_error() {
        let enc = encode_classify_req(None, 6, &[0b100_0000]); // bit 6 of a 6-bit sample
        assert_eq!(decode(&enc), Err(FrameError::StrayBits { sample: 0 }));
    }

    #[test]
    fn request_into_packed_is_bit_exact() {
        let bits = 10u16;
        let words = req_words(130, bits, 7);
        let packed = request_into_packed(bits, &words);
        assert_eq!(packed.num_samples(), 130);
        let mut want = PackedBatch::with_capacity(bits as usize, 130);
        for s in 0..130 {
            want.push_sample(&sample_bits(bits, &words, s));
        }
        assert_eq!(packed, want);
    }
}
