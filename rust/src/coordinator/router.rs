//! Request router: a dynamic batcher in front of one pluggable
//! [`InferenceEngine`].
//!
//! The coordinator's demonstration goal (`rust/DESIGN.md` §Serving): the
//! synthesized fixed-function logic *is* the production inference path —
//! bit-exact against the quantized NN — while the AOT-compiled XLA
//! executable serves as the numeric reference. The [`Policy`] names which
//! engine stack [`RouterBuilder::build`] assembles:
//!
//! * `Logic` — everything on the netlist simulator (the paper's artifact)
//! * `Numeric` — everything on PJRT
//! * `Compare` — a [`MirrorEngine`]: reply from logic, shadow onto PJRT,
//!   count disagreements
//! * `Native` — the circuit lowered to machine code
//!   ([`crate::coordinator::engine::NativeCodegenEngine`]); when codegen is
//!   unavailable (no rustc, non-Linux) the build falls back to the
//!   interpreter engine with a notice instead of failing the router
//!
//! The dispatcher itself is backend-agnostic: it drains batches and hands
//! them to the engine via [`crate::coordinator::engine::dispatch`]. Engine
//! construction happens before the router accepts traffic, and failures
//! (missing HLO artifact, incompatible circuit) come back as typed errors
//! from [`RouterBuilder::build`] instead of panicking the dispatcher thread
//! and hanging every submitter.
//!
//! The logic path is packed end to end: `submit` binarizes the features
//! into a [`BitVec`], the batcher flushes a
//! [`PackedBatch`](crate::util::bitvec::PackedBatch), and the engine hands
//! that straight to one shared compiled netlist — inline for
//! single-lane-group batches, sharded across the engine's worker pool for
//! larger ones. No per-sample `Vec` exists between
//! [`Batcher::next_batch`] and the simulator.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::{
    Batch, BatchPolicy, Batcher, Reply, ReplyNotify, Request, SubmitError,
};
use crate::coordinator::engine::{
    self, EngineError, InferenceEngine, MirrorEngine, NativeCodegenEngine,
    PackedLogicEngine, PjrtNumericEngine,
};
use crate::coordinator::metrics::Metrics;
use crate::error::NnError;
use crate::logic::netlist::LutNetlist;
use crate::nn::eval::{codes_to_bitvec, quantize_input};
use crate::nn::model::Model;
use crate::util::bitvec::BitVec;
use crate::util::sync::{mpsc, thread, Mutex};

/// Routing policy: which engine stack the builder assembles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Logic,
    Numeric,
    Compare,
    /// Native codegen with interpreter fallback (see the module docs).
    Native,
}

impl Policy {
    /// Parse "logic" / "pjrt" / "compare" / "native".
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "logic" => Some(Policy::Logic),
            "pjrt" | "numeric" => Some(Policy::Numeric),
            "compare" | "both" => Some(Policy::Compare),
            "native" => Some(Policy::Native),
            _ => None,
        }
    }
}

/// How to construct the PJRT engine. The engine itself is `!Send` (its C
/// handles are reference-counted without atomics), so the builder carries a
/// *spec* and the engine is instantiated on the dispatcher thread where it
/// lives for the router's whole lifetime.
#[derive(Clone, Debug)]
pub struct PjrtSpec {
    /// Path to `artifacts/<arch>.hlo.txt`.
    pub hlo_path: String,
    /// Compiled batch size of the artifact.
    pub batch: usize,
    /// Input features.
    pub in_features: usize,
    /// Output width.
    pub out_width: usize,
}

impl PjrtSpec {
    /// Cheap pre-spawn validation: the backend must be compiled in and the
    /// HLO artifact readable. Full load/compile happens on the dispatcher
    /// thread (the loaded engine is not `Send`).
    pub fn preflight(&self) -> Result<(), EngineError> {
        if !crate::runtime::pjrt::backend_available() {
            return Err(EngineError::Construction(format!(
                "PJRT backend unavailable: built without the `xla` feature \
                 (cannot load {})",
                self.hlo_path
            )));
        }
        if let Err(e) = std::fs::metadata(&self.hlo_path) {
            return Err(EngineError::Construction(format!(
                "HLO artifact {}: {e}",
                self.hlo_path
            )));
        }
        Ok(())
    }
}

/// What the dispatcher reports back once its engine is constructed.
struct EngineMeta {
    name: &'static str,
    wants_features: bool,
    wants_packed: bool,
    /// `(LUTs before, after)` the compile-time netlist optimizer, when the
    /// engine evaluates a compiled circuit.
    lut_counts: Option<(usize, usize)>,
}

/// Builder for a [`Router`]. Replaces the old 6-positional-argument
/// `Router::start`:
///
/// ```ignore
/// let router = RouterBuilder::new(model)
///     .circuit(flow.circuit.netlist)
///     .engine(Policy::Logic)
///     .batch_policy(BatchPolicy::default())
///     .workers(4)
///     .build()?;
/// ```
pub struct RouterBuilder {
    model: Model,
    netlist: Option<LutNetlist>,
    pjrt: Option<PjrtSpec>,
    policy: Policy,
    batch_policy: BatchPolicy,
    workers: usize,
    native_cache: Option<String>,
}

impl RouterBuilder {
    /// Start a builder for `model` (logic policy, default batch policy,
    /// one worker).
    pub fn new(model: Model) -> RouterBuilder {
        RouterBuilder {
            model,
            netlist: None,
            pjrt: None,
            policy: Policy::Logic,
            batch_policy: BatchPolicy::default(),
            workers: 1,
            native_cache: None,
        }
    }

    /// Attach the synthesized (or artifact-loaded) logic circuit. Required
    /// for the `Logic` and `Compare` policies.
    pub fn circuit(mut self, netlist: LutNetlist) -> Self {
        self.netlist = Some(netlist);
        self
    }

    /// Attach a PJRT engine spec. Required for `Numeric`; optional shadow
    /// for `Compare`.
    pub fn pjrt(mut self, spec: PjrtSpec) -> Self {
        self.pjrt = Some(spec);
        self
    }

    /// Select the engine stack (default: `Policy::Logic`).
    pub fn engine(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Where the `Native` policy caches its built `.so` (next to the
    /// circuit bundle when serving from one). Without it, a
    /// fingerprint-keyed path under the temp dir is used.
    pub fn native_cache(mut self, so_path: impl Into<String>) -> Self {
        self.native_cache = Some(so_path.into());
        self
    }

    /// Set the batch flush policy.
    pub fn batch_policy(mut self, bp: BatchPolicy) -> Self {
        self.batch_policy = bp;
        self
    }

    /// Size the logic engine's shard pool: with ≥ 2 workers, batches
    /// spanning multiple 64-sample lane groups are evaluated in parallel on
    /// one shared compiled netlist.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Sensible shard-worker default for interactive servers: available
    /// parallelism, capped at 4 (one place for the policy — the CLI and
    /// the serving example both quote it).
    pub fn default_workers() -> usize {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(4)
    }

    /// Validate the configuration, construct the engine stack, and start
    /// the dispatcher. Engine-construction failures (missing circuit or
    /// HLO artifact, absent backend, incompatible widths) return here as
    /// typed errors — the router never starts half-alive.
    pub fn build(self) -> Result<Router, NnError> {
        let RouterBuilder {
            model,
            netlist,
            pjrt,
            policy,
            batch_policy,
            workers,
            native_cache,
        } = self;
        let needs_logic = matches!(policy, Policy::Logic | Policy::Compare | Policy::Native);
        if needs_logic && netlist.is_none() {
            return Err(NnError::Engine(EngineError::Construction(format!(
                "{policy:?} routing needs a logic circuit (RouterBuilder::circuit)"
            ))));
        }
        if policy == Policy::Numeric && pjrt.is_none() {
            return Err(NnError::Engine(EngineError::Construction(
                "Numeric routing needs a PJRT spec (RouterBuilder::pjrt)".into(),
            )));
        }
        if matches!(policy, Policy::Numeric | Policy::Compare) {
            if let Some(spec) = &pjrt {
                spec.preflight().map_err(NnError::Engine)?;
            }
        }

        let model = Arc::new(model);
        let metrics = Arc::new(Metrics::new());
        // The batcher shares the model's metrics so admission decisions
        // (overload rejections, queue high-watermark) land in the same
        // per-model report the `metrics` admin command renders.
        let batcher = Arc::new(Batcher::with_metrics(
            batch_policy,
            model.input_bits(),
            Some(Arc::clone(&metrics)),
        ));

        // The engine is constructed on the dispatcher thread (it may own
        // non-`Send` handles); readiness — or the construction error — is
        // reported back over this channel before `build` returns.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<EngineMeta, EngineError>>();
        let b = Arc::clone(&batcher);
        let m = Arc::clone(&metrics);
        let model_for_engine = Arc::clone(&model);
        let metrics_for_engine = Arc::clone(&metrics);
        let make_engine = move || -> Result<Box<dyn InferenceEngine>, EngineError> {
            let logic = |metrics: Arc<Metrics>| -> Result<Box<PackedLogicEngine>, EngineError> {
                let nl = netlist.as_ref().ok_or_else(|| {
                    EngineError::Construction("logic engine needs a circuit".into())
                })?;
                Ok(Box::new(PackedLogicEngine::new(
                    Arc::clone(&model_for_engine),
                    nl,
                    workers,
                    metrics,
                )?))
            };
            match policy {
                Policy::Logic => Ok(logic(metrics_for_engine)?),
                Policy::Numeric => {
                    let spec = pjrt.as_ref().ok_or_else(|| {
                        EngineError::Construction("numeric engine needs a PJRT spec".into())
                    })?;
                    Ok(Box::new(PjrtNumericEngine::new(
                        spec,
                        model_for_engine.num_classes,
                        metrics_for_engine,
                    )?))
                }
                Policy::Compare => {
                    let primary = logic(Arc::clone(&metrics_for_engine))?;
                    match pjrt.as_ref() {
                        Some(spec) => {
                            let shadow = Box::new(PjrtNumericEngine::new(
                                spec,
                                model_for_engine.num_classes,
                                Arc::clone(&metrics_for_engine),
                            )?);
                            Ok(Box::new(MirrorEngine::new(
                                primary,
                                shadow,
                                metrics_for_engine,
                            )))
                        }
                        // No numeric reference available: serve logic alone.
                        None => Ok(primary),
                    }
                }
                Policy::Native => {
                    let nl = netlist.as_ref().ok_or_else(|| {
                        EngineError::Construction("native engine needs a circuit".into())
                    })?;
                    match NativeCodegenEngine::new(
                        Arc::clone(&model_for_engine),
                        nl,
                        native_cache.as_deref(),
                        Arc::clone(&metrics_for_engine),
                    ) {
                        Ok(native) => Ok(Box::new(native)),
                        // The fallback ladder: native construction failing
                        // (no rustc, dlopen stub, build error) downgrades
                        // to the SIMD interpreter with a notice — the
                        // router still comes up and serves bit-identical
                        // results, just slower. The downgrade is counted so
                        // the metrics report shows which tier is serving.
                        Err(EngineError::Construction(msg)) => {
                            eprintln!(
                                "native engine unavailable ({msg}); falling back to the \
                                 interpreter engine"
                            );
                            metrics_for_engine
                                .fallback_downgrades
                                .fetch_add(1, Ordering::Relaxed);
                            Ok(logic(metrics_for_engine)?)
                        }
                        Err(e) => Err(e),
                    }
                }
            }
        };

        let dispatcher = thread::Builder::new()
            .name("nnt-dispatcher".into())
            .spawn(move || {
                let mut engine: Box<dyn InferenceEngine> = match make_engine() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let wants_features = engine.wants_features();
                let meta = EngineMeta {
                    name: engine.name(),
                    wants_features,
                    wants_packed: engine.wants_packed(),
                    lut_counts: engine.lut_counts(),
                };
                if ready_tx.send(Ok(meta)).is_err() {
                    return;
                }
                while let Some(batch) = b.next_batch() {
                    let t = Instant::now();
                    let Batch { inputs, mut requests } = batch;
                    let n = requests.len() as u64;
                    // `take`, not clone: the features are dead after
                    // dispatch (replies only need `enqueued` + `reply`).
                    let xs: Option<Vec<Vec<f64>>> = if wants_features {
                        requests.iter_mut().map(|r| r.features.take()).collect()
                    } else {
                        None
                    };
                    // Arc'd once so a sharding engine can hand the batch to
                    // its workers zero-copy.
                    let inputs = Arc::new(inputs);
                    let result = engine::dispatch(engine.as_mut(), &inputs, xs.as_deref());
                    m.batches.fetch_add(1, Ordering::Relaxed);
                    m.batch_latency.record_ns(t.elapsed().as_nanos() as u64);
                    match result {
                        Ok(preds) => {
                            let name = engine.name();
                            for (req, class) in requests.into_iter().zip(preds) {
                                let latency = req.enqueued.elapsed();
                                m.request_latency.record_ns(latency.as_nanos() as u64);
                                let _ = req.reply.send(Reply { class, engine: name, latency });
                                // Notify *after* the send: a nonblocking
                                // caller that wakes now finds the reply.
                                if let Some(notify) = req.notify {
                                    notify();
                                }
                            }
                        }
                        Err(e) => {
                            // Dropping each reply sender makes submitters
                            // observe a disconnect, never a hang — and the
                            // notify fires *after* the drop, so an
                            // event-loop caller wakes to the disconnect
                            // rather than sleeping forever on it.
                            m.engine_failures.fetch_add(n, Ordering::Relaxed);
                            eprintln!(
                                "engine '{}': batch of {n} failed: {e}",
                                engine.name()
                            );
                            for req in requests {
                                let Request { reply, notify, .. } = req;
                                drop(reply);
                                if let Some(notify) = notify {
                                    notify();
                                }
                            }
                        }
                    }
                }
            })
            .map_err(|e| {
                NnError::Engine(EngineError::Construction(format!(
                    "spawn dispatcher: {e}"
                )))
            })?;

        match ready_rx.recv() {
            Ok(Ok(meta)) => Ok(Router {
                batcher,
                metrics,
                model,
                wants_features: meta.wants_features,
                wants_packed: meta.wants_packed,
                engine_name: meta.name,
                lut_counts: meta.lut_counts,
                dispatcher: Mutex::named("router.dispatcher", Some(dispatcher)),
            }),
            Ok(Err(e)) => {
                let _ = dispatcher.join();
                Err(NnError::Engine(e))
            }
            Err(_) => {
                let _ = dispatcher.join();
                Err(NnError::Engine(EngineError::Construction(
                    "dispatcher exited before signalling readiness".into(),
                )))
            }
        }
    }
}

/// Why [`Router::try_submit_bits`] refused a request. Both variants hand
/// the binarized bits back untouched; they demand opposite reactions:
/// `Closed` means "re-fetch the live router and resubmit the same bits"
/// (hot-swap race), `Overloaded` means "surface a typed overload reply so
/// the client backs off" — retrying an overload immediately would fail
/// again and amplify the load that caused it.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitRejection {
    /// The router was shut down (or is draining for a hot-swap).
    Closed(BitVec),
    /// Admission control: the model's queue is at its depth cap.
    Overloaded(BitVec),
}

impl SubmitRejection {
    /// The rejected bits, whichever way they were rejected.
    pub fn into_bits(self) -> BitVec {
        match self {
            SubmitRejection::Closed(b) | SubmitRejection::Overloaded(b) => b,
        }
    }
}

/// The serving router: owns the batcher, metrics, and the dispatcher
/// thread that drives one [`InferenceEngine`]. Construct via
/// [`RouterBuilder`].
pub struct Router {
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    model: Arc<Model>,
    wants_features: bool,
    wants_packed: bool,
    engine_name: &'static str,
    lut_counts: Option<(usize, usize)>,
    /// Behind a mutex so [`Router::shutdown`] works through a shared
    /// reference — a hot-swapping registry drains the old router via its
    /// `Arc` while in-flight submitters still hold clones.
    dispatcher: Mutex<Option<thread::JoinHandle<()>>>,
}

impl Router {
    /// Submit one request; returns the receiver for its reply. Features are
    /// binarized here — the batcher and engine only ever see packed bits.
    /// Panics if the feature width does not match the model (callers with
    /// untrusted input should check [`Router::input_features`] first) or if
    /// the router has been shut down (callers racing a hot-swap drain use
    /// [`Router::try_submit`] and retry on a live router). If the engine
    /// fails on the batch, the receiver observes a disconnect instead of a
    /// reply.
    pub fn submit(&self, features: Vec<f64>) -> mpsc::Receiver<Reply> {
        let bits = self.binarize(&features);
        // Move, don't copy: an engine that wants the raw features takes the
        // caller's own Vec (the pre-registry zero-copy behavior).
        let features = self.wants_features.then_some(features);
        match self.enqueue(bits, features, None, None) {
            Ok(rx) => rx,
            Err(SubmitError::Overloaded(_)) => {
                panic!("submit on an overloaded router (use try_submit_bits for typed backpressure)")
            }
            Err(SubmitError::Closed(_)) => {
                panic!("submit on a shut-down router (use try_submit to handle hot-swap)")
            }
        }
    }

    /// Submit one request from a borrowed feature slice. Returns `None`
    /// when the router has been shut down — its dispatcher may already
    /// have drained the final batch, so accepting the request would hang
    /// its receiver. A hot-swapping caller re-fetches the replacement
    /// router and retries; the slice is untouched, so the retry is free.
    /// The slice is copied only when the engine retains raw features.
    pub fn try_submit(&self, features: &[f64]) -> Option<mpsc::Receiver<Reply>> {
        let bits = self.binarize(features);
        self.try_submit_bits(bits, features, None, None).ok()
    }

    /// Submit one request whose circuit-input bits are **already
    /// binarized** (via [`Router::binarize`] — possibly on a displaced
    /// router serving the same quantization). Both rejection variants hand
    /// the bits back untouched: [`SubmitRejection::Closed`] lets a
    /// hot-swap retry resubmit them to the replacement without
    /// re-quantizing the features (the resubmit double-work fix of
    /// ISSUE 5), [`SubmitRejection::Overloaded`] is admission control —
    /// the caller surfaces a typed overload reply instead of retrying.
    /// `features` is copied only when the engine retains raw feature
    /// vectors. `deadline` (if any) rides the request into the batcher:
    /// once it passes, the batcher sheds the request without evaluation
    /// and the receiver observes a disconnect — the submitter, which knows
    /// the deadline it set, surfaces that as [`NnError::Deadline`].
    /// `notify` (if any) fires once the reply is resolved — sent, dropped,
    /// or shed — so a nonblocking caller can park on its event loop. The
    /// bit width must match this router's circuit (the registry checks
    /// compatibility before reuse).
    pub fn try_submit_bits(
        &self,
        bits: BitVec,
        features: &[f64],
        deadline: Option<Instant>,
        notify: Option<ReplyNotify>,
    ) -> Result<mpsc::Receiver<Reply>, SubmitRejection> {
        let features = self.wants_features.then(|| features.to_vec());
        self.enqueue(bits, features, deadline, notify).map_err(|rejected| {
            match rejected {
                SubmitError::Closed(req) => SubmitRejection::Closed(req.bits),
                SubmitError::Overloaded(req) => SubmitRejection::Overloaded(req.bits),
            }
        })
    }

    /// Quantize + pack features for the engine (width-checked), or a
    /// zeroed placeholder when the engine never reads packed bits.
    /// Crate-visible so the registry can binarize once and retry the same
    /// bits through a hot-swap ([`Router::try_submit_bits`]).
    pub(crate) fn binarize(&self, features: &[f64]) -> BitVec {
        assert_eq!(
            features.len(),
            self.model.input_features,
            "submit: {} features for a {}-feature model",
            features.len(),
            self.model.input_features
        );
        if self.wants_packed {
            let codes = quantize_input(&self.model, features);
            codes_to_bitvec(&codes, self.model.input_quant.bits)
        } else {
            // A numeric-only engine never reads the packed bits: skip the
            // dead quantize + pack work and carry a zeroed placeholder.
            BitVec::zeros(self.model.input_bits())
        }
    }

    /// The one place a [`Request`] is built and offered to the batcher;
    /// every submit variant funnels through it. A rejecting batcher hands
    /// the request back so retry paths can salvage its bits.
    fn enqueue(
        &self,
        bits: BitVec,
        features: Option<Vec<f64>>,
        deadline: Option<Instant>,
        notify: Option<ReplyNotify>,
    ) -> Result<mpsc::Receiver<Reply>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            bits,
            features,
            enqueued: Instant::now(),
            deadline,
            reply: tx,
            notify,
        };
        self.batcher.submit(req).map(|_| rx)
    }

    /// Feature width the model expects (for request validation).
    pub fn input_features(&self) -> usize {
        self.model.input_features
    }

    /// The model this router serves.
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Label of the engine replies come from ("logic" / "pjrt" /
    /// "native" — the latter degrades to "logic" when codegen fell back).
    pub fn engine_name(&self) -> &'static str {
        self.engine_name
    }

    /// `(LUTs before, after)` the compile-time netlist optimizer, when the
    /// engine evaluates a compiled circuit (surfaced per model by the
    /// `depth` admin command).
    pub fn lut_counts(&self) -> Option<(usize, usize)> {
        self.lut_counts
    }

    /// Whether the engine reads packed circuit-input bits (false for
    /// numeric-only engines, whose requests carry a zeroed placeholder).
    pub fn wants_packed(&self) -> bool {
        self.wants_packed
    }

    /// Whether the engine retains raw feature vectors (numeric and mirror
    /// engines). Such engines cannot serve bits-only submissions — the
    /// binary wire protocol deliberately carries no floats.
    pub fn wants_features(&self) -> bool {
        self.wants_features
    }

    /// Metrics handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// The batch policy this router's batcher flushes under (surfaced so
    /// overload replies can quote the configured depth cap).
    pub fn batch_policy(&self) -> BatchPolicy {
        self.batcher.policy()
    }

    /// Queue depth.
    pub fn depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Stop the dispatcher and drain: closing the batcher flushes every
    /// queued request immediately (no max-wait stall), the dispatcher
    /// serves those final batches, and the join returns once every
    /// in-flight reply has been sent. Works through a shared reference so
    /// a registry can drain an `Arc<Router>` while submitters still hold
    /// clones; concurrent calls are safe (the second finds no handle).
    pub fn shutdown(&self) {
        self.batcher.close();
        let handle = self.dispatcher.lock().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::random_model;
    use std::time::Duration;

    fn make_router(policy: Policy) -> (Router, Model) {
        let model = random_model("srv", 6, &[4, 3], 2, 1, 99);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let router = RouterBuilder::new(model.clone())
            .circuit(r.circuit.netlist)
            .engine(policy)
            .batch_policy(BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            })
            .workers(2)
            .build()
            .unwrap();
        (router, model)
    }

    #[test]
    fn serves_logic_requests() {
        let (router, model) = make_router(Policy::Logic);
        assert_eq!(router.engine_name(), "logic");
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for i in 0..50 {
            let x: Vec<f64> = (0..6).map(|j| ((i * 5 + j) as f64 * 0.37).sin()).collect();
            want.push(crate::nn::eval::classify(&model, &x));
            rxs.push(router.submit(x));
        }
        for (rx, w) in rxs.into_iter().zip(want) {
            let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(reply.class, w, "logic path must match NN exactly");
            assert_eq!(reply.engine, "logic");
        }
        let m = router.metrics();
        assert_eq!(m.logic_requests.load(Ordering::Relaxed), 50);
        assert!(m.batches.load(Ordering::Relaxed) >= 7); // 50 / 8
        router.shutdown();
    }

    #[test]
    fn multi_group_batches_use_the_sharded_path() {
        // max_batch 256 → batches spanning up to 4 lane groups, evaluated on
        // 4 workers sharing one Arc<CompiledNetlist>.
        let model = random_model("srv4", 6, &[4, 3], 2, 1, 7);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let router = RouterBuilder::new(model.clone())
            .circuit(r.circuit.netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy {
                max_batch: 256,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            })
            .workers(4)
            .build()
            .unwrap();
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for i in 0..300 {
            let x: Vec<f64> = (0..6).map(|j| ((i * 3 + j) as f64 * 0.21).cos()).collect();
            want.push(crate::nn::eval::classify(&model, &x));
            rxs.push(router.submit(x));
        }
        for (rx, w) in rxs.into_iter().zip(want) {
            let reply = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(reply.class, w, "sharded path must match NN exactly");
        }
        assert_eq!(router.metrics().logic_requests.load(Ordering::Relaxed), 300);
        router.shutdown();
    }

    #[test]
    fn parse_policies() {
        assert_eq!(Policy::parse("logic"), Some(Policy::Logic));
        assert_eq!(Policy::parse("pjrt"), Some(Policy::Numeric));
        assert_eq!(Policy::parse("compare"), Some(Policy::Compare));
        assert_eq!(Policy::parse("native"), Some(Policy::Native));
        assert_eq!(Policy::parse("x"), None);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // may spawn rustc — not a Miri workload
    fn native_policy_serves_bit_exact_with_or_without_codegen() {
        // On a host with rustc this serves from the generated library; on
        // one without, construction falls back to the interpreter. Either
        // way the router must come up and replies must match the NN.
        let (router, model) = make_router(Policy::Native);
        assert!(
            matches!(router.engine_name(), "native" | "logic"),
            "unexpected engine {}",
            router.engine_name()
        );
        if !crate::logic::codegen::rustc_available() {
            assert_eq!(router.engine_name(), "logic", "fallback must select the interpreter");
        }
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for i in 0..80 {
            let x: Vec<f64> = (0..6).map(|j| ((i * 7 + j) as f64 * 0.23).sin()).collect();
            want.push(crate::nn::eval::classify(&model, &x));
            rxs.push(router.submit(x));
        }
        for (rx, w) in rxs.into_iter().zip(want) {
            let reply = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(reply.class, w, "native path must match NN exactly");
        }
        router.shutdown();
    }

    #[test]
    fn native_build_without_circuit_is_a_typed_error() {
        let model = random_model("nnc", 4, &[3], 2, 1, 5);
        let err = RouterBuilder::new(model).engine(Policy::Native).build().unwrap_err();
        assert!(
            matches!(err, NnError::Engine(EngineError::Construction(_))),
            "{err}"
        );
    }

    #[test]
    fn shutdown_is_clean() {
        let (router, _) = make_router(Policy::Logic);
        let rx = router.submit(vec![0.0; 6]);
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        router.shutdown();
    }

    #[test]
    fn try_submit_bits_round_trips_through_a_closed_router() {
        let (router, model) = make_router(Policy::Logic);
        let x: Vec<f64> = (0..6).map(|j| (j as f64 * 0.4).sin()).collect();
        let bits = router.binarize(&x);
        // Live router: pre-binarized bits serve normally, bit-exact.
        let rx = router
            .try_submit_bits(bits.clone(), &x, None, None)
            .expect("live router accepts");
        let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(reply.class, crate::nn::eval::classify(&model, &x));
        // Closed router: the same bits come back untouched — and typed as
        // Closed, not Overloaded — so a hot-swap retry can resubmit
        // without re-binarizing the features.
        router.shutdown();
        let back = router
            .try_submit_bits(bits.clone(), &x, None, None)
            .expect_err("closed router rejects");
        assert_eq!(back, SubmitRejection::Closed(bits), "bits must come back for a free resubmit");
    }

    #[test]
    fn expired_deadline_sheds_and_disconnects_the_receiver() {
        let (router, model) = make_router(Policy::Logic);
        let x: Vec<f64> = (0..6).map(|j| (j as f64 * 0.6).sin()).collect();
        let bits = router.binarize(&x);
        // A deadline already in the past: the batcher sheds the request
        // before evaluation, so the receiver observes a disconnect instead
        // of a reply. A live deadline serves normally.
        let dead = Instant::now() - Duration::from_millis(5);
        let rx = router
            .try_submit_bits(bits.clone(), &x, Some(dead), None)
            .expect("admission still accepts; shedding happens at flush");
        assert!(
            rx.recv_timeout(Duration::from_secs(5)).is_err(),
            "expired request must be shed, not answered"
        );
        let live = Instant::now() + Duration::from_secs(30);
        let rx = router
            .try_submit_bits(bits, &x, Some(live), None)
            .expect("live router accepts");
        let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(reply.class, crate::nn::eval::classify(&model, &x));
        assert_eq!(router.metrics().deadline_expired.load(Ordering::Relaxed), 1);
        router.shutdown();
    }

    #[test]
    fn notify_fires_after_the_reply_is_sent() {
        use std::sync::atomic::AtomicU64;
        let (router, _) = make_router(Policy::Logic);
        let x: Vec<f64> = (0..6).map(|j| (j as f64 * 0.9).cos()).collect();
        let bits = router.binarize(&x);
        let fired = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&fired);
        let notify: ReplyNotify = Arc::new(move || {
            f.fetch_add(1, Ordering::Relaxed);
        });
        let rx = router
            .try_submit_bits(bits, &x, None, Some(notify))
            .expect("live router accepts");
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // The notify is ordered after the send, so the receiver can observe
        // the reply a beat before the callback runs — shutdown joins the
        // dispatcher, after which the callback must have fired exactly once.
        router.shutdown();
        assert_eq!(fired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn router_surfaces_optimizer_lut_counts() {
        let (router, _) = make_router(Policy::Logic);
        let (pre, post) = router.lut_counts().expect("logic router has LUT counts");
        assert!(post <= pre, "optimizer must not add LUTs ({pre} → {post})");
        assert!(router.wants_packed());
    }

    #[test]
    fn build_without_circuit_is_a_typed_error() {
        let model = random_model("noc", 4, &[3], 2, 1, 5);
        let err = RouterBuilder::new(model).engine(Policy::Logic).build().unwrap_err();
        assert!(
            matches!(err, NnError::Engine(EngineError::Construction(_))),
            "{err}"
        );
    }

    #[test]
    fn numeric_build_without_spec_is_a_typed_error() {
        let model = random_model("nos", 4, &[3], 2, 1, 5);
        let err = RouterBuilder::new(model).engine(Policy::Numeric).build().unwrap_err();
        assert!(matches!(err, NnError::Engine(_)), "{err}");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn numeric_build_without_backend_errors_before_serving() {
        // The old router panicked the dispatcher on this path and hung
        // every submitter; now it is a typed build error.
        let model = random_model("nob", 4, &[3], 2, 1, 5);
        let err = RouterBuilder::new(model)
            .engine(Policy::Numeric)
            .pjrt(PjrtSpec {
                hlo_path: "artifacts/missing.hlo.txt".into(),
                batch: 64,
                in_features: 4,
                out_width: 3,
            })
            .build()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("xla") || msg.contains("HLO"), "{msg}");
    }
}
