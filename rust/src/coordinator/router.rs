//! Request router: dispatches batches to the combinational-logic engine
//! and/or the PJRT numeric engine.
//!
//! The coordinator's demonstration goal (`rust/DESIGN.md` §Serving): the
//! synthesized fixed-function logic *is* the production inference path —
//! bit-exact against the quantized NN — while the AOT-compiled XLA
//! executable serves as the numeric reference. Routing policies:
//!
//! * `Logic` — everything on the netlist simulator (the paper's artifact)
//! * `Numeric` — everything on PJRT
//! * `Compare` — run both, count disagreements, reply from logic
//!
//! The logic path is packed end to end: `submit` binarizes the features
//! into a [`BitVec`](crate::util::bitvec::BitVec), the batcher flushes a
//! [`PackedBatch`], and the dispatcher hands that straight to one shared
//! `Arc<CompiledNetlist>` — inline for single-lane-group batches, sharded
//! across an engine [`ThreadPool`] for larger ones. No per-sample `Vec`
//! exists between [`Batcher::next_batch`] and the simulator.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::{Batch, BatchPolicy, Batcher, Reply, Request};
use crate::coordinator::metrics::Metrics;
use crate::flow::build::classify_packed;
use crate::logic::sim::{CompiledNetlist, SimScratch};
use crate::nn::eval::{codes_to_bitvec, quantize_input};
use crate::nn::model::Model;
use crate::runtime::PjrtEngine;
use crate::util::bitvec::PackedBatch;
use crate::util::threadpool::ThreadPool;

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Logic,
    Numeric,
    Compare,
}

impl Policy {
    /// Parse "logic" / "pjrt" / "compare".
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "logic" => Some(Policy::Logic),
            "pjrt" | "numeric" => Some(Policy::Numeric),
            "compare" | "both" => Some(Policy::Compare),
            _ => None,
        }
    }
}

/// How to construct the PJRT engine. The engine itself is `!Send` (its C
/// handles are reference-counted without atomics), so the router receives a
/// *spec* and instantiates the engine on the dispatcher thread where it
/// lives for the router's whole lifetime.
#[derive(Clone, Debug)]
pub struct PjrtSpec {
    /// Path to `artifacts/<arch>.hlo.txt`.
    pub hlo_path: String,
    /// Compiled batch size of the artifact.
    pub batch: usize,
    /// Input features.
    pub in_features: usize,
    /// Output width.
    pub out_width: usize,
}

impl PjrtSpec {
    fn load(&self) -> PjrtEngine {
        PjrtEngine::load(&self.hlo_path, self.batch, self.in_features, self.out_width)
            .expect("load PJRT artifact")
    }
}

/// The serving router: owns the batcher, engines, metrics, and dispatcher
/// thread.
pub struct Router {
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    model: Arc<Model>,
    policy: Policy,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

/// Evaluate a packed batch on the logic engine and classify straight from
/// the packed output words. Batches spanning ≥ 2 lane groups are sharded
/// across `pool` workers sharing the `Arc<CompiledNetlist>`; smaller ones
/// run inline on the dispatcher's own scratch.
fn eval_logic(
    sim: &Arc<CompiledNetlist>,
    pool: &Option<ThreadPool>,
    scratch: &mut SimScratch,
    inputs: PackedBatch,
    model: &Model,
) -> Vec<usize> {
    let outputs = match pool {
        Some(p) if inputs.num_groups() >= 2 => {
            let shared = Arc::new(inputs);
            CompiledNetlist::run_packed_sharded(sim, p, &shared)
        }
        _ => sim.run_packed(&inputs, scratch),
    };
    classify_packed(model, &outputs)
}

/// Clone the retained feature vectors for the numeric engine (only the
/// numeric/compare policies keep them on the request).
fn features_of(requests: &[Request]) -> Vec<Vec<f64>> {
    requests
        .iter()
        .map(|r| r.features.clone().expect("numeric path retains features"))
        .collect()
}

impl Router {
    /// Start a router over the given engines. `pjrt` may be `None` when
    /// only the logic path is wanted (e.g. artifacts not built). `workers`
    /// sizes the logic engine's shard pool: with ≥ 2 workers, batches
    /// spanning multiple 64-sample lane groups are evaluated in parallel on
    /// one shared compiled netlist.
    pub fn start(
        model: Model,
        netlist: crate::logic::netlist::LutNetlist,
        pjrt: Option<PjrtSpec>,
        policy: Policy,
        batch_policy: BatchPolicy,
        workers: usize,
    ) -> Router {
        let model = Arc::new(model);
        let batcher = Arc::new(Batcher::new(batch_policy, model.input_bits()));
        let metrics = Arc::new(Metrics::new());
        let b = Arc::clone(&batcher);
        let m = Arc::clone(&metrics);
        let model_for_dispatch = Arc::clone(&model);
        let dispatcher = std::thread::Builder::new()
            .name("nnt-dispatcher".into())
            .spawn(move || {
                let model = model_for_dispatch;
                let sim = Arc::new(CompiledNetlist::compile(&netlist));
                let pool = (workers > 1).then(|| ThreadPool::new(workers));
                let mut scratch = sim.make_scratch();
                let pjrt: Option<PjrtEngine> = pjrt.map(|s| s.load());
                while let Some(batch) = b.next_batch() {
                    let t = Instant::now();
                    let Batch { inputs, requests } = batch;
                    let n = requests.len() as u64;
                    let (preds, engine): (Vec<usize>, &'static str) = match policy {
                        Policy::Logic => {
                            m.logic_requests.fetch_add(n, Ordering::Relaxed);
                            (eval_logic(&sim, &pool, &mut scratch, inputs, &model), "logic")
                        }
                        Policy::Numeric => {
                            let e = pjrt.as_ref().expect("numeric policy needs PJRT");
                            m.numeric_requests.fetch_add(n, Ordering::Relaxed);
                            let xs = features_of(&requests);
                            (
                                e.classify_all(&xs, model.num_classes)
                                    .expect("pjrt inference"),
                                "pjrt",
                            )
                        }
                        Policy::Compare => {
                            let logic =
                                eval_logic(&sim, &pool, &mut scratch, inputs, &model);
                            m.logic_requests.fetch_add(n, Ordering::Relaxed);
                            if let Some(e) = pjrt.as_ref() {
                                let xs = features_of(&requests);
                                let num = e
                                    .classify_all(&xs, model.num_classes)
                                    .expect("pjrt inference");
                                m.numeric_requests.fetch_add(n, Ordering::Relaxed);
                                let dis = logic
                                    .iter()
                                    .zip(&num)
                                    .filter(|(a, b)| a != b)
                                    .count();
                                m.disagreements.fetch_add(dis as u64, Ordering::Relaxed);
                            }
                            (logic, "logic")
                        }
                    };
                    m.batches.fetch_add(1, Ordering::Relaxed);
                    m.batch_latency.record_ns(t.elapsed().as_nanos() as u64);
                    for (req, class) in requests.into_iter().zip(preds) {
                        let latency = req.enqueued.elapsed();
                        m.request_latency.record_ns(latency.as_nanos() as u64);
                        let _ = req.reply.send(Reply { class, engine, latency });
                    }
                }
            })
            .expect("spawn dispatcher");
        Router { batcher, metrics, model, policy, dispatcher: Some(dispatcher) }
    }

    /// Submit one request; returns the receiver for its reply. Features are
    /// binarized here — the batcher and engine only ever see packed bits.
    /// Panics if the feature width does not match the model (callers with
    /// untrusted input should check [`Router::input_features`] first).
    pub fn submit(&self, features: Vec<f64>) -> std::sync::mpsc::Receiver<Reply> {
        let (tx, rx) = std::sync::mpsc::channel();
        assert_eq!(
            features.len(),
            self.model.input_features,
            "submit: {} features for a {}-feature model",
            features.len(),
            self.model.input_features
        );
        let bits = if self.policy == Policy::Numeric {
            // The logic engine never sees a numeric-only batch: skip the
            // dead quantize + pack work and carry a zeroed placeholder.
            crate::util::bitvec::BitVec::zeros(self.model.input_bits())
        } else {
            let codes = quantize_input(&self.model, &features);
            codes_to_bitvec(&codes, self.model.input_quant.bits)
        };
        let features = (self.policy != Policy::Logic).then_some(features);
        self.batcher.submit(Request { bits, features, enqueued: Instant::now(), reply: tx });
        rx
    }

    /// Feature width the model expects (for request validation).
    pub fn input_features(&self) -> usize {
        self.model.input_features
    }

    /// Metrics handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Queue depth.
    pub fn depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Stop the dispatcher (drains in-flight batches).
    pub fn shutdown(mut self) {
        self.batcher.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.batcher.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::random_model;
    use std::time::Duration;

    fn make_router(policy: Policy) -> (Router, Model) {
        let model = random_model("srv", 6, &[4, 3], 2, 1, 99);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let router = Router::start(
            model.clone(),
            r.circuit.netlist,
            None,
            policy,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            2,
        );
        (router, model)
    }

    #[test]
    fn serves_logic_requests() {
        let (router, model) = make_router(Policy::Logic);
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for i in 0..50 {
            let x: Vec<f64> = (0..6).map(|j| ((i * 5 + j) as f64 * 0.37).sin()).collect();
            want.push(crate::nn::eval::classify(&model, &x));
            rxs.push(router.submit(x));
        }
        for (rx, w) in rxs.into_iter().zip(want) {
            let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(reply.class, w, "logic path must match NN exactly");
            assert_eq!(reply.engine, "logic");
        }
        let m = router.metrics();
        assert_eq!(m.logic_requests.load(Ordering::Relaxed), 50);
        assert!(m.batches.load(Ordering::Relaxed) >= 7); // 50 / 8
        router.shutdown();
    }

    #[test]
    fn multi_group_batches_use_the_sharded_path() {
        // max_batch 256 → batches spanning up to 4 lane groups, evaluated on
        // 4 workers sharing one Arc<CompiledNetlist>.
        let model = random_model("srv4", 6, &[4, 3], 2, 1, 7);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let router = Router::start(
            model.clone(),
            r.circuit.netlist,
            None,
            Policy::Logic,
            BatchPolicy { max_batch: 256, max_wait: Duration::from_millis(2) },
            4,
        );
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for i in 0..300 {
            let x: Vec<f64> = (0..6).map(|j| ((i * 3 + j) as f64 * 0.21).cos()).collect();
            want.push(crate::nn::eval::classify(&model, &x));
            rxs.push(router.submit(x));
        }
        for (rx, w) in rxs.into_iter().zip(want) {
            let reply = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(reply.class, w, "sharded path must match NN exactly");
        }
        assert_eq!(router.metrics().logic_requests.load(Ordering::Relaxed), 300);
        router.shutdown();
    }

    #[test]
    fn parse_policies() {
        assert_eq!(Policy::parse("logic"), Some(Policy::Logic));
        assert_eq!(Policy::parse("pjrt"), Some(Policy::Numeric));
        assert_eq!(Policy::parse("compare"), Some(Policy::Compare));
        assert_eq!(Policy::parse("x"), None);
    }

    #[test]
    fn shutdown_is_clean() {
        let (router, _) = make_router(Policy::Logic);
        let rx = router.submit(vec![0.0; 6]);
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        router.shutdown();
    }
}
