//! Request router: dispatches batches to the combinational-logic engine
//! and/or the PJRT numeric engine.
//!
//! The coordinator's demonstration goal (DESIGN.md §2): the synthesized
//! fixed-function logic *is* the production inference path — bit-exact
//! against the quantized NN — while the AOT-compiled XLA executable serves
//! as the numeric reference. Routing policies:
//!
//! * `Logic` — everything on the netlist simulator (the paper's artifact)
//! * `Numeric` — everything on PJRT
//! * `Compare` — run both, count disagreements, reply from logic

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::{BatchPolicy, Batcher, Reply, Request};
use crate::coordinator::metrics::Metrics;
use crate::flow::build::classify_batch;
use crate::logic::sim::CompiledNetlist;
use crate::nn::model::Model;
use crate::runtime::PjrtEngine;

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Logic,
    Numeric,
    Compare,
}

impl Policy {
    /// Parse "logic" / "pjrt" / "compare".
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "logic" => Some(Policy::Logic),
            "pjrt" | "numeric" => Some(Policy::Numeric),
            "compare" | "both" => Some(Policy::Compare),
            _ => None,
        }
    }
}

/// How to construct the PJRT engine. The engine itself is `!Send` (its C
/// handles are reference-counted without atomics), so the router receives a
/// *spec* and instantiates the engine on the dispatcher thread where it
/// lives for the router's whole lifetime.
#[derive(Clone, Debug)]
pub struct PjrtSpec {
    /// Path to `artifacts/<arch>.hlo.txt`.
    pub hlo_path: String,
    /// Compiled batch size of the artifact.
    pub batch: usize,
    /// Input features.
    pub in_features: usize,
    /// Output width.
    pub out_width: usize,
}

impl PjrtSpec {
    fn load(&self) -> PjrtEngine {
        PjrtEngine::load(&self.hlo_path, self.batch, self.in_features, self.out_width)
            .expect("load PJRT artifact")
    }
}

/// The serving router: owns the batcher, engines, metrics, and dispatcher
/// thread.
pub struct Router {
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Start a router over the given engines. `pjrt` may be `None` when
    /// only the logic path is wanted (e.g. artifacts not built).
    pub fn start(
        model: Model,
        netlist: crate::logic::netlist::LutNetlist,
        pjrt: Option<PjrtSpec>,
        policy: Policy,
        batch_policy: BatchPolicy,
    ) -> Router {
        let batcher = Arc::new(Batcher::new(batch_policy));
        let metrics = Arc::new(Metrics::new());
        let b = Arc::clone(&batcher);
        let m = Arc::clone(&metrics);
        let dispatcher = std::thread::Builder::new()
            .name("nnt-dispatcher".into())
            .spawn(move || {
                let mut sim = CompiledNetlist::compile(&netlist);
                let pjrt: Option<PjrtEngine> = pjrt.map(|s| s.load());
                while let Some(batch) = b.next_batch() {
                    let t = Instant::now();
                    let xs: Vec<Vec<f64>> =
                        batch.iter().map(|r| r.features.clone()).collect();
                    let (preds, engine): (Vec<usize>, &'static str) = match policy {
                        Policy::Logic => {
                            m.logic_requests.fetch_add(xs.len() as u64, Ordering::Relaxed);
                            (classify_batch(&model, &mut sim, &xs), "logic")
                        }
                        Policy::Numeric => {
                            let e = pjrt.as_ref().expect("numeric policy needs PJRT");
                            m.numeric_requests
                                .fetch_add(xs.len() as u64, Ordering::Relaxed);
                            (
                                e.classify_all(&xs, model.num_classes)
                                    .expect("pjrt inference"),
                                "pjrt",
                            )
                        }
                        Policy::Compare => {
                            let logic = classify_batch(&model, &mut sim, &xs);
                            m.logic_requests.fetch_add(xs.len() as u64, Ordering::Relaxed);
                            if let Some(e) = pjrt.as_ref() {
                                let num = e
                                    .classify_all(&xs, model.num_classes)
                                    .expect("pjrt inference");
                                m.numeric_requests
                                    .fetch_add(xs.len() as u64, Ordering::Relaxed);
                                let dis = logic
                                    .iter()
                                    .zip(&num)
                                    .filter(|(a, b)| a != b)
                                    .count();
                                m.disagreements.fetch_add(dis as u64, Ordering::Relaxed);
                            }
                            (logic, "logic")
                        }
                    };
                    m.batches.fetch_add(1, Ordering::Relaxed);
                    m.batch_latency.record_ns(t.elapsed().as_nanos() as u64);
                    for (req, class) in batch.into_iter().zip(preds) {
                        let latency = req.enqueued.elapsed();
                        m.request_latency.record_ns(latency.as_nanos() as u64);
                        let _ = req.reply.send(Reply { class, engine, latency });
                    }
                }
            })
            .expect("spawn dispatcher");
        Router { batcher, metrics, dispatcher: Some(dispatcher) }
    }

    /// Submit one request; returns the receiver for its reply.
    pub fn submit(&self, features: Vec<f64>) -> std::sync::mpsc::Receiver<Reply> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.batcher.submit(Request { features, enqueued: Instant::now(), reply: tx });
        rx
    }

    /// Metrics handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Queue depth.
    pub fn depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Stop the dispatcher (drains in-flight batches).
    pub fn shutdown(mut self) {
        self.batcher.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.batcher.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::random_model;
    use std::time::Duration;

    fn make_router(policy: Policy) -> (Router, Model) {
        let model = random_model("srv", 6, &[4, 3], 2, 1, 99);
        let r = run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let router = Router::start(
            model.clone(),
            r.circuit.netlist,
            None,
            policy,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        (router, model)
    }

    #[test]
    fn serves_logic_requests() {
        let (router, model) = make_router(Policy::Logic);
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for i in 0..50 {
            let x: Vec<f64> = (0..6).map(|j| ((i * 5 + j) as f64 * 0.37).sin()).collect();
            want.push(crate::nn::eval::classify(&model, &x));
            rxs.push(router.submit(x));
        }
        for (rx, w) in rxs.into_iter().zip(want) {
            let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(reply.class, w, "logic path must match NN exactly");
            assert_eq!(reply.engine, "logic");
        }
        let m = router.metrics();
        assert_eq!(m.logic_requests.load(Ordering::Relaxed), 50);
        assert!(m.batches.load(Ordering::Relaxed) >= 7); // 50 / 8
        router.shutdown();
    }

    #[test]
    fn parse_policies() {
        assert_eq!(Policy::parse("logic"), Some(Policy::Logic));
        assert_eq!(Policy::parse("pjrt"), Some(Policy::Numeric));
        assert_eq!(Policy::parse("compare"), Some(Policy::Compare));
        assert_eq!(Policy::parse("x"), None);
    }

    #[test]
    fn shutdown_is_clean() {
        let (router, _) = make_router(Policy::Logic);
        let rx = router.submit(vec![0.0; 6]);
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        router.shutdown();
    }
}
