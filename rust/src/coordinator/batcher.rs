//! Dynamic request batching.
//!
//! Classic serving-side batcher: requests accumulate in a queue; a flush is
//! triggered by either reaching `max_batch` or a request aging past
//! `max_wait`. The flushed batch goes to one of the inference engines (the
//! bit-parallel logic simulator packs 64 samples per word pass; the PJRT
//! executable has a fixed compiled batch). Built on std primitives — the
//! offline environment has no tokio — with one dispatcher thread per
//! [`crate::coordinator::router::Router`].

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued inference request.
pub struct Request {
    /// Feature vector.
    pub features: Vec<f64>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
    /// Completion channel: (predicted class, engine label).
    pub reply: Sender<Reply>,
}

/// Completion message.
#[derive(Clone, Debug, PartialEq)]
pub struct Reply {
    /// Predicted class.
    pub class: usize,
    /// Which engine served it ("logic" / "pjrt").
    pub engine: &'static str,
    /// End-to-end latency.
    pub latency: Duration,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush when this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(200) }
    }
}

/// Thread-safe request queue with batch-flush semantics.
pub struct Batcher {
    policy: BatchPolicy,
    queue: Mutex<VecDeque<Request>>,
    signal: Condvar,
    closed: Mutex<bool>,
}

impl Batcher {
    /// New empty batcher.
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queue: Mutex::new(VecDeque::new()),
            signal: Condvar::new(),
            closed: Mutex::new(false),
        }
    }

    /// Policy accessor.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request.
    pub fn submit(&self, req: Request) {
        let mut q = self.queue.lock().unwrap();
        q.push_back(req);
        if q.len() >= self.policy.max_batch {
            self.signal.notify_one();
        } else {
            // Wake the dispatcher so it can arm the age timer.
            self.signal.notify_one();
        }
    }

    /// Mark closed; wakes the dispatcher.
    pub fn close(&self) {
        *self.closed.lock().unwrap() = true;
        self.signal.notify_all();
    }

    /// Dispatcher side: wait for the next batch (or `None` once closed and
    /// drained). Blocks up to the age deadline of the oldest request.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if q.len() >= self.policy.max_batch {
                return Some(q.drain(..self.policy.max_batch).collect());
            }
            if let Some(oldest) = q.front() {
                let age = oldest.enqueued.elapsed();
                if age >= self.policy.max_wait {
                    let n = q.len().min(self.policy.max_batch);
                    return Some(q.drain(..n).collect());
                }
                let remaining = self.policy.max_wait - age;
                let (nq, _timeout) = self.signal.wait_timeout(q, remaining).unwrap();
                q = nq;
            } else {
                if *self.closed.lock().unwrap() {
                    return None;
                }
                q = self.signal.wait(q).unwrap();
            }
        }
    }

    /// Number of queued requests (diagnostics).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(v: f64) -> (Request, std::sync::mpsc::Receiver<Reply>) {
        let (tx, rx) = channel();
        (
            Request { features: vec![v], enqueued: Instant::now(), reply: tx },
            rx,
        )
    }

    #[test]
    fn flushes_on_max_batch() {
        let b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10) });
        for i in 0..3 {
            let (r, _rx) = req(i as f64);
            std::mem::forget(_rx);
            b.submit(r);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn flushes_on_age() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        }));
        let (r, _rx) = req(1.0);
        std::mem::forget(_rx);
        b.submit(r);
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(4), "must wait for age");
    }

    #[test]
    fn close_drains_to_none() {
        let b = Batcher::new(BatchPolicy::default());
        b.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_submit_and_drain() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_millis(1),
        }));
        let b2 = Arc::clone(&b);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                let (r, rx) = req(i as f64);
                std::mem::forget(rx);
                b2.submit(r);
            }
            b2.close();
        });
        let mut total = 0;
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 10);
            total += batch.len();
            if total == 100 {
                break;
            }
        }
        producer.join().unwrap();
        assert_eq!(total, 100);
    }
}
