//! Dynamic request batching.
//!
//! Classic serving-side batcher: requests accumulate in a queue; a flush is
//! triggered by either reaching `max_batch` or a request aging past
//! `max_wait`. Requests arrive **pre-binarized** (the router quantizes the
//! feature vector into circuit-input bits at submit time), and a flush hands
//! the dispatcher a [`Batch`] whose inputs are already a [`PackedBatch`] —
//! one `u64` word per input signal per 64-sample lane group — so the logic
//! engine consumes the batch with zero per-sample `Vec` traffic between
//! [`Batcher::next_batch`] and the simulator. The queue is **bounded**:
//! a submit past [`BatchPolicy::max_depth`] is rejected as
//! [`SubmitError::Overloaded`] (counted per model), so a saturated engine
//! sheds load as typed overload replies instead of growing an unbounded
//! queue. Requests may carry a **deadline**: an expired request is shed
//! before evaluation (its reply sender dropped, its notify fired, counted
//! in `deadline_expired`) and never burns a batch lane — and a queue at
//! its depth cap purges expired entries before judging admission, so dead
//! requests do not hold live slots. Built on the crate's sync shim
//! (std-backed; no tokio offline) — with one or more dispatcher threads per
//! [`crate::coordinator::router::Router`]. Under `--cfg nnt_model_check`
//! the close-flush vs concurrent-submit protocol is exhaustively model
//! checked (`tests/model_check.rs`).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::util::sync::mpsc::Sender;
use crate::util::sync::{Condvar, Mutex};

use crate::util::bitvec::{BitVec, PackedBatch};

/// Callback the dispatcher invokes once a request's reply (or failure) has
/// been sent — how a *nonblocking* front end learns a reply is ready
/// without parking a thread on the receiver. The event loop passes its
/// waker here; blocking callers pass `None` and park on `reply` directly.
pub type ReplyNotify = Arc<dyn Fn() + Send + Sync>;

/// One queued inference request.
pub struct Request {
    /// Pre-binarized circuit-input bits (the logic engine's native format).
    pub bits: BitVec,
    /// Raw features, kept only when a numeric engine may need them
    /// (compare / numeric routing policies). `None` on the logic-only path.
    pub features: Option<Vec<f64>>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
    /// Optional completion deadline. Once passed, the batcher sheds the
    /// request before evaluation: the reply sender is dropped (the waiting
    /// receiver observes disconnection, which the submit side surfaces as
    /// a typed `NnError::Deadline`) and `notify` still fires.
    pub deadline: Option<Instant>,
    /// Completion channel: (predicted class, engine label).
    pub reply: Sender<Reply>,
    /// Invoked after `reply` is resolved (sent **or** dropped on engine
    /// failure or deadline shed) so an event-loop caller wakes exactly
    /// when polling the receiver will succeed. `None` for blocking
    /// callers.
    pub notify: Option<ReplyNotify>,
}

impl Request {
    /// Whether this request's deadline (if any) has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// Completion message.
#[derive(Clone, Debug, PartialEq)]
pub struct Reply {
    /// Predicted class.
    pub class: usize,
    /// Which engine served it ("logic" / "pjrt").
    pub engine: &'static str,
    /// End-to-end latency.
    pub latency: Duration,
}

/// A flushed batch: packed engine inputs plus per-sample reply metadata.
/// `requests[s]` is the request packed at lane `s` of `inputs`.
pub struct Batch {
    /// Bit-packed circuit inputs, ready for the simulator.
    pub inputs: PackedBatch,
    /// Reply metadata in lane order.
    pub requests: Vec<Request>,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush when this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest request has waited this long.
    pub max_wait: Duration,
    /// Admission cap: reject (rather than queue) a submit that would push
    /// the queue past this depth. Bounds worst-case queueing latency and
    /// memory per model; the rejection surfaces as a typed overload reply,
    /// not unbounded queue growth.
    pub max_depth: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            max_depth: DEFAULT_MAX_DEPTH,
        }
    }
}

/// Default admission cap — deep enough that only a genuinely saturated
/// model trips it (64 full batches at the default `max_batch`).
pub const DEFAULT_MAX_DEPTH: usize = 4096;

/// Why [`Batcher::submit`] refused a request. Both variants hand the
/// request back intact (reply sender included) — the two cases demand
/// opposite reactions, which is why this is not a bare `Err(Request)`:
/// a closed batcher means "re-fetch the live router and resubmit"
/// (hot-swap race), an overloaded one means "tell the client to back off".
pub enum SubmitError {
    /// The batcher was closed (shutdown or hot-swap drain).
    Closed(Request),
    /// The queue is at [`BatchPolicy::max_depth`]; admission control
    /// rejected the request.
    Overloaded(Request),
}

impl SubmitError {
    /// The rejected request, whichever way it was rejected.
    pub fn into_request(self) -> Request {
        match self {
            SubmitError::Closed(r) | SubmitError::Overloaded(r) => r,
        }
    }
}

/// Queue plus shutdown flag, guarded by ONE mutex: the condvar waits on the
/// same lock `close()` writes under, so a close can never slip into the
/// window between a dispatcher's empty-queue check and its `wait` (the
/// classic lost-wakeup race a separate `Mutex<bool>` would allow).
struct QueueState {
    queue: VecDeque<Request>,
    closed: bool,
}

/// Thread-safe request queue with batch-flush semantics.
pub struct Batcher {
    policy: BatchPolicy,
    /// Circuit-input bit width every request must match.
    input_bits: usize,
    state: Mutex<QueueState>,
    signal: Condvar,
    /// Per-model metrics for the admission counters (overload rejections,
    /// queue high-watermark). `None` for standalone batchers in tests.
    metrics: Option<Arc<Metrics>>,
}

impl Batcher {
    /// New empty batcher over requests of `input_bits` circuit-input bits.
    pub fn new(policy: BatchPolicy, input_bits: usize) -> Self {
        Self::with_metrics(policy, input_bits, None)
    }

    /// Like [`new`](Self::new), wired to a model's [`Metrics`] so admission
    /// decisions (overload rejections, queue high-watermark) are counted
    /// where the `metrics` admin command reports them.
    pub fn with_metrics(
        policy: BatchPolicy,
        input_bits: usize,
        metrics: Option<Arc<Metrics>>,
    ) -> Self {
        Batcher {
            policy,
            input_bits,
            state: Mutex::named("batcher.state", QueueState { queue: VecDeque::new(), closed: false }),
            signal: Condvar::new(),
            metrics,
        }
    }

    /// Policy accessor.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Circuit-input bit width of every request.
    pub fn input_bits(&self) -> usize {
        self.input_bits
    }

    /// Enqueue a request. Two typed rejections, both handing the request
    /// back intact (reply sender included):
    ///
    /// * [`SubmitError::Closed`] — the batcher has been closed: its
    ///   dispatcher may already have drained the final batch and exited,
    ///   so accepting the request would strand its reply sender forever.
    ///   Callers racing a shutdown or hot-swap re-fetch a live router and
    ///   resubmit.
    /// * [`SubmitError::Overloaded`] — admission control: the queue is at
    ///   [`BatchPolicy::max_depth`]. Resubmitting immediately would fail
    ///   again; the caller surfaces a typed overload reply so the client
    ///   backs off.
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        assert_eq!(
            req.bits.len(),
            self.input_bits,
            "submit: request packs {} bits, batcher expects {}",
            req.bits.len(),
            self.input_bits
        );
        let mut s = self.state.lock();
        if s.closed {
            return Err(SubmitError::Closed(req));
        }
        // A queue at its cap may be full of requests whose clients already
        // gave up; purge expired entries before judging admission so dead
        // requests never hold live slots.
        let mut dead: Vec<Request> = Vec::new();
        if s.queue.len() >= self.policy.max_depth {
            let now = Instant::now();
            if s.queue.iter().any(|r| r.expired(now)) {
                let kept: VecDeque<Request> = s
                    .queue
                    .drain(..)
                    .filter_map(|r| {
                        if r.expired(now) {
                            dead.push(r);
                            None
                        } else {
                            Some(r)
                        }
                    })
                    .collect();
                s.queue = kept;
            }
        }
        if s.queue.len() >= self.policy.max_depth {
            drop(s);
            self.shed(dead);
            if let Some(m) = &self.metrics {
                m.rejected_overload.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            return Err(SubmitError::Overloaded(req));
        }
        s.queue.push_back(req);
        let depth = s.queue.len();
        let full = depth >= self.policy.max_batch;
        drop(s);
        self.shed(dead);
        if let Some(m) = &self.metrics {
            m.observe_queue_depth(depth as u64);
        }
        if full {
            // A full queue can satisfy the flush condition of every parked
            // dispatcher at once; wake them all so none strands a flush.
            self.signal.notify_all();
        } else {
            // Wake one dispatcher so it can arm the age timer.
            self.signal.notify_one();
        }
        Ok(())
    }

    /// Mark closed; wakes all dispatchers. Written under the queue lock so
    /// no dispatcher can park between observing "open + empty" and waiting.
    pub fn close(&self) {
        self.state.lock().closed = true;
        self.signal.notify_all();
    }

    /// Shed requests whose deadline has passed: count them, drop each
    /// reply sender (the receiver observes disconnection), and fire each
    /// notify — always called with the queue lock released.
    fn shed(&self, dead: Vec<Request>) {
        if dead.is_empty() {
            return;
        }
        if let Some(m) = &self.metrics {
            m.deadline_expired
                .fetch_add(dead.len() as u64, std::sync::atomic::Ordering::Relaxed);
        }
        for r in dead {
            let Request { reply, notify, .. } = r;
            drop(reply);
            if let Some(n) = notify {
                n();
            }
        }
    }

    /// Dispatcher side: wait for the next batch (or `None` once closed and
    /// drained). Blocks up to the age deadline of the oldest request. The
    /// drained requests are bit-packed into the returned [`Batch`] outside
    /// the queue lock; expired requests are shed here — after the drain,
    /// before packing — so no dead request ever reaches an engine.
    pub fn next_batch(&self) -> Option<Batch> {
        loop {
            let drained = self.drain_requests()?;
            let now = Instant::now();
            let mut requests = Vec::with_capacity(drained.len());
            let mut dead = Vec::new();
            for r in drained {
                if r.expired(now) {
                    dead.push(r);
                } else {
                    requests.push(r);
                }
            }
            self.shed(dead);
            if requests.is_empty() {
                // Every drained request had expired; go back to waiting
                // rather than hand the engine an empty batch.
                continue;
            }
            let mut inputs = PackedBatch::with_capacity(self.input_bits, requests.len());
            if self.input_bits <= 64 {
                // Word-level fast path: a request's pre-binarized bits are
                // one packed word (circuit inputs rarely exceed 64 bits),
                // so the flush transpose scatters only the set bits.
                for r in &requests {
                    inputs.push_sample_word(r.bits.words().first().copied().unwrap_or(0));
                }
            } else {
                for r in &requests {
                    inputs.push_sample(&r.bits);
                }
            }
            return Some(Batch { inputs, requests });
        }
    }

    fn drain_requests(&self) -> Option<Vec<Request>> {
        let mut s = self.state.lock();
        loop {
            if s.queue.len() >= self.policy.max_batch {
                return Some(s.queue.drain(..self.policy.max_batch).collect());
            }
            // Closed beats the age timer: a `close()` wakeup used to fall
            // back into the age branch with a partial queue and sleep out
            // the full `max_wait` — stalling shutdown (and hot-swap drain)
            // by up to the flush window. Flush whatever is queued NOW; the
            // next iteration (or call) observes the emptied queue and
            // returns `None`.
            if s.closed {
                if s.queue.is_empty() {
                    return None;
                }
                let n = s.queue.len().min(self.policy.max_batch);
                return Some(s.queue.drain(..n).collect());
            }
            if let Some(oldest) = s.queue.front() {
                let age = oldest.enqueued.elapsed();
                if age >= self.policy.max_wait {
                    let n = s.queue.len().min(self.policy.max_batch);
                    return Some(s.queue.drain(..n).collect());
                }
                let remaining = self.policy.max_wait - age;
                let (ns, _timed_out) = self.signal.wait_timeout(s, remaining);
                s = ns;
            } else {
                s = self.signal.wait(s);
            }
        }
    }

    /// Number of queued requests (diagnostics).
    pub fn depth(&self) -> usize {
        self.state.lock().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::mpsc::channel;
    use std::sync::Arc;

    const BITS: usize = 3;

    fn req(pattern: usize) -> (Request, crate::util::sync::mpsc::Receiver<Reply>) {
        req_deadline(pattern, None)
    }

    fn req_deadline(
        pattern: usize,
        deadline: Option<Instant>,
    ) -> (Request, crate::util::sync::mpsc::Receiver<Reply>) {
        let (tx, rx) = channel();
        let bits = BitVec::from_bools((0..BITS).map(|i| (pattern >> i) & 1 == 1));
        (
            Request {
                bits,
                features: None,
                enqueued: Instant::now(),
                deadline,
                reply: tx,
                notify: None,
            },
            rx,
        )
    }

    #[test]
    fn flushes_on_max_batch() {
        let b = Batcher::new(
            BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10), ..Default::default() },
            BITS,
        );
        for i in 0..3 {
            let (r, _rx) = req(i);
            std::mem::forget(_rx);
            b.submit(r).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.inputs.num_samples(), 3);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn packs_request_bits_in_lane_order() {
        let b = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10), ..Default::default() },
            BITS,
        );
        for pattern in 0..8usize {
            let (r, _rx) = req(pattern);
            std::mem::forget(_rx);
            b.submit(r).unwrap();
        }
        let batch = b.next_batch().unwrap();
        for lane in 0..8usize {
            // request with pattern `lane` was packed at lane `lane`
            for i in 0..BITS {
                assert_eq!(batch.inputs.get(lane, i), (lane >> i) & 1 == 1, "lane {lane} bit {i}");
            }
        }
    }

    #[test]
    fn flushes_on_age() {
        let b = Arc::new(Batcher::new(
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5), ..Default::default() },
            BITS,
        ));
        let (r, _rx) = req(1);
        std::mem::forget(_rx);
        b.submit(r).unwrap();
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(4), "must wait for age");
    }

    #[test]
    fn close_drains_to_none() {
        let b = Batcher::new(BatchPolicy::default(), BITS);
        b.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn close_flushes_partial_queue_immediately() {
        // Regression: with a partial queue and a long max_wait, a close()
        // wakeup re-entered the age branch and slept out the full window —
        // here, 10 s. The flush must happen in milliseconds.
        let b = Batcher::new(
            BatchPolicy { max_batch: 64, max_wait: Duration::from_secs(10), ..Default::default() },
            BITS,
        );
        let (r, _rx) = req(5);
        std::mem::forget(_rx);
        b.submit(r).unwrap();
        b.close();
        let t = Instant::now();
        let batch = b.next_batch().expect("queued request must flush on close");
        assert_eq!(batch.requests.len(), 1);
        assert!(
            t.elapsed() < Duration::from_secs(1),
            "close-flush took {:?}; must not wait out max_wait",
            t.elapsed()
        );
        assert!(b.next_batch().is_none(), "drained + closed ⇒ None");
    }

    #[test]
    fn close_wakes_a_parked_dispatcher_promptly() {
        // Same stall, other interleaving: the dispatcher is already parked
        // in the age branch's wait_timeout when close() arrives.
        let b = Arc::new(Batcher::new(
            BatchPolicy { max_batch: 64, max_wait: Duration::from_secs(10), ..Default::default() },
            BITS,
        ));
        let b2 = Arc::clone(&b);
        let dispatcher = std::thread::spawn(move || {
            let batch = b2.next_batch().expect("flush on close");
            batch.requests.len()
        });
        let (r, _rx) = req(2);
        std::mem::forget(_rx);
        b.submit(r).unwrap();
        // Give the dispatcher time to park on the age deadline.
        std::thread::sleep(Duration::from_millis(50));
        let t = Instant::now();
        b.close();
        assert_eq!(dispatcher.join().unwrap(), 1);
        assert!(
            t.elapsed() < Duration::from_secs(1),
            "close must wake the parked dispatcher, took {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn submit_after_close_returns_the_request() {
        let b = Batcher::new(BatchPolicy::default(), BITS);
        b.close();
        let (r, _rx) = req(3);
        let rejected = b.submit(r).expect_err("closed batcher must reject");
        assert!(matches!(rejected, SubmitError::Closed(_)), "a close is not an overload");
        // The caller gets the request back intact (reply sender included),
        // so it can resubmit to a replacement router.
        assert_eq!(rejected.into_request().bits.len(), BITS);
        assert_eq!(b.depth(), 0, "rejected request must not sit in the queue");
    }

    #[test]
    fn submit_past_max_depth_is_rejected_as_overload() {
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::with_metrics(
            BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10), max_depth: 2 },
            BITS,
            Some(Arc::clone(&metrics)),
        );
        for i in 0..2 {
            let (r, rx) = req(i);
            std::mem::forget(rx);
            b.submit(r).unwrap();
        }
        let (r, _rx) = req(7);
        let rejected = b.submit(r).expect_err("queue at max_depth must reject");
        assert!(matches!(rejected, SubmitError::Overloaded(_)));
        assert_eq!(rejected.into_request().bits.len(), BITS, "request comes back intact");
        assert_eq!(b.depth(), 2, "rejected request must not grow the queue");
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.rejected_overload.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queue_depth_high_watermark.load(Ordering::Relaxed), 2);
        // Draining the queue reopens admission.
        assert!(b.next_batch().is_some());
        let (r, _rx2) = req(1);
        b.submit(r).expect("drained queue admits again");
    }

    #[test]
    fn depth_capped_below_max_batch_still_flushes_on_age() {
        // A depth cap below max_batch (e.g. --max-queue-depth 1 to induce
        // overload in CI) must not starve the queue: the age timer still
        // flushes whatever is admitted.
        let b = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2), max_depth: 1 },
            BITS,
        );
        let (r, _rx) = req(1);
        std::mem::forget(_rx);
        b.submit(r).unwrap();
        let batch = b.next_batch().expect("age flush below max_batch");
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    #[should_panic(expected = "batcher expects")]
    fn wrong_width_request_is_rejected() {
        let b = Batcher::new(BatchPolicy::default(), BITS);
        let (tx, _rx) = channel();
        let _ = b.submit(Request {
            bits: BitVec::zeros(BITS + 1),
            features: None,
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
            notify: None,
        });
    }

    #[test]
    fn expired_requests_are_shed_not_evaluated() {
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::with_metrics(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10), ..Default::default() },
            BITS,
            Some(Arc::clone(&metrics)),
        );
        let past = Instant::now() - Duration::from_millis(5);
        let mut dead_rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req_deadline(i, Some(past));
            dead_rxs.push(rx);
            b.submit(r).unwrap();
        }
        let (live, live_rx) = req(7);
        b.submit(live).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1, "only the live request reaches the engine");
        assert_eq!(batch.inputs.num_samples(), 1);
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.deadline_expired.load(Ordering::Relaxed), 3);
        // Shed requests' reply channels observe disconnection, never a class.
        for rx in dead_rxs {
            assert!(rx.try_recv().is_err(), "expired request must not get a reply");
        }
        drop(batch);
        assert!(live_rx.try_recv().is_err(), "no reply sent yet — just not shed");
    }

    #[test]
    fn shed_fires_the_notify_callback() {
        let b = Batcher::new(
            BatchPolicy { max_batch: 1, max_wait: Duration::from_secs(10), ..Default::default() },
            BITS,
        );
        let fired = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let fired2 = Arc::clone(&fired);
        let (tx, _rx) = channel();
        let r = Request {
            bits: BitVec::from_bools((0..BITS).map(|_| false)),
            features: None,
            enqueued: Instant::now(),
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            reply: tx,
            notify: Some(Arc::new(move || {
                fired2.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            })),
        };
        b.submit(r).unwrap();
        b.close();
        assert!(b.next_batch().is_none(), "an all-expired drain sheds and keeps waiting");
        assert_eq!(fired.load(std::sync::atomic::Ordering::SeqCst), 1);
    }

    #[test]
    fn expired_requests_do_not_count_against_max_depth() {
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::with_metrics(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10), max_depth: 2 },
            BITS,
            Some(Arc::clone(&metrics)),
        );
        let past = Instant::now() - Duration::from_millis(5);
        for i in 0..2 {
            let (r, rx) = req_deadline(i, Some(past));
            std::mem::forget(rx);
            b.submit(r).unwrap();
        }
        assert_eq!(b.depth(), 2, "queue is at its cap");
        // A live submit at the cap purges the dead entries and is admitted.
        let (r, _rx) = req(5);
        b.submit(r).expect("dead requests must not hold admission slots");
        assert_eq!(b.depth(), 1, "two expired shed, one live admitted");
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.deadline_expired.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.rejected_overload.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn concurrent_submit_and_drain() {
        let b = Arc::new(Batcher::new(
            BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(1), ..Default::default() },
            BITS,
        ));
        let b2 = Arc::clone(&b);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                let (r, rx) = req(i % 8);
                std::mem::forget(rx);
                b2.submit(r).unwrap();
            }
            b2.close();
        });
        let mut total = 0;
        while let Some(batch) = b.next_batch() {
            assert!(batch.requests.len() <= 10);
            assert_eq!(batch.inputs.num_samples(), batch.requests.len());
            total += batch.requests.len();
            if total == 100 {
                break;
            }
        }
        producer.join().unwrap();
        assert_eq!(total, 100);
    }
}
