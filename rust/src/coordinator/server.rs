//! TCP line-protocol inference server.
//!
//! A deliberately simple wire format (one JSON object per line) so any
//! client — `nc`, Python, curl-less scripts — can drive the coordinator:
//!
//! ```text
//! → {"features": [0.1, -0.5, …]}                 # default model
//! ← {"class": 3, "engine": "logic", "latency_us": 42.0}
//! → {"model": "jsc-m", "features": [0.1, …]}     # named model
//! ← {"class": 1, "engine": "logic", "latency_us": 38.0}
//! → {"cmd": "models"}
//! ← {"models": [{"name": …, "engine": …, "features": N, "depth": D,
//!               "default": true}, …], "default": "jsc-s"}
//! → {"cmd": "load", "path": "m.circuit.json"[, "name": "alias"]}
//! ← {"ok": true, "name": "…"}                    # loads or hot-swaps
//! → {"cmd": "unload", "name": "jsc-m"}
//! ← {"ok": true}
//! → {"cmd": "metrics"}
//! ← {"report": "…"}                              # one section per model
//! → {"cmd": "depth"}
//! ← {"depth": 0, "models": {"jsc-s": 0, …},
//!    "luts": {"jsc-s": {"pre": 214, "post": 180}, …}}
//! → {"cmd": "shutdown"}
//! ```
//!
//! One thread per connection (std::net; no tokio offline). The server owns
//! a [`ModelRegistry`]; classify requests name a model (or fall through to
//! the registry default, which keeps every pre-registry client working
//! unchanged), and all inference for one model goes through that model's
//! dynamic batcher, so concurrent clients share batches.
//!
//! Client sockets carry a read timeout so every connection thread polls the
//! shared stop flag even while its client is silent — a shutdown therefore
//! terminates `serve` promptly instead of joining threads parked forever in
//! a blocking read. Finished connection threads are reaped from the accept
//! loop, so a long-lived server does not accumulate one `JoinHandle` per
//! connection ever served.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::registry::ModelRegistry;
use crate::util::json::Json;
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{mpsc, thread};

/// How often an idle connection thread wakes to poll the stop flag.
const READ_POLL: Duration = Duration::from_millis(50);

/// Hard cap on one request line; a client streaming bytes without a
/// newline gets a protocol error and is disconnected instead of growing
/// the per-connection buffer without bound.
const MAX_LINE_BYTES: usize = 1 << 20;

/// Serve until a client sends `{"cmd": "shutdown"}`. Binds to `addr`
/// (e.g. "127.0.0.1:7878"); `ready` is signalled once listening (tests).
/// The registry is left intact on return (the caller may still read
/// per-model metrics); its routers drain when the registry drops.
pub fn serve(
    registry: Arc<ModelRegistry>,
    addr: &str,
    ready: Option<mpsc::Sender<u16>>,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    if let Some(tx) = ready {
        let _ = tx.send(port);
    }
    let stop = Arc::new(AtomicBool::new(false));
    // Accept loop with periodic stop checks.
    listener.set_nonblocking(true)?;
    let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                let r = Arc::clone(&registry);
                let s = Arc::clone(&stop);
                handles.push(thread::spawn(move || handle_client(stream, r, s)));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
        handles = reap_finished(handles);
    }
    // Every thread polls the stop flag at READ_POLL cadence, so this join
    // completes promptly even for connections that never sent a byte.
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Join and drop handles whose threads have already exited.
fn reap_finished(handles: Vec<thread::JoinHandle<()>>) -> Vec<thread::JoinHandle<()>> {
    handles
        .into_iter()
        .filter_map(|h| {
            if h.is_finished() {
                let _ = h.join();
                None
            } else {
                Some(h)
            }
        })
        .collect()
}

fn handle_client(stream: TcpStream, registry: Arc<ModelRegistry>, stop: Arc<AtomicBool>) {
    // A blocking read would pin this thread (and the final join in `serve`)
    // on a silent client forever; time out reads and treat the timeout as a
    // stop-flag poll. Writes get a generous timeout too: a client that
    // pipelines requests but never reads replies would otherwise park this
    // thread in `write_all` with the stop flag unpolled — the same hang,
    // one direction over.
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // Accumulate raw bytes, not a String: `read_line`'s UTF-8 guard
    // truncates everything appended by a call that errors, so a timeout
    // landing mid-multibyte-sequence would silently drop consumed bytes.
    // `read_until` documents that partially read bytes stay in the buffer.
    let mut raw: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Acquire) {
            return;
        }
        // `take` bounds a single call: a client firehosing bytes with no
        // newline (and no ≥ READ_POLL gap) must not grow `raw` past the cap
        // inside one unbounded `read_until`. The loop keeps
        // `raw.len() ≤ MAX_LINE_BYTES` here, so the budget is ≥ 1 and
        // `Ok(0)` unambiguously means EOF.
        let budget = (MAX_LINE_BYTES + 1 - raw.len()) as u64;
        let eof = match (&mut reader).take(budget).read_until(b'\n', &mut raw) {
            Ok(0) => true,
            Ok(_) => false,
            // Timed out while idle or mid-line; bytes read so far stay in
            // `raw` — keep accumulating after the stop-flag poll.
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                false
            }
            Err(_) => return,
        };
        if raw.len() > MAX_LINE_BYTES {
            let e = Json::obj([(
                "error",
                Json::str(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
            )]);
            let _ = writer.write_all(format!("{}\n", e.to_string()).as_bytes());
            return;
        }
        if !raw.ends_with(b"\n") && !eof {
            continue; // mid-line: wait for the rest
        }
        let line = String::from_utf8_lossy(&raw);
        if !line.trim().is_empty() {
            let response = match handle_line(&line, &registry, &stop) {
                Ok(j) => j,
                Err(msg) => Json::obj([("error", Json::str(msg))]),
            };
            if writer
                .write_all(format!("{}\n", response.to_string()).as_bytes())
                .is_err()
            {
                return;
            }
        }
        if eof {
            return;
        }
        raw.clear();
    }
}

fn handle_line(
    line: &str,
    registry: &ModelRegistry,
    stop: &AtomicBool,
) -> Result<Json, String> {
    let req = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
        return handle_cmd(cmd, &req, registry, stop);
    }
    // `model` must be a string when present (`null` counts as absent); a
    // numeric id from a buggy client must not be silently routed to the
    // default model.
    let model = match req.get("model") {
        None | Some(Json::Null) => None,
        Some(m) => Some(
            m.as_str()
                .ok_or_else(|| "model must be a string".to_string())?,
        ),
    };
    let features = req
        .req("features")
        .map_err(|e| e.to_string())?
        .to_f64_vec()
        .map_err(|e| format!("features: {e}"))?;
    // The registry validates the model name and feature width, so an
    // unknown model or wrong-width request comes back as a protocol error,
    // not a panic inside the serving path.
    let rx = registry.classify(model, &features).map_err(|e| e.to_string())?;
    let reply = rx
        .recv_timeout(Duration::from_secs(10))
        .map_err(|_| "inference failed or timed out".to_string())?;
    Ok(Json::obj([
        ("class", Json::int(reply.class as i64)),
        ("engine", Json::str(reply.engine)),
        ("latency_us", Json::float(reply.latency.as_secs_f64() * 1e6)),
    ]))
}

/// Admin commands: registry introspection, live load/unload, shutdown.
fn handle_cmd(
    cmd: &str,
    req: &Json,
    registry: &ModelRegistry,
    stop: &AtomicBool,
) -> Result<Json, String> {
    match cmd {
        // One section per model; single-model deployments read the same
        // counters they always did.
        "metrics" => Ok(Json::obj([(
            "report",
            Json::str(registry.metrics_report()),
        )])),
        // `depth` stays a single integer (total across models) for
        // existing clients, with the per-model split — and the compile-time
        // optimizer's LUT counts (pre/post) per model — alongside.
        "depth" => {
            let infos = registry.infos();
            let per: std::collections::BTreeMap<String, Json> = infos
                .iter()
                .map(|i| (i.name.clone(), Json::int(i.depth as i64)))
                .collect();
            let luts: std::collections::BTreeMap<String, Json> = infos
                .iter()
                .filter_map(|i| {
                    i.lut_counts.map(|(pre, post)| {
                        (
                            i.name.clone(),
                            Json::obj([
                                ("pre", Json::int(pre as i64)),
                                ("post", Json::int(post as i64)),
                            ]),
                        )
                    })
                })
                .collect();
            Ok(Json::obj([
                ("depth", Json::int(registry.depth_total() as i64)),
                ("models", Json::Obj(per)),
                ("luts", Json::Obj(luts)),
            ]))
        }
        "models" => {
            let models: Vec<Json> = registry
                .infos()
                .into_iter()
                .map(|i| {
                    Json::obj([
                        ("name", Json::str(i.name)),
                        ("engine", Json::str(i.engine)),
                        ("features", Json::int(i.features as i64)),
                        ("depth", Json::int(i.depth as i64)),
                        ("default", Json::Bool(i.default)),
                        ("source", i.source.map(Json::str).unwrap_or(Json::Null)),
                    ])
                })
                .collect();
            let default =
                registry.default_name().map(Json::str).unwrap_or(Json::Null);
            Ok(Json::obj([("models", Json::Arr(models)), ("default", default)]))
        }
        "load" => {
            let path = req
                .req("path")
                .map_err(|e| e.to_string())?
                .as_str()
                .ok_or_else(|| "path must be a string".to_string())?;
            // Strict like classify's "model": a non-string alias must not
            // silently fall back to the bundle's own name — that could
            // hot-swap a live model the caller never meant to touch.
            let name = match req.get("name") {
                None | Some(Json::Null) => None,
                Some(n) => Some(
                    n.as_str()
                        .ok_or_else(|| "name must be a string".to_string())?,
                ),
            };
            let key = registry.load_path(path, name).map_err(|e| e.to_string())?;
            Ok(Json::obj([("ok", Json::Bool(true)), ("name", Json::str(key))]))
        }
        "unload" => {
            let name = req
                .req("name")
                .map_err(|e| e.to_string())?
                .as_str()
                .ok_or_else(|| "name must be a string".to_string())?;
            registry.unload(name).map_err(|e| e.to_string())?;
            Ok(Json::obj([("ok", Json::Bool(true))]))
        }
        "shutdown" => {
            stop.store(true, Ordering::Release);
            Ok(Json::obj([("ok", Json::Bool(true))]))
        }
        other => Err(format!("unknown cmd '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::router::{Policy, Router, RouterBuilder};
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::{random_model, Model};
    use std::io::{BufRead, BufReader, Write};

    fn tiny_router_for(model: &Model) -> Router {
        let flow =
            run_flow(model, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        RouterBuilder::new(model.clone())
            .circuit(flow.circuit.netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) })
            .workers(2)
            .build()
            .unwrap()
    }

    fn tiny_registry(seed: u64) -> (Arc<ModelRegistry>, Model) {
        let model = random_model("tcp", 4, &[3, 3], 2, 1, seed);
        let router = tiny_router_for(&model);
        (Arc::new(ModelRegistry::with_default("tcp", router)), model)
    }

    fn spawn_server(
        registry: Arc<ModelRegistry>,
    ) -> (std::thread::JoinHandle<()>, u16) {
        let (tx, rx) = mpsc::channel();
        let server = std::thread::spawn(move || {
            serve(registry, "127.0.0.1:0", Some(tx)).unwrap();
        });
        let port = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        (server, port)
    }

    #[test]
    fn end_to_end_tcp_session() {
        let (registry, model) = tiny_registry(1);
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());

        // inference
        let x = vec![0.3, -0.2, 0.9, -1.0];
        conn.write_all(b"{\"features\": [0.3, -0.2, 0.9, -1.0]}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        let class = resp.get("class").unwrap().as_usize().unwrap();
        assert_eq!(class, crate::nn::eval::classify(&model, &x));
        assert_eq!(resp.get("engine").unwrap().as_str(), Some("logic"));

        // metrics
        conn.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("logic=1"));

        // malformed input → error, session continues
        conn.write_all(b"not json\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));

        // wrong feature width → protocol error, session continues
        conn.write_all(b"{\"features\": [0.1]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error") && line.contains("expected 4"), "{line}");

        // shutdown
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"));
        server.join().unwrap();
    }

    #[test]
    fn depth_command_reports_queue_depth() {
        let (registry, _model) = tiny_registry(2);
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"{\"cmd\": \"depth\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        let depth = resp
            .get("depth")
            .and_then(|d| d.as_usize())
            .expect("depth must be a non-negative integer");
        // An idle router has an empty queue.
        assert_eq!(depth, 0, "{line}");
        // The optimizer's LUT counts ride along per model.
        let luts = resp.get("luts").unwrap().as_obj().unwrap();
        let entry = luts.values().next().expect("one logic model");
        let pre = entry.get("pre").and_then(|v| v.as_usize()).unwrap();
        let post = entry.get("post").and_then(|v| v.as_usize()).unwrap();
        assert!(post <= pre, "{line}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn oversized_line_disconnects_instead_of_growing_forever() {
        let (registry, _model) = tiny_registry(4);
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        // > MAX_LINE_BYTES with no newline: the server must cap the buffer
        // and drop the connection (an error reply may or may not survive
        // the reset race — termination is the contract).
        let chunk = vec![b'x'; (1 << 20) + (1 << 16)];
        let _ = conn.write_all(&chunk);
        let mut line = String::new();
        let _ = reader.read_line(&mut line); // error reply or EOF/reset
        drop(conn);

        // The server itself stays healthy and shuts down cleanly.
        let mut c2 = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut r2 = BufReader::new(c2.try_clone().unwrap());
        c2.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        let mut l2 = String::new();
        r2.read_line(&mut l2).unwrap();
        assert!(l2.contains("ok"), "{l2}");
        server.join().unwrap();
    }

    #[test]
    fn model_field_routes_between_models() {
        // Two models with different feature widths: a misroute would either
        // hit the wrong-width protocol error or decode the wrong circuit.
        let m4 = random_model("four", 4, &[3, 3], 2, 1, 21);
        let m6 = random_model("six", 6, &[4, 3], 2, 1, 22);
        let registry = Arc::new(ModelRegistry::with_default("four", tiny_router_for(&m4)));
        registry.install("six", tiny_router_for(&m6), None).unwrap();
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        // Unnamed → default (the 4-feature model): unchanged legacy shape.
        let x4 = vec![0.3, -0.2, 0.9, -1.0];
        conn.write_all(b"{\"features\": [0.3, -0.2, 0.9, -1.0]}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("class").unwrap().as_usize().unwrap(),
            crate::nn::eval::classify(&m4, &x4)
        );

        // Named → the 6-feature model.
        let x6 = vec![0.1, 0.2, -0.4, 0.5, -0.6, 0.7];
        conn.write_all(
            b"{\"model\": \"six\", \"features\": [0.1, 0.2, -0.4, 0.5, -0.6, 0.7]}\n",
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("class").unwrap().as_usize().unwrap(),
            crate::nn::eval::classify(&m6, &x6),
            "{line}"
        );

        // Unknown model → protocol error, session continues.
        conn.write_all(b"{\"model\": \"nope\", \"features\": [0.0]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error") && line.contains("no model named"), "{line}");

        // Non-string model → protocol error, not silent default routing.
        conn.write_all(b"{\"model\": 3, \"features\": [0.3, -0.2, 0.9, -1.0]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("model must be a string"), "{line}");

        // models command lists both with the default flagged.
        conn.write_all(b"{\"cmd\": \"models\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        let models = resp.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(resp.get("default").unwrap().as_str(), Some("four"));

        // depth: total plus the per-model split.
        conn.write_all(b"{\"cmd\": \"depth\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(resp.get("depth").unwrap().as_usize(), Some(0));
        let per = resp.get("models").unwrap().as_obj().unwrap();
        assert!(per.contains_key("four") && per.contains_key("six"), "{line}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn load_and_unload_over_tcp() {
        let (registry, _model) = tiny_registry(5);
        let (server, port) = spawn_server(Arc::clone(&registry));

        // Persist a bundle for a fresh model to load live.
        let extra = random_model("extra", 5, &[4, 3], 2, 1, 31);
        let flow = run_flow(&extra, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let path = "/tmp/nnt_server_live_load.circuit.json";
        crate::flow::artifact::save_circuit(path, &flow.circuit, &extra).unwrap();

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        conn.write_all(
            format!("{{\"cmd\": \"load\", \"path\": \"{path}\"}}\n").as_bytes(),
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(|o| o.as_bool()), Some(true), "{line}");
        assert_eq!(resp.get("name").unwrap().as_str(), Some("extra"));

        // The freshly loaded model serves, bit-exact.
        let x = vec![0.2, -0.3, 0.4, -0.5, 0.6];
        conn.write_all(
            b"{\"model\": \"extra\", \"features\": [0.2, -0.3, 0.4, -0.5, 0.6]}\n",
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("class").unwrap().as_usize().unwrap(),
            crate::nn::eval::classify(&extra, &x),
            "{line}"
        );

        // Unload it; classifying it again is a protocol error.
        conn.write_all(b"{\"cmd\": \"unload\", \"name\": \"extra\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"), "{line}");
        conn.write_all(b"{\"model\": \"extra\", \"features\": [0.0]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("no model named 'extra'"), "{line}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        server.join().unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn shutdown_completes_with_an_idle_client_attached() {
        // Regression: `serve` used to join per-client threads that could
        // block forever in a read; an idle (never-writing) client therefore
        // hung the shutdown. The read timeout turns that into a poll.
        let (registry, _model) = tiny_registry(3);
        let (server, port) = spawn_server(Arc::clone(&registry));

        // Idle client: connects, never sends a byte.
        let idle = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"));
        // Must return despite the idle client still being connected.
        server.join().unwrap();
        drop(idle);
    }
}
