//! TCP line-protocol inference server.
//!
//! A deliberately simple wire format (one JSON object per line) so any
//! client — `nc`, Python, curl-less scripts — can drive the coordinator:
//!
//! ```text
//! → {"features": [0.1, -0.5, …]}
//! ← {"class": 3, "engine": "logic", "latency_us": 42.0}
//! → {"cmd": "metrics"}
//! ← {"report": "…"}
//! → {"cmd": "shutdown"}
//! ```
//!
//! One thread per connection (std::net; no tokio offline). The server owns
//! a [`Router`]; all inference goes through its dynamic batcher, so
//! concurrent clients share batches.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::router::Router;
use crate::util::json::Json;

/// Serve until a client sends `{"cmd": "shutdown"}`. Binds to `addr`
/// (e.g. "127.0.0.1:7878"); `ready` is signalled once listening (tests).
pub fn serve(
    router: Arc<Router>,
    addr: &str,
    ready: Option<std::sync::mpsc::Sender<u16>>,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    if let Some(tx) = ready {
        let _ = tx.send(port);
    }
    let stop = Arc::new(AtomicBool::new(false));
    // Accept loop with periodic stop checks.
    listener.set_nonblocking(true)?;
    let mut handles = Vec::new();
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                let r = Arc::clone(&router);
                let s = Arc::clone(&stop);
                handles.push(std::thread::spawn(move || handle_client(stream, r, s)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_client(stream: TcpStream, router: Arc<Router>, stop: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let response = match handle_line(&line, &router, &stop) {
            Ok(j) => j,
            Err(msg) => Json::obj([("error", Json::str(msg))]),
        };
        if writer
            .write_all(format!("{}\n", response.to_string()).as_bytes())
            .is_err()
        {
            break;
        }
        if stop.load(Ordering::Acquire) {
            break;
        }
    }
    let _ = peer; // quiet unused warning in non-logging builds
}

fn handle_line(
    line: &str,
    router: &Router,
    stop: &AtomicBool,
) -> Result<Json, String> {
    let req = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "metrics" => Ok(Json::obj([(
                "report",
                Json::str(router.metrics().report()),
            )])),
            "depth" => Ok(Json::obj([("depth", Json::int(router.depth() as i64))])),
            "shutdown" => {
                stop.store(true, Ordering::Release);
                Ok(Json::obj([("ok", Json::Bool(true))]))
            }
            other => Err(format!("unknown cmd '{other}'")),
        };
    }
    let features = req
        .req("features")
        .map_err(|e| e.to_string())?
        .to_f64_vec()
        .map_err(|e| format!("features: {e}"))?;
    // Validate the width up front: a wrong-width request must come back as
    // a protocol error, not a panic inside the serving path.
    if features.len() != router.input_features() {
        return Err(format!(
            "features: expected {} values, got {}",
            router.input_features(),
            features.len()
        ));
    }
    let rx = router.submit(features);
    let reply = rx
        .recv_timeout(std::time::Duration::from_secs(10))
        .map_err(|_| "inference timeout".to_string())?;
    Ok(Json::obj([
        ("class", Json::int(reply.class as i64)),
        ("engine", Json::str(reply.engine)),
        ("latency_us", Json::float(reply.latency.as_secs_f64() * 1e6)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::router::Policy;
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::random_model;
    use std::io::{BufRead, BufReader, Write};
    use std::time::Duration;

    #[test]
    fn end_to_end_tcp_session() {
        let model = random_model("tcp", 4, &[3, 3], 2, 1, 1);
        let flow =
            run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        let router = Arc::new(Router::start(
            model.clone(),
            flow.circuit.netlist,
            None,
            Policy::Logic,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            2,
        ));
        let (tx, rx) = std::sync::mpsc::channel();
        let r2 = Arc::clone(&router);
        let server = std::thread::spawn(move || {
            serve(r2, "127.0.0.1:0", Some(tx)).unwrap();
        });
        let port = rx.recv_timeout(Duration::from_secs(5)).unwrap();

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());

        // inference
        let x = vec![0.3, -0.2, 0.9, -1.0];
        conn.write_all(b"{\"features\": [0.3, -0.2, 0.9, -1.0]}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        let class = resp.get("class").unwrap().as_usize().unwrap();
        assert_eq!(class, crate::nn::eval::classify(&model, &x));
        assert_eq!(resp.get("engine").unwrap().as_str(), Some("logic"));

        // metrics
        conn.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("logic=1"));

        // malformed input → error, session continues
        conn.write_all(b"not json\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));

        // wrong feature width → protocol error, session continues
        conn.write_all(b"{\"features\": [0.1]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error") && line.contains("expected 4"), "{line}");

        // shutdown
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"));
        server.join().unwrap();
    }
}
