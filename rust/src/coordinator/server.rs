//! TCP inference server: JSON lines and binary frames on one port, with a
//! blocking thread-per-connection path and an epoll event-loop path.
//!
//! ## Wire protocols
//!
//! Every connection speaks one of two protocols, chosen by its first byte
//! (see [`crate::coordinator::frame`] for the sniffing argument):
//!
//! * **JSON lines** — one object per line, so any client (`nc`, Python,
//!   `/dev/tcp` scripts) can drive the coordinator:
//!
//! ```text
//! → {"features": [0.1, -0.5, …]}                 # default model
//! ← {"class": 3, "engine": "logic", "latency_us": 42.0}
//! → {"model": "jsc-m", "features": [0.1, …]}     # named model
//! ← {"class": 1, "engine": "logic", "latency_us": 38.0}
//! → {"cmd": "models"}
//! ← {"models": [{"name": …, "engine": …, "features": N, "depth": D,
//!               "default": true}, …], "default": "jsc-s"}
//! → {"cmd": "load", "path": "m.circuit.json"[, "name": "alias"]}
//! ← {"ok": true, "name": "…"}                    # loads or hot-swaps
//! → {"cmd": "unload", "name": "jsc-m"}
//! ← {"ok": true}
//! → {"cmd": "metrics"}
//! ← {"report": "…"}                              # one section per model
//! → {"cmd": "depth"}
//! ← {"depth": 0, "models": {"jsc-s": 0, …},
//!    "luts": {"jsc-s": {"pre": 214, "post": 180}, …}}
//! → {"cmd": "shutdown"}
//! ```
//!
//!   A classify rejected by admission control replies
//!   `{"error": …, "overloaded": true}` so clients can tell "back off"
//!   from "your request is malformed".
//!
//! * **Binary frames** — length-prefixed, carrying pre-binarized packed
//!   `u64` feature words ([`frame`]); classify-only (admin commands stay
//!   JSON). Overload comes back as a typed [`frame::TYPE_OVERLOAD`] frame.
//!
//! ## Accept paths
//!
//! [`serve`] runs one *blocking* thread per connection — simple, portable,
//! and fine for a handful of clients. Connection streams are registered in
//! a named-lock table (`"server.conns"`, visible to `nullanet check
//! --locks`); shutdown stores the stop flag, half-closes every registered
//! stream (unparking blocked reads as EOF), and self-connects once to wake
//! the blocking accept — O(1) work per connection with **no polling**, so
//! an idle server burns zero CPU and shutdown completes in microseconds,
//! not read-timeout periods.
//!
//! [`serve_event`] multiplexes every connection on one thread over
//! [`crate::util::evloop`] (Linux epoll). Requests pipeline per
//! connection — replies are written strictly in request order — and reply
//! readiness is signalled by the dispatcher through a [`ReplyNotify`] that
//! wakes the loop's eventfd. Writes never block: partial writes buffer per
//! connection and drain under `EPOLLOUT`; a connection whose client stops
//! reading is paused (read interest dropped) once its out-buffer passes
//! [`HIGH_WATER`] and resumed below [`LOW_WATER`], so one slow consumer
//! cannot balloon server memory.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Reply, ReplyNotify};
use crate::coordinator::frame;
use crate::coordinator::registry::ModelRegistry;
use crate::error::NnError;
use crate::util::json::Json;
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{mpsc, thread, Mutex};

/// Hard cap on one JSON request line; a client streaming bytes without a
/// newline gets a protocol error and is disconnected instead of growing
/// the per-connection buffer without bound.
const MAX_LINE_BYTES: usize = 1 << 20;

/// How long a blocking session waits for an engine reply.
const REPLY_TIMEOUT: Duration = Duration::from_secs(10);

/// Read granularity for both accept paths.
const READ_CHUNK: usize = 8192;

/// Unflushed reply bytes past which the event loop stops reading a
/// connection (write backpressure engages).
const HIGH_WATER: usize = 1 << 20;

/// Unflushed reply bytes below which a paused connection resumes reading.
const LOW_WATER: usize = 64 << 10;

/// Server-wide default latency budget in milliseconds, applied to
/// classify requests that carry no explicit `deadline_ms`. Zero means no
/// default. Set once by the CLI (`--deadline-ms`) before serving starts;
/// a per-request budget always wins over the default.
static DEFAULT_DEADLINE_MS: AtomicU64 = AtomicU64::new(0);

/// Install the server-wide default deadline budget (`--deadline-ms`).
/// `None` or `Some(0)` clears it.
pub fn set_default_deadline_ms(ms: Option<u64>) {
    DEFAULT_DEADLINE_MS.store(ms.unwrap_or(0), Ordering::Relaxed);
}

/// Resolve a request's absolute deadline from its explicit budget or the
/// server-wide default, anchored at request arrival (now), not at
/// evaluation — queueing time counts against the budget, which is the
/// whole point of shedding.
fn resolve_deadline(explicit_ms: Option<u64>) -> Option<Instant> {
    let ms = match explicit_ms {
        Some(ms) => Some(ms),
        None => match DEFAULT_DEADLINE_MS.load(Ordering::Relaxed) {
            0 => None,
            d => Some(d),
        },
    };
    ms.map(|ms| Instant::now() + Duration::from_millis(ms))
}

/// Classify a dropped reply channel: a disconnect after the request's
/// deadline passed is the batcher shedding it — report the typed
/// deadline error, not a generic timeout.
fn shed_past_deadline(deadline: Option<Instant>) -> Option<NnError> {
    match deadline {
        Some(d) if Instant::now() >= d => Some(NnError::Deadline(
            "request shed before evaluation".to_string(),
        )),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Shared request handling (both accept paths, both protocols)
// ---------------------------------------------------------------------------

/// What one JSON request line asks for. Admin commands resolve immediately
/// (`Reply`); classifies come back unsubmitted so each accept path can
/// choose blocking (`recv_timeout`) or pipelined (pending-queue) delivery.
enum Parsed {
    Reply(Json),
    Classify {
        model: Option<String>,
        features: Vec<f64>,
        deadline_ms: Option<u64>,
    },
}

fn parse_request(
    line: &str,
    registry: &ModelRegistry,
    stop: &AtomicBool,
) -> Result<Parsed, String> {
    let req = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
        return handle_cmd(cmd, &req, registry, stop).map(Parsed::Reply);
    }
    // `model` must be a string when present (`null` counts as absent); a
    // numeric id from a buggy client must not be silently routed to the
    // default model.
    let model = match req.get("model") {
        None | Some(Json::Null) => None,
        Some(m) => Some(
            m.as_str()
                .ok_or_else(|| "model must be a string".to_string())?
                .to_string(),
        ),
    };
    let features = req
        .req("features")
        .map_err(|e| e.to_string())?
        .to_f64_vec()
        .map_err(|e| format!("features: {e}"))?;
    // Strict like `model`: a deadline the server cannot honor as given
    // must be a protocol error, not a silently unbounded request.
    let deadline_ms = match req.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let ms = v.as_i64().filter(|ms| *ms >= 0).ok_or_else(|| {
                "deadline_ms must be a non-negative integer".to_string()
            })?;
            Some(ms as u64)
        }
    };
    Ok(Parsed::Classify { model, features, deadline_ms })
}

/// Admin commands: registry introspection, live load/unload, shutdown.
fn handle_cmd(
    cmd: &str,
    req: &Json,
    registry: &ModelRegistry,
    stop: &AtomicBool,
) -> Result<Json, String> {
    match cmd {
        // One section per model; single-model deployments read the same
        // counters they always did.
        "metrics" => Ok(Json::obj([(
            "report",
            Json::str(registry.metrics_report()),
        )])),
        // `depth` stays a single integer (total across models) for
        // existing clients, with the per-model split — and the compile-time
        // optimizer's LUT counts (pre/post) per model — alongside.
        "depth" => {
            let infos = registry.infos();
            let per: std::collections::BTreeMap<String, Json> = infos
                .iter()
                .map(|i| (i.name.clone(), Json::int(i.depth as i64)))
                .collect();
            let luts: std::collections::BTreeMap<String, Json> = infos
                .iter()
                .filter_map(|i| {
                    i.lut_counts.map(|(pre, post)| {
                        (
                            i.name.clone(),
                            Json::obj([
                                ("pre", Json::int(pre as i64)),
                                ("post", Json::int(post as i64)),
                            ]),
                        )
                    })
                })
                .collect();
            Ok(Json::obj([
                ("depth", Json::int(registry.depth_total() as i64)),
                ("models", Json::Obj(per)),
                ("luts", Json::Obj(luts)),
            ]))
        }
        "models" => {
            let models: Vec<Json> = registry
                .infos()
                .into_iter()
                .map(|i| {
                    Json::obj([
                        ("name", Json::str(i.name)),
                        ("engine", Json::str(i.engine)),
                        ("features", Json::int(i.features as i64)),
                        ("depth", Json::int(i.depth as i64)),
                        ("default", Json::Bool(i.default)),
                        ("source", i.source.map(Json::str).unwrap_or(Json::Null)),
                    ])
                })
                .collect();
            let default =
                registry.default_name().map(Json::str).unwrap_or(Json::Null);
            Ok(Json::obj([("models", Json::Arr(models)), ("default", default)]))
        }
        "load" => {
            let path = req
                .req("path")
                .map_err(|e| e.to_string())?
                .as_str()
                .ok_or_else(|| "path must be a string".to_string())?;
            // Strict like classify's "model": a non-string alias must not
            // silently fall back to the bundle's own name — that could
            // hot-swap a live model the caller never meant to touch.
            let name = match req.get("name") {
                None | Some(Json::Null) => None,
                Some(n) => Some(
                    n.as_str()
                        .ok_or_else(|| "name must be a string".to_string())?,
                ),
            };
            let key = registry.load_path(path, name).map_err(|e| e.to_string())?;
            Ok(Json::obj([("ok", Json::Bool(true)), ("name", Json::str(key))]))
        }
        "unload" => {
            let name = req
                .req("name")
                .map_err(|e| e.to_string())?
                .as_str()
                .ok_or_else(|| "name must be a string".to_string())?;
            registry.unload(name).map_err(|e| e.to_string())?;
            Ok(Json::obj([("ok", Json::Bool(true))]))
        }
        "shutdown" => {
            stop.store(true, Ordering::Release);
            Ok(Json::obj([("ok", Json::Bool(true))]))
        }
        other => Err(format!("unknown cmd '{other}'")),
    }
}

/// Render a successful classify reply.
fn json_reply(reply: &Reply) -> Json {
    Json::obj([
        ("class", Json::int(reply.class as i64)),
        ("engine", Json::str(reply.engine)),
        ("latency_us", Json::float(reply.latency.as_secs_f64() * 1e6)),
    ])
}

/// Render a classify error; admission-control rejections carry an explicit
/// `"overloaded": true` so JSON clients can back off instead of treating
/// the rejection as a malformed request, and deadline sheds carry
/// `"deadline_exceeded": true` so clients know a verbatim retry of an
/// already-late request is pointless.
fn json_error(err: &NnError) -> Json {
    match err {
        NnError::Overload(_) => Json::obj([
            ("error", Json::str(err.to_string())),
            ("overloaded", Json::Bool(true)),
        ]),
        NnError::Deadline(_) => Json::obj([
            ("error", Json::str(err.to_string())),
            ("deadline_exceeded", Json::Bool(true)),
        ]),
        _ => Json::obj([("error", Json::str(err.to_string()))]),
    }
}

fn json_line(j: &Json) -> Vec<u8> {
    let mut bytes = j.to_string().into_bytes();
    bytes.push(b'\n');
    bytes
}

fn oversized_line_reply() -> Vec<u8> {
    json_line(&Json::obj([(
        "error",
        Json::str(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
    )]))
}

/// Serve one decoded binary frame synchronously (blocking path). The
/// registry enforces model/width invariants; overload comes back as the
/// typed overload frame and a deadline shed as the typed deadline frame.
fn respond_frame_blocking(
    f: frame::Frame,
    registry: &ModelRegistry,
    pipelined: bool,
) -> Vec<u8> {
    let frame::Frame::ClassifyReq { model, bits, words, deadline_ms } = f else {
        return frame::encode_error("unexpected frame type from client");
    };
    let deadline = resolve_deadline(deadline_ms.map(u64::from));
    let wps = frame::words_per_sample(bits);
    let samples = words.len() / wps;
    let mut rxs = Vec::with_capacity(samples);
    for s in 0..samples {
        let sample = frame::sample_bits(bits, &words, s);
        match registry.classify_bits(model.as_deref(), sample, deadline, None, pipelined) {
            Ok(rx) => rxs.push(rx),
            // Reject the whole frame; replies for samples already admitted
            // are dropped with their receivers (the dispatcher tolerates a
            // closed reply channel).
            Err(e @ NnError::Overload(_)) => {
                return frame::encode_overload(&e.to_string());
            }
            Err(e) => return frame::encode_error(&e.to_string()),
        }
    }
    let mut classes = Vec::with_capacity(samples);
    for rx in &rxs {
        match rx.recv_timeout(REPLY_TIMEOUT) {
            Ok(r) => classes.push(r.class as u16),
            Err(_) => {
                return match shed_past_deadline(deadline) {
                    Some(e) => frame::encode_deadline(&e.to_string()),
                    None => frame::encode_error("inference failed or timed out"),
                };
            }
        }
    }
    frame::encode_classify_resp(&classes)
}

// ---------------------------------------------------------------------------
// Blocking thread-per-connection path
// ---------------------------------------------------------------------------

/// State shared by the accept loop and every connection thread. The
/// connection table is what makes shutdown O(1)-per-connection without
/// read timeouts: the thread that serves `{"cmd":"shutdown"}` half-closes
/// every registered stream, which unparks blocked reads as EOF, then
/// self-connects once to wake the blocking accept.
struct Shared {
    stop: AtomicBool,
    conns: Mutex<HashMap<usize, TcpStream>>,
    next_token: AtomicUsize,
    /// Where the shutdown wake connects (the listener address, rewritten
    /// to loopback when the bind address is unspecified).
    wake_addr: SocketAddr,
}

impl Shared {
    /// Unblock every parked connection thread and the accept loop. Safe to
    /// call from several threads; shutting down an already-shut stream is
    /// a no-op.
    fn begin_shutdown(&self) {
        for stream in self.conns.lock().values() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        let _ = TcpStream::connect_timeout(&self.wake_addr, Duration::from_secs(1));
    }
}

/// RAII registration in the connection table: the entry disappears no
/// matter which path the handler thread exits through.
struct TableGuard {
    shared: Arc<Shared>,
    token: usize,
}

impl Drop for TableGuard {
    fn drop(&mut self) {
        self.shared.conns.lock().remove(&self.token);
    }
}

/// Serve until a client sends `{"cmd": "shutdown"}`. Binds to `addr`
/// (e.g. "127.0.0.1:7878"); `ready` is signalled once listening (tests).
/// The registry is left intact on return (the caller may still read
/// per-model metrics); its routers drain when the registry drops.
pub fn serve(
    registry: Arc<ModelRegistry>,
    addr: &str,
    ready: Option<mpsc::Sender<u16>>,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    if let Some(tx) = ready {
        let _ = tx.send(local.port());
    }
    let wake_addr = if local.ip().is_unspecified() {
        // 0.0.0.0 / :: accepts loopback but is not connectable as a
        // destination; the wake must target a real interface.
        SocketAddr::new(
            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
            local.port(),
        )
    } else {
        local
    };
    let shared = Arc::new(Shared {
        stop: AtomicBool::new(false),
        conns: Mutex::named("server.conns", HashMap::new()),
        next_token: AtomicUsize::new(0),
        wake_addr,
    });
    let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
    loop {
        let (stream, _) = match listener.accept() {
            Ok(accepted) => accepted,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        // The shutdown self-connect lands here: dropped unserved.
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        let r = Arc::clone(&registry);
        let s = Arc::clone(&shared);
        handles.push(thread::spawn(move || handle_client(stream, r, s)));
        handles = reap_finished(handles);
    }
    // Every connection stream was half-closed by `begin_shutdown`, so each
    // thread's blocked read has already returned EOF — this join is prompt.
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Join and drop handles whose threads have already exited.
fn reap_finished(handles: Vec<thread::JoinHandle<()>>) -> Vec<thread::JoinHandle<()>> {
    handles
        .into_iter()
        .filter_map(|h| {
            if h.is_finished() {
                let _ = h.join();
                None
            } else {
                Some(h)
            }
        })
        .collect()
}

fn handle_client(mut stream: TcpStream, registry: Arc<ModelRegistry>, shared: Arc<Shared>) {
    // Register in the connection table *before* checking the stop flag:
    // `begin_shutdown` stores the flag before walking the table (both
    // under no lock and the walk under the table lock), so a connection
    // either gets half-closed by the walk or observes the flag here —
    // never neither, which would leave its read parked forever.
    let token = shared.next_token.fetch_add(1, Ordering::Relaxed);
    let Ok(clone) = stream.try_clone() else { return };
    shared.conns.lock().insert(token, clone);
    let _guard = TableGuard { shared: Arc::clone(&shared), token };
    if shared.stop.load(Ordering::Acquire) {
        return;
    }
    let _ = stream.set_nodelay(true);
    // A client that pipelines requests but never reads replies would park
    // this thread in `write_all` past shutdown; bound that direction.
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));

    // Sniff the protocol off the first byte: 0xF5 can never begin a JSON
    // line (it is not valid leading UTF-8), so one read disambiguates the
    // whole connection.
    let mut buf = Vec::new();
    let mut chunk = [0u8; READ_CHUNK];
    let n = loop {
        match stream.read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => break n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    };
    buf.extend_from_slice(&chunk[..n]);
    if buf[0] == frame::MAGIC {
        blocking_binary_session(stream, buf, &registry, &shared);
    } else {
        blocking_json_session(stream, buf, &registry, &shared);
    }
}

fn blocking_json_session(
    mut stream: TcpStream,
    mut buf: Vec<u8>,
    registry: &ModelRegistry,
    shared: &Shared,
) {
    let mut chunk = [0u8; READ_CHUNK];
    loop {
        // Serve every complete line already buffered.
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=pos).collect();
            if line.len() > MAX_LINE_BYTES {
                let _ = stream.write_all(&oversized_line_reply());
                return;
            }
            // Lossy, not strict: a stray invalid byte yields a JSON parse
            // error reply instead of silently dropping consumed input.
            let text = String::from_utf8_lossy(&line);
            let trimmed = text.trim();
            if trimmed.is_empty() {
                continue;
            }
            let response = respond_json_blocking(trimmed, registry, &shared.stop);
            if stream.write_all(&json_line(&response)).is_err() {
                return;
            }
            if shared.stop.load(Ordering::Acquire) {
                // This thread served the shutdown (or observed one):
                // unpark everyone else, wake the accept loop, exit.
                shared.begin_shutdown();
                return;
            }
        }
        if buf.len() > MAX_LINE_BYTES {
            let _ = stream.write_all(&oversized_line_reply());
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                // EOF with a trailing unterminated line: still serve it —
                // a one-shot `printf '{…}' | nc` client deserves a reply.
                let text = String::from_utf8_lossy(&buf);
                let trimmed = text.trim();
                if !trimmed.is_empty() {
                    let response =
                        respond_json_blocking(trimmed, registry, &shared.stop);
                    let _ = stream.write_all(&json_line(&response));
                    if shared.stop.load(Ordering::Acquire) {
                        shared.begin_shutdown();
                    }
                }
                return;
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

fn respond_json_blocking(line: &str, registry: &ModelRegistry, stop: &AtomicBool) -> Json {
    match parse_request(line, registry, stop) {
        Err(msg) => Json::obj([("error", Json::str(msg))]),
        Ok(Parsed::Reply(j)) => j,
        Ok(Parsed::Classify { model, features, deadline_ms }) => {
            // The registry validates the model name and feature width, so
            // an unknown model or wrong-width request comes back as a
            // protocol error, not a panic inside the serving path.
            let deadline = resolve_deadline(deadline_ms);
            match registry.classify_with(model.as_deref(), &features, deadline, None, false) {
                Err(e) => json_error(&e),
                Ok(rx) => match rx.recv_timeout(REPLY_TIMEOUT) {
                    Ok(r) => json_reply(&r),
                    Err(_) => match shed_past_deadline(deadline) {
                        Some(e) => json_error(&e),
                        None => Json::obj([(
                            "error",
                            Json::str("inference failed or timed out"),
                        )]),
                    },
                },
            }
        }
    }
}

fn blocking_binary_session(
    mut stream: TcpStream,
    mut buf: Vec<u8>,
    registry: &ModelRegistry,
    shared: &Shared,
) {
    let mut chunk = [0u8; READ_CHUNK];
    loop {
        loop {
            match frame::decode(&buf) {
                Ok(None) => break,
                Ok(Some((f, consumed))) => {
                    buf.drain(..consumed);
                    // Bytes already queued behind this frame are pipelined
                    // requests (the same signal the event loop feeds into
                    // the `pipelined_requests` counter).
                    let pipelined = !buf.is_empty();
                    let reply = respond_frame_blocking(f, registry, pipelined);
                    if stream.write_all(&reply).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    // The stream is unsynchronized past a bad header: a
                    // best-effort typed error, then disconnect.
                    let _ = stream.write_all(&frame::encode_error(&e.to_string()));
                    return;
                }
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Event-loop path (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod event {
    use super::*;
    use crate::util::evloop::{Event, EventLoop, Interest, WAKER_TOKEN};
    use std::collections::VecDeque;
    use std::os::fd::AsRawFd;

    const LISTENER_TOKEN: u64 = 0;

    /// Cap on reads per readiness event so one firehosing connection
    /// cannot starve the rest of the loop; level-triggered epoll re-arms
    /// whatever input is left.
    const MAX_READS_PER_EVENT: usize = 16;

    /// One queued reply, in request order. `Ready` replies (admin results,
    /// protocol errors, overload rejections) still queue behind earlier
    /// classifies so a pipelined client sees responses in exactly the
    /// order it sent requests.
    enum Pending {
        Ready(Vec<u8>),
        Json {
            rx: mpsc::Receiver<Reply>,
            deadline: Option<Instant>,
        },
        Frame {
            rxs: Vec<mpsc::Receiver<Reply>>,
            classes: Vec<Option<u16>>,
            failed: bool,
            deadline: Option<Instant>,
        },
    }

    impl Pending {
        /// Bytes to write, once this reply is fully resolved. A dropped
        /// reply channel past the request's deadline is the batcher
        /// shedding it — rendered as the typed deadline reply, not a
        /// generic failure.
        fn poll(&mut self) -> Option<Vec<u8>> {
            match self {
                Pending::Ready(bytes) => Some(std::mem::take(bytes)),
                Pending::Json { rx, deadline } => match rx.try_recv() {
                    Ok(r) => Some(json_line(&json_reply(&r))),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        let reply = match shed_past_deadline(*deadline) {
                            Some(e) => json_error(&e),
                            None => Json::obj([(
                                "error",
                                Json::str("inference failed or timed out"),
                            )]),
                        };
                        Some(json_line(&reply))
                    }
                },
                Pending::Frame { rxs, classes, failed, deadline } => {
                    for (i, rx) in rxs.iter().enumerate() {
                        if classes[i].is_some() {
                            continue;
                        }
                        match rx.try_recv() {
                            Ok(r) => classes[i] = Some(r.class as u16),
                            Err(mpsc::TryRecvError::Empty) => {}
                            Err(mpsc::TryRecvError::Disconnected) => {
                                *failed = true;
                                classes[i] = Some(0);
                            }
                        }
                    }
                    if classes.iter().all(Option::is_some) {
                        if *failed {
                            match shed_past_deadline(*deadline) {
                                Some(e) => {
                                    Some(frame::encode_deadline(&e.to_string()))
                                }
                                None => Some(frame::encode_error(
                                    "inference failed or timed out",
                                )),
                            }
                        } else {
                            let out: Vec<u16> =
                                classes.iter().map(|c| c.unwrap_or(0)).collect();
                            Some(frame::encode_classify_resp(&out))
                        }
                    } else {
                        None
                    }
                }
            }
        }
    }

    #[derive(Clone, Copy, PartialEq)]
    enum Proto {
        Json,
        Binary,
    }

    struct Conn {
        stream: TcpStream,
        token: u64,
        proto: Option<Proto>,
        in_buf: Vec<u8>,
        pending: VecDeque<Pending>,
        out: Vec<u8>,
        out_pos: usize,
        /// Peer sent EOF (or RDHUP): no more requests, but queued replies
        /// still flush — half-close is a legal client pattern.
        read_closed: bool,
        /// Protocol violation: stop reading, flush queued replies, drop.
        closing: bool,
        /// Fatal I/O error: drop immediately.
        dead: bool,
        /// Read interest withdrawn because the out-buffer passed
        /// [`HIGH_WATER`].
        paused: bool,
        registered: Interest,
    }

    impl Conn {
        fn new(stream: TcpStream, token: u64) -> Conn {
            Conn {
                stream,
                token,
                proto: None,
                in_buf: Vec::new(),
                pending: VecDeque::new(),
                out: Vec::new(),
                out_pos: 0,
                read_closed: false,
                closing: false,
                dead: false,
                paused: false,
                registered: Interest::READ,
            }
        }

        fn backlog(&self) -> usize {
            self.out.len() - self.out_pos
        }

        fn done(&self) -> bool {
            self.dead
                || ((self.read_closed || self.closing)
                    && self.pending.is_empty()
                    && self.backlog() == 0)
        }

        fn push_ready(&mut self, bytes: Vec<u8>) {
            self.pending.push_back(Pending::Ready(bytes));
        }

        fn read_and_process(
            &mut self,
            registry: &ModelRegistry,
            notify: &ReplyNotify,
            stop: &AtomicBool,
        ) {
            if self.closing || self.read_closed {
                return;
            }
            let mut chunk = [0u8; READ_CHUNK];
            let mut budget = MAX_READS_PER_EVENT;
            while budget > 0 {
                budget -= 1;
                match self.stream.read(&mut chunk) {
                    Ok(0) => {
                        self.read_closed = true;
                        break;
                    }
                    Ok(n) => self.in_buf.extend_from_slice(&chunk[..n]),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        self.dead = true;
                        return;
                    }
                }
            }
            self.process(registry, notify, stop);
        }

        fn process(
            &mut self,
            registry: &ModelRegistry,
            notify: &ReplyNotify,
            stop: &AtomicBool,
        ) {
            if self.proto.is_none() {
                let Some(&first) = self.in_buf.first() else { return };
                self.proto = Some(if first == frame::MAGIC {
                    Proto::Binary
                } else {
                    Proto::Json
                });
            }
            match self.proto {
                Some(Proto::Binary) => self.process_frames(registry, notify),
                Some(Proto::Json) => self.process_lines(registry, notify, stop),
                None => {}
            }
        }

        fn process_lines(
            &mut self,
            registry: &ModelRegistry,
            notify: &ReplyNotify,
            stop: &AtomicBool,
        ) {
            while let Some(pos) = self.in_buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = self.in_buf.drain(..=pos).collect();
                if line.len() > MAX_LINE_BYTES {
                    self.push_ready(oversized_line_reply());
                    self.closing = true;
                    return;
                }
                let text = String::from_utf8_lossy(&line);
                let trimmed = text.trim();
                if trimmed.is_empty() {
                    continue;
                }
                match parse_request(trimmed, registry, stop) {
                    Err(msg) => self.push_ready(json_line(&Json::obj([(
                        "error",
                        Json::str(msg),
                    )]))),
                    Ok(Parsed::Reply(j)) => {
                        self.push_ready(json_line(&j));
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                    }
                    Ok(Parsed::Classify { model, features, deadline_ms }) => {
                        let pipelined = !self.pending.is_empty();
                        let deadline = resolve_deadline(deadline_ms);
                        match registry.classify_with(
                            model.as_deref(),
                            &features,
                            deadline,
                            Some(notify.clone()),
                            pipelined,
                        ) {
                            Ok(rx) => {
                                self.pending.push_back(Pending::Json { rx, deadline })
                            }
                            Err(e) => self.push_ready(json_line(&json_error(&e))),
                        }
                    }
                }
            }
            if self.in_buf.len() > MAX_LINE_BYTES {
                self.push_ready(oversized_line_reply());
                self.closing = true;
            }
        }

        fn process_frames(&mut self, registry: &ModelRegistry, notify: &ReplyNotify) {
            loop {
                match frame::decode(&self.in_buf) {
                    Ok(None) => break,
                    Ok(Some((f, consumed))) => {
                        self.in_buf.drain(..consumed);
                        self.handle_frame(f, registry, notify);
                    }
                    Err(e) => {
                        self.push_ready(frame::encode_error(&e.to_string()));
                        self.closing = true;
                        break;
                    }
                }
            }
        }

        fn handle_frame(
            &mut self,
            f: frame::Frame,
            registry: &ModelRegistry,
            notify: &ReplyNotify,
        ) {
            let frame::Frame::ClassifyReq { model, bits, words, deadline_ms } = f else {
                self.push_ready(frame::encode_error(
                    "unexpected frame type from client",
                ));
                return;
            };
            let pipelined = !self.pending.is_empty();
            let deadline = resolve_deadline(deadline_ms.map(u64::from));
            let wps = frame::words_per_sample(bits);
            let samples = words.len() / wps;
            let mut rxs = Vec::with_capacity(samples);
            for s in 0..samples {
                let sample = frame::sample_bits(bits, &words, s);
                match registry.classify_bits(
                    model.as_deref(),
                    sample,
                    deadline,
                    Some(notify.clone()),
                    pipelined,
                ) {
                    Ok(rx) => rxs.push(rx),
                    Err(e) => {
                        // Reject the whole frame; replies for samples
                        // already admitted are dropped with their
                        // receivers (the dispatcher tolerates that).
                        let bytes = if matches!(e, NnError::Overload(_)) {
                            frame::encode_overload(&e.to_string())
                        } else {
                            frame::encode_error(&e.to_string())
                        };
                        self.push_ready(bytes);
                        return;
                    }
                }
            }
            let n = rxs.len();
            self.pending.push_back(Pending::Frame {
                rxs,
                classes: vec![None; n],
                failed: false,
                deadline,
            });
        }

        /// Move every resolved reply at the front of the queue into the
        /// out-buffer. Stops at the first unresolved reply: responses go
        /// out strictly in request order.
        fn pump(&mut self) {
            while let Some(front) = self.pending.front_mut() {
                match front.poll() {
                    Some(bytes) => {
                        self.out.extend_from_slice(&bytes);
                        self.pending.pop_front();
                    }
                    None => break,
                }
            }
            self.update_pause();
        }

        /// Write as much of the out-buffer as the socket accepts.
        fn flush(&mut self) {
            while self.out_pos < self.out.len() {
                // Fault point `socket.write`: pretend the kernel accepted a
                // single byte, so reply ordering and the backpressure
                // hysteresis face maximal short-write fragmentation.
                let end = if crate::util::fault::should_fail("socket.write") {
                    self.out_pos + 1
                } else {
                    self.out.len()
                };
                match self.stream.write(&self.out[self.out_pos..end]) {
                    Ok(0) => {
                        self.dead = true;
                        return;
                    }
                    Ok(n) => self.out_pos += n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        self.dead = true;
                        return;
                    }
                }
            }
            if self.out_pos == self.out.len() {
                self.out.clear();
                self.out_pos = 0;
            } else if self.out_pos > LOW_WATER {
                // Reclaim the flushed prefix occasionally so a long-lived
                // slow consumer does not pin peak-backlog memory.
                self.out.drain(..self.out_pos);
                self.out_pos = 0;
            }
            self.update_pause();
        }

        /// Hysteresis: pause reads past [`HIGH_WATER`] of unflushed reply
        /// bytes, resume below [`LOW_WATER`].
        fn update_pause(&mut self) {
            let backlog = self.backlog();
            if backlog > HIGH_WATER {
                self.paused = true;
            } else if backlog < LOW_WATER {
                self.paused = false;
            }
        }

        /// Re-register with epoll when the wanted interest set changed.
        fn update_interest(&mut self, lp: &EventLoop) {
            let want = Interest {
                readable: !self.paused && !self.read_closed && !self.closing,
                writable: self.backlog() > 0,
            };
            if want != self.registered
                && lp.modify(self.stream.as_raw_fd(), self.token, want).is_ok()
            {
                self.registered = want;
            }
        }
    }

    /// Serve on one thread over epoll until a client sends
    /// `{"cmd": "shutdown"}`. Both wire protocols, pipelined requests,
    /// ordered replies, write backpressure — see the module docs. Errors
    /// with [`ErrorKind::Unsupported`] where epoll is unavailable; callers
    /// fall back to [`serve`].
    pub fn serve_event(
        registry: Arc<ModelRegistry>,
        addr: &str,
        ready: Option<mpsc::Sender<u16>>,
    ) -> std::io::Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let port = listener.local_addr()?.port();
        let mut lp = EventLoop::new()?;
        lp.register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ)?;
        if let Some(tx) = ready {
            let _ = tx.send(port);
        }
        // The dispatcher thread resolves replies; this closure is its
        // doorbell into the loop (coalesced by the eventfd).
        let waker = lp.waker();
        let notify: ReplyNotify = Arc::new(move || waker.wake());
        let stop = AtomicBool::new(false);
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut next_token: u64 = LISTENER_TOKEN + 1;
        let mut events: Vec<Event> = Vec::new();

        loop {
            // Purely event-driven: no timeout, no polling. Every wakeup is
            // socket readiness or the dispatcher's reply doorbell.
            lp.wait(&mut events, None)?;
            for ev in &events {
                match ev.token {
                    WAKER_TOKEN => {} // replies are pumped below, for all conns
                    LISTENER_TOKEN => loop {
                        match listener.accept() {
                            Ok((s, _)) => {
                                let _ = s.set_nonblocking(true);
                                let _ = s.set_nodelay(true);
                                let token = next_token;
                                next_token += 1;
                                if lp
                                    .register(s.as_raw_fd(), token, Interest::READ)
                                    .is_ok()
                                {
                                    conns.insert(token, Conn::new(s, token));
                                }
                            }
                            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                            Err(e) if e.kind() == ErrorKind::Interrupted => {}
                            Err(_) => break,
                        }
                    },
                    t => {
                        if let Some(c) = conns.get_mut(&t) {
                            if ev.readable {
                                c.read_and_process(&registry, &notify, &stop);
                            }
                            if ev.closed {
                                c.read_closed = true;
                            }
                            if ev.writable {
                                c.flush();
                            }
                        }
                    }
                }
            }
            // Pump every connection: a waker event names no connection,
            // and an admitted request's reply may belong to any of them.
            let mut gone: Vec<u64> = Vec::new();
            for c in conns.values_mut() {
                c.pump();
                c.flush();
                if c.done() {
                    gone.push(c.token);
                } else {
                    c.update_interest(&lp);
                }
            }
            for t in gone {
                if let Some(c) = conns.remove(&t) {
                    let _ = lp.deregister(c.stream.as_raw_fd());
                }
            }
            if stop.load(Ordering::Acquire) {
                break;
            }
        }
        // Final drain: give every connection a short blocking window to
        // receive what it is owed (the shutdown "ok" above all), then
        // close. Unresolved classifies are abandoned — their clients see
        // the connection close, the contract for requests in flight at
        // shutdown.
        for mut c in conns.into_values() {
            let _ = lp.deregister(c.stream.as_raw_fd());
            c.pump();
            if c.backlog() > 0 {
                let _ = c.stream.set_nonblocking(false);
                let _ = c.stream.set_write_timeout(Some(Duration::from_millis(250)));
                let _ = c.stream.write_all(&c.out[c.out_pos..]);
            }
            let _ = c.stream.shutdown(Shutdown::Both);
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
pub use event::serve_event;

/// Stub off Linux: the event loop needs epoll. Callers fall back to the
/// blocking [`serve`] path.
#[cfg(not(target_os = "linux"))]
pub fn serve_event(
    _registry: Arc<ModelRegistry>,
    _addr: &str,
    _ready: Option<mpsc::Sender<u16>>,
) -> std::io::Result<()> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "serve_event requires Linux epoll; use the blocking serve path",
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::router::{Policy, Router, RouterBuilder};
    use crate::flow::{run_flow, FlowConfig};
    use crate::nn::model::{random_model, Model};
    use std::io::{BufRead, BufReader, Write};

    fn tiny_router_for(model: &Model) -> Router {
        let flow =
            run_flow(model, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        RouterBuilder::new(model.clone())
            .circuit(flow.circuit.netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            })
            .workers(2)
            .build()
            .unwrap()
    }

    fn tiny_registry(seed: u64) -> (Arc<ModelRegistry>, Model) {
        let model = random_model("tcp", 4, &[3, 3], 2, 1, seed);
        let router = tiny_router_for(&model);
        (Arc::new(ModelRegistry::with_default("tcp", router)), model)
    }

    fn spawn_server(
        registry: Arc<ModelRegistry>,
    ) -> (std::thread::JoinHandle<()>, u16) {
        let (tx, rx) = mpsc::channel();
        let server = std::thread::spawn(move || {
            serve(registry, "127.0.0.1:0", Some(tx)).unwrap();
        });
        let port = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        (server, port)
    }

    /// Encode one sample as a classify-request frame the way a binary
    /// client would: binarize through the model's own quantizer, ship the
    /// packed words.
    fn frame_for(registry: &ModelRegistry, model: Option<&str>, x: &[f64]) -> Vec<u8> {
        let router = registry.get(model).unwrap();
        let bits = router.binarize(x);
        frame::encode_classify_req(model, bits.len() as u16, bits.words())
    }

    /// Read one complete frame off a blocking client socket.
    fn read_frame(stream: &mut std::net::TcpStream, buf: &mut Vec<u8>) -> frame::Frame {
        let mut chunk = [0u8; 4096];
        loop {
            if let Some((f, n)) = frame::decode(buf).unwrap() {
                buf.drain(..n);
                return f;
            }
            let n = stream.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed mid-frame");
            buf.extend_from_slice(&chunk[..n]);
        }
    }

    #[test]
    fn end_to_end_tcp_session() {
        let (registry, model) = tiny_registry(1);
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());

        // inference
        let x = vec![0.3, -0.2, 0.9, -1.0];
        conn.write_all(b"{\"features\": [0.3, -0.2, 0.9, -1.0]}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        let class = resp.get("class").unwrap().as_usize().unwrap();
        assert_eq!(class, crate::nn::eval::classify(&model, &x));
        assert_eq!(resp.get("engine").unwrap().as_str(), Some("logic"));

        // metrics
        conn.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("logic=1"));

        // malformed input → error, session continues
        conn.write_all(b"not json\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));

        // wrong feature width → protocol error, session continues
        conn.write_all(b"{\"features\": [0.1]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error") && line.contains("expected 4"), "{line}");

        // shutdown
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"));
        server.join().unwrap();
    }

    #[test]
    fn depth_command_reports_queue_depth() {
        let (registry, _model) = tiny_registry(2);
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"{\"cmd\": \"depth\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        let depth = resp
            .get("depth")
            .and_then(|d| d.as_usize())
            .expect("depth must be a non-negative integer");
        // An idle router has an empty queue.
        assert_eq!(depth, 0, "{line}");
        // The optimizer's LUT counts ride along per model.
        let luts = resp.get("luts").unwrap().as_obj().unwrap();
        let entry = luts.values().next().expect("one logic model");
        let pre = entry.get("pre").and_then(|v| v.as_usize()).unwrap();
        let post = entry.get("post").and_then(|v| v.as_usize()).unwrap();
        assert!(post <= pre, "{line}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn oversized_line_disconnects_instead_of_growing_forever() {
        let (registry, _model) = tiny_registry(4);
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        // > MAX_LINE_BYTES with no newline: the server must cap the buffer
        // and drop the connection (an error reply may or may not survive
        // the reset race — termination is the contract).
        let chunk = vec![b'x'; (1 << 20) + (1 << 16)];
        let _ = conn.write_all(&chunk);
        let mut line = String::new();
        let _ = reader.read_line(&mut line); // error reply or EOF/reset
        drop(conn);

        // The server itself stays healthy and shuts down cleanly.
        let mut c2 = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut r2 = BufReader::new(c2.try_clone().unwrap());
        c2.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        let mut l2 = String::new();
        r2.read_line(&mut l2).unwrap();
        assert!(l2.contains("ok"), "{l2}");
        server.join().unwrap();
    }

    #[test]
    fn model_field_routes_between_models() {
        // Two models with different feature widths: a misroute would either
        // hit the wrong-width protocol error or decode the wrong circuit.
        let m4 = random_model("four", 4, &[3, 3], 2, 1, 21);
        let m6 = random_model("six", 6, &[4, 3], 2, 1, 22);
        let registry = Arc::new(ModelRegistry::with_default("four", tiny_router_for(&m4)));
        registry.install("six", tiny_router_for(&m6), None).unwrap();
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        // Unnamed → default (the 4-feature model): unchanged legacy shape.
        let x4 = vec![0.3, -0.2, 0.9, -1.0];
        conn.write_all(b"{\"features\": [0.3, -0.2, 0.9, -1.0]}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("class").unwrap().as_usize().unwrap(),
            crate::nn::eval::classify(&m4, &x4)
        );

        // Named → the 6-feature model.
        let x6 = vec![0.1, 0.2, -0.4, 0.5, -0.6, 0.7];
        conn.write_all(
            b"{\"model\": \"six\", \"features\": [0.1, 0.2, -0.4, 0.5, -0.6, 0.7]}\n",
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("class").unwrap().as_usize().unwrap(),
            crate::nn::eval::classify(&m6, &x6),
            "{line}"
        );

        // Unknown model → protocol error, session continues.
        conn.write_all(b"{\"model\": \"nope\", \"features\": [0.0]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error") && line.contains("no model named"), "{line}");

        // Non-string model → protocol error, not silent default routing.
        conn.write_all(b"{\"model\": 3, \"features\": [0.3, -0.2, 0.9, -1.0]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("model must be a string"), "{line}");

        // models command lists both with the default flagged.
        conn.write_all(b"{\"cmd\": \"models\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        let models = resp.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(resp.get("default").unwrap().as_str(), Some("four"));

        // depth: total plus the per-model split.
        conn.write_all(b"{\"cmd\": \"depth\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(resp.get("depth").unwrap().as_usize(), Some(0));
        let per = resp.get("models").unwrap().as_obj().unwrap();
        assert!(per.contains_key("four") && per.contains_key("six"), "{line}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn load_and_unload_over_tcp() {
        let (registry, _model) = tiny_registry(5);
        let (server, port) = spawn_server(Arc::clone(&registry));

        // Persist a bundle for a fresh model to load live.
        let extra = random_model("extra", 5, &[4, 3], 2, 1, 31);
        let flow = run_flow(&extra, &FlowConfig { jobs: 1, ..Default::default() }, None)
            .unwrap();
        let path = "/tmp/nnt_server_live_load.circuit.json";
        crate::flow::artifact::save_circuit(path, &flow.circuit, &extra).unwrap();

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        conn.write_all(
            format!("{{\"cmd\": \"load\", \"path\": \"{path}\"}}\n").as_bytes(),
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(resp.get("ok").and_then(|o| o.as_bool()), Some(true), "{line}");
        assert_eq!(resp.get("name").unwrap().as_str(), Some("extra"));

        // The freshly loaded model serves, bit-exact.
        let x = vec![0.2, -0.3, 0.4, -0.5, 0.6];
        conn.write_all(
            b"{\"model\": \"extra\", \"features\": [0.2, -0.3, 0.4, -0.5, 0.6]}\n",
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("class").unwrap().as_usize().unwrap(),
            crate::nn::eval::classify(&extra, &x),
            "{line}"
        );

        // Unload it; classifying it again is a protocol error.
        conn.write_all(b"{\"cmd\": \"unload\", \"name\": \"extra\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"), "{line}");
        conn.write_all(b"{\"model\": \"extra\", \"features\": [0.0]}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("no model named 'extra'"), "{line}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        server.join().unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn deadline_ms_sheds_and_replies_typed_error() {
        // An age-timer flush 200 ms out guarantees a 0 ms budget expires
        // while the request is still queued: the batcher sheds it and the
        // session gets the typed deadline reply, not a generic timeout.
        let model = random_model("tcp", 4, &[3, 3], 2, 1, 41);
        let flow =
            run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None).unwrap();
        let router = RouterBuilder::new(model.clone())
            .circuit(flow.circuit.netlist)
            .engine(Policy::Logic)
            .batch_policy(BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(200),
                ..Default::default()
            })
            .workers(1)
            .build()
            .unwrap();
        let registry = Arc::new(ModelRegistry::with_default("tcp", router));
        let (server, port) = spawn_server(Arc::clone(&registry));

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        conn.write_all(b"{\"features\": [0.3, -0.2, 0.9, -1.0], \"deadline_ms\": 0}\n")
            .unwrap();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        let msg = resp.get("error").and_then(|e| e.as_str()).unwrap_or("");
        assert!(msg.contains("deadline exceeded"), "{line}");
        assert_eq!(
            resp.get("deadline_exceeded").and_then(|v| v.as_bool()),
            Some(true),
            "{line}"
        );
        let m = registry.get(None).unwrap().metrics();
        assert!(m.deadline_expired.load(std::sync::atomic::Ordering::Relaxed) >= 1);

        // A generous budget still serves normally on the same session.
        let x = vec![0.3, -0.2, 0.9, -1.0];
        conn.write_all(
            b"{\"features\": [0.3, -0.2, 0.9, -1.0], \"deadline_ms\": 30000}\n",
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(
            resp.get("class").unwrap().as_usize().unwrap(),
            crate::nn::eval::classify(&model, &x),
            "{line}"
        );

        // A negative budget is a protocol error; the session continues.
        conn.write_all(b"{\"features\": [0.3], \"deadline_ms\": -5}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("deadline_ms must be a non-negative integer"), "{line}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn shutdown_completes_with_an_idle_client_attached() {
        // Regression, twice over. Originally `serve` joined per-client
        // threads that could block forever in a read, so an idle client
        // hung the shutdown; then a 50 ms read-timeout poll fixed the hang
        // but made every idle connection burn syscalls. The conn-table
        // design must shut down promptly with *zero* polling — pin the
        // latency so a poll-based regression (or a lost wakeup) fails here.
        let (registry, _model) = tiny_registry(3);
        let (server, port) = spawn_server(Arc::clone(&registry));

        // Idle client: connects, never sends a byte.
        let idle = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();

        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"));
        // Must return despite the idle client still being connected — and
        // fast: the shutdown path is event-driven, not poll-driven.
        let t0 = std::time::Instant::now();
        server.join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(250),
            "shutdown took {:?}; the O(1) wake path regressed",
            t0.elapsed()
        );
        drop(idle);
    }

    #[test]
    fn binary_frames_are_sniffed_on_the_blocking_path() {
        let (registry, model) = tiny_registry(6);
        let (server, port) = spawn_server(Arc::clone(&registry));

        // Binary client: one two-sample frame first.
        let mut bin = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let xs = [vec![0.3, -0.2, 0.9, -1.0], vec![-0.5, 0.1, 0.2, 0.8]];
        let router = registry.get(None).unwrap();
        let b0 = router.binarize(&xs[0]);
        let b1 = router.binarize(&xs[1]);
        let mut words = b0.words().to_vec();
        words.extend_from_slice(b1.words());
        let req = frame::encode_classify_req(Some("tcp"), b0.len() as u16, &words);
        bin.write_all(&req).unwrap();
        let mut buf = Vec::new();
        let resp = read_frame(&mut bin, &mut buf);
        let want: Vec<u16> = xs
            .iter()
            .map(|x| crate::nn::eval::classify(&model, x) as u16)
            .collect();
        assert_eq!(resp, frame::Frame::ClassifyResp { classes: want });

        // Pipelined single-sample frames answer in order.
        let mut two = frame_for(&registry, None, &xs[0]);
        two.extend_from_slice(&frame_for(&registry, None, &xs[1]));
        bin.write_all(&two).unwrap();
        for x in &xs {
            let resp = read_frame(&mut bin, &mut buf);
            let want = crate::nn::eval::classify(&model, x) as u16;
            assert_eq!(resp, frame::Frame::ClassifyResp { classes: vec![want] });
        }
        drop(bin);

        // JSON admin on the same port still works: one port, two protocols.
        let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"), "{line}");
        server.join().unwrap();
    }

    #[cfg(target_os = "linux")]
    mod event_loop {
        use super::*;

        fn spawn_event_server(
            registry: Arc<ModelRegistry>,
        ) -> (std::thread::JoinHandle<()>, u16) {
            let (tx, rx) = mpsc::channel();
            let server = std::thread::spawn(move || {
                serve_event(registry, "127.0.0.1:0", Some(tx)).unwrap();
            });
            let port = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            (server, port)
        }

        #[test]
        fn serves_json_and_binary_on_one_port() {
            let (registry, model) = tiny_registry(11);
            let (server, port) = spawn_event_server(Arc::clone(&registry));

            // JSON session (the legacy protocol, unchanged).
            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let x = vec![0.3, -0.2, 0.9, -1.0];
            conn.write_all(b"{\"features\": [0.3, -0.2, 0.9, -1.0]}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = crate::util::json::Json::parse(&line).unwrap();
            assert_eq!(
                resp.get("class").unwrap().as_usize().unwrap(),
                crate::nn::eval::classify(&model, &x)
            );
            conn.write_all(b"{\"cmd\": \"metrics\"}\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("logic=1"), "{line}");
            // Malformed JSON → error reply, session continues.
            conn.write_all(b"not json\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("error"), "{line}");

            // Binary session on the same port, concurrently.
            let mut bin = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            bin.write_all(&frame_for(&registry, Some("tcp"), &x)).unwrap();
            let mut buf = Vec::new();
            let resp = read_frame(&mut bin, &mut buf);
            let want = crate::nn::eval::classify(&model, &x) as u16;
            assert_eq!(resp, frame::Frame::ClassifyResp { classes: vec![want] });
            drop(bin);

            conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("ok"), "{line}");
            server.join().unwrap();
        }

        #[test]
        fn pipelined_frames_answer_in_order_and_count() {
            // A flush policy that parks the batcher briefly guarantees the
            // second and third frames arrive while the first's reply is
            // still pending — deterministic pipelining.
            let model = random_model("tcp", 4, &[3, 3], 2, 1, 12);
            let flow =
                run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
                    .unwrap();
            let router = RouterBuilder::new(model.clone())
                .circuit(flow.circuit.netlist)
                .engine(Policy::Logic)
                .batch_policy(BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(100),
                    ..Default::default()
                })
                .workers(1)
                .build()
                .unwrap();
            let registry = Arc::new(ModelRegistry::with_default("tcp", router));
            let (server, port) = spawn_event_server(Arc::clone(&registry));

            let xs = [
                vec![0.3, -0.2, 0.9, -1.0],
                vec![-0.5, 0.1, 0.2, 0.8],
                vec![0.7, 0.7, -0.7, -0.7],
            ];
            let mut burst = Vec::new();
            for x in &xs {
                burst.extend_from_slice(&frame_for(&registry, None, x));
            }
            let mut bin = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            bin.write_all(&burst).unwrap();
            let mut buf = Vec::new();
            for x in &xs {
                let resp = read_frame(&mut bin, &mut buf);
                let want = crate::nn::eval::classify(&model, x) as u16;
                assert_eq!(
                    resp,
                    frame::Frame::ClassifyResp { classes: vec![want] },
                    "replies must come back in request order"
                );
            }
            // Frames 2 and 3 were submitted while frame 1's reply was
            // parked on the batcher's age timer.
            let m = registry.get(None).unwrap().metrics();
            assert!(
                m.pipelined_requests.load(std::sync::atomic::Ordering::Relaxed) >= 2,
                "pipelined requests must be counted"
            );
            drop(bin);

            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            server.join().unwrap();
        }

        #[test]
        fn overload_comes_back_as_a_typed_frame() {
            // Depth cap 1 with the dispatcher parked on a 200 ms age
            // timer: the first frame is admitted, the second MUST be
            // rejected while the first still occupies the queue.
            let model = random_model("tcp", 4, &[3, 3], 2, 1, 13);
            let flow =
                run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
                    .unwrap();
            let router = RouterBuilder::new(model.clone())
                .circuit(flow.circuit.netlist)
                .engine(Policy::Logic)
                .batch_policy(BatchPolicy {
                    max_batch: 64,
                    max_wait: Duration::from_millis(200),
                    max_depth: 1,
                })
                .workers(1)
                .build()
                .unwrap();
            let registry = Arc::new(ModelRegistry::with_default("tcp", router));
            let (server, port) = spawn_event_server(Arc::clone(&registry));

            let x = vec![0.3, -0.2, 0.9, -1.0];
            let mut burst = frame_for(&registry, None, &x);
            burst.extend_from_slice(&frame_for(&registry, None, &x));
            let mut bin = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            bin.write_all(&burst).unwrap();
            let mut buf = Vec::new();
            // Reply 1: the admitted classify (after the age flush).
            let first = read_frame(&mut bin, &mut buf);
            assert!(
                matches!(first, frame::Frame::ClassifyResp { .. }),
                "admitted request must still serve: {first:?}"
            );
            // Reply 2: the typed overload rejection, in order.
            let second = read_frame(&mut bin, &mut buf);
            assert!(
                matches!(&second, frame::Frame::Overload { message }
                    if message.contains("depth cap")),
                "expected overload frame, got {second:?}"
            );
            let m = registry.get(None).unwrap().metrics();
            assert!(
                m.rejected_overload.load(std::sync::atomic::Ordering::Relaxed) >= 1
            );
            drop(bin);

            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            server.join().unwrap();
        }

        #[test]
        fn deadline_frame_comes_back_typed_on_the_event_loop() {
            // Same shape as the blocking deadline test, through the wire:
            // a type-6 frame with a 0 ms budget is shed on the batcher's
            // 200 ms age timer and answered with a typed DEADLINE frame.
            let model = random_model("tcp", 4, &[3, 3], 2, 1, 42);
            let flow =
                run_flow(&model, &FlowConfig { jobs: 1, ..Default::default() }, None)
                    .unwrap();
            let router = RouterBuilder::new(model.clone())
                .circuit(flow.circuit.netlist)
                .engine(Policy::Logic)
                .batch_policy(BatchPolicy {
                    max_batch: 64,
                    max_wait: Duration::from_millis(200),
                    ..Default::default()
                })
                .workers(1)
                .build()
                .unwrap();
            let registry = Arc::new(ModelRegistry::with_default("tcp", router));
            let (server, port) = spawn_event_server(Arc::clone(&registry));

            let x = vec![0.3, -0.2, 0.9, -1.0];
            let bits = registry.get(None).unwrap().binarize(&x);
            let req = frame::encode_classify_req_deadline(
                None,
                bits.len() as u16,
                bits.words(),
                0,
            );
            let mut bin = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            bin.write_all(&req).unwrap();
            let mut buf = Vec::new();
            let resp = read_frame(&mut bin, &mut buf);
            assert!(
                matches!(&resp, frame::Frame::Deadline { message }
                    if message.contains("deadline exceeded")),
                "expected a typed DEADLINE frame, got {resp:?}"
            );
            let m = registry.get(None).unwrap().metrics();
            assert!(m.deadline_expired.load(std::sync::atomic::Ordering::Relaxed) >= 1);

            // A budget-carrying frame with headroom still classifies.
            let req = frame::encode_classify_req_deadline(
                None,
                bits.len() as u16,
                bits.words(),
                30_000,
            );
            bin.write_all(&req).unwrap();
            let resp = read_frame(&mut bin, &mut buf);
            let want = crate::nn::eval::classify(&model, &x) as u16;
            assert_eq!(resp, frame::Frame::ClassifyResp { classes: vec![want] });
            drop(bin);

            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            server.join().unwrap();
        }

        #[test]
        fn shutdown_is_prompt_with_idle_clients_attached() {
            let (registry, _model) = tiny_registry(14);
            let (server, port) = spawn_event_server(Arc::clone(&registry));

            let idle1 = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let idle2 = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();

            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("ok"), "{line}");
            let t0 = std::time::Instant::now();
            server.join().unwrap();
            assert!(
                t0.elapsed() < Duration::from_millis(250),
                "event-loop shutdown took {:?}",
                t0.elapsed()
            );
            drop(idle1);
            drop(idle2);
        }

        #[test]
        fn bad_frame_gets_a_typed_error_then_disconnect() {
            let (registry, _model) = tiny_registry(15);
            let (server, port) = spawn_event_server(Arc::clone(&registry));

            let mut bin = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            // Valid magic, hostile version byte.
            let mut bad = frame_for(&registry, None, &[0.3, -0.2, 0.9, -1.0]);
            bad[1] = 9;
            bin.write_all(&bad).unwrap();
            let mut buf = Vec::new();
            let resp = read_frame(&mut bin, &mut buf);
            assert!(
                matches!(&resp, frame::Frame::Error { message }
                    if message.contains("version")),
                "{resp:?}"
            );
            // The server closes the unsynchronized stream afterwards.
            let mut probe = [0u8; 1];
            assert_eq!(bin.read(&mut probe).unwrap_or(0), 0, "stream must close");

            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            server.join().unwrap();
        }
    }
}
