//! # NullaNet Tiny — ultra-low-latency DNN inference through fixed-function
//! combinational logic
//!
//! A production-oriented reproduction of *NullaNet Tiny* (Nazemi et al.,
//! 2021): quantized, fanin-constrained neural networks are converted —
//! neuron by neuron — into optimized Boolean logic mapped onto FPGA-style
//! 6-LUTs, eliminating multiply-accumulate arithmetic entirely.
//!
//! The crate is layer 3 of a three-layer stack:
//!
//! * **L1/L2 (build-time Python, `python/`)** — Pallas kernel + JAX model:
//!   quantization-aware training with per-layer activation selection and
//!   fanin-constrained pruning; AOT-lowered to HLO text artifacts.
//! * **L3 (this crate)** — loads the trained model, runs the
//!   enumerate → ESPRESSO-II → AIG → LUT-map → retime pipeline, verifies
//!   bit-exactness against the quantized network, evaluates FPGA cost
//!   (LUTs/FFs/fmax), persists the synthesized circuit as a reloadable,
//!   fingerprint-checked artifact ([`flow::artifact`]), and serves
//!   inference behind the pluggable [`coordinator::engine::InferenceEngine`]
//!   trait: the packed multi-worker bit-parallel simulator, the PJRT
//!   numeric engine, or a disagreement-counting mirror of both. Any number
//!   of compiled models share one process behind the
//!   [`coordinator::registry::ModelRegistry`] — per-model batchers and
//!   metrics, artifact-directory cold start, and live hot-swap that drains
//!   the displaced engine without dropping in-flight replies. Public
//!   entry points report typed [`NnError`]s.
//!
//! See [`rust/DESIGN.md`](../DESIGN.md) for the full system inventory, the
//! packed serving path, and the dependency/substitution policy.

pub mod baseline;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod flow;
pub mod fpga;
pub mod logic;

pub mod nn;
pub mod runtime;
pub mod util;

pub use error::NnError;
