//! Crate-wide typed error.
//!
//! The public API used to hand back `Result<_, String>`; [`NnError`] wraps
//! each layer's own error type (flow, data, runtime, engine, artifact)
//! behind one `Display + Error` enum, dependency-free, so callers can match
//! on the failing layer instead of grepping message strings.

use std::fmt;

use crate::coordinator::engine::EngineError;
use crate::flow::artifact::ArtifactError;
use crate::logic::check::CheckError;
use crate::runtime::pjrt::RuntimeError;
use crate::util::sync::SyncError;

/// Top-level error of the NullaNet Tiny crate.
#[derive(Debug)]
pub enum NnError {
    /// Synthesis-flow failure (enumerate / ESPRESSO / map / retime /
    /// verification mismatch).
    Flow(String),
    /// Model or dataset loading/validation failure.
    Data(String),
    /// Numeric runtime (PJRT) failure.
    Runtime(RuntimeError),
    /// Serving-engine construction or inference failure.
    Engine(EngineError),
    /// Compiled-circuit artifact I/O, format, or fingerprint failure.
    Artifact(ArtifactError),
    /// Structural or equivalence check failure (lint / CEC) — the netlist
    /// would miscompute if used.
    Check(CheckError),
    /// Command-line / configuration error.
    Config(String),
    /// A lock in the serving stack was poisoned by a panicked thread; the
    /// lock healed, but this request saw the fault (checked lock paths).
    Sync(SyncError),
    /// Admission control rejected the request: the model's batch queue is
    /// at its configured depth cap. Transient by design — clients should
    /// back off and resubmit, not treat this as a malformed request.
    Overload(String),
    /// The request's deadline expired before an answer was produced; the
    /// batcher shed it without evaluation. The client already stopped
    /// caring — this names why no classification came back.
    Deadline(String),
}

impl fmt::Display for NnError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NnError::Flow(m) => write!(f, "flow: {m}"),
            NnError::Data(m) => write!(f, "data: {m}"),
            NnError::Runtime(e) => write!(f, "runtime: {e}"),
            NnError::Engine(e) => write!(f, "engine: {e}"),
            NnError::Artifact(e) => write!(f, "artifact: {e}"),
            NnError::Check(e) => write!(f, "check: {e}"),
            NnError::Config(m) => write!(f, "{m}"),
            NnError::Sync(e) => write!(f, "sync: {e}"),
            NnError::Overload(m) => write!(f, "overloaded: {m}"),
            NnError::Deadline(m) => write!(f, "deadline exceeded: {m}"),
        }
    }
}

impl std::error::Error for NnError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NnError::Runtime(e) => Some(e),
            NnError::Engine(e) => Some(e),
            NnError::Artifact(e) => Some(e),
            NnError::Check(e) => Some(e),
            NnError::Sync(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::data::dataset::DataError> for NnError {
    fn from(e: crate::data::dataset::DataError) -> NnError {
        NnError::Data(e.0)
    }
}

impl From<RuntimeError> for NnError {
    fn from(e: RuntimeError) -> NnError {
        NnError::Runtime(e)
    }
}

impl From<EngineError> for NnError {
    fn from(e: EngineError) -> NnError {
        NnError::Engine(e)
    }
}

impl From<ArtifactError> for NnError {
    fn from(e: ArtifactError) -> NnError {
        // A lint failure detected while loading an artifact is a check
        // failure first — surface it as such so callers can match on it
        // regardless of which gate caught the malformed netlist.
        match e {
            ArtifactError::Check(c) => NnError::Check(c),
            other => NnError::Artifact(other),
        }
    }
}

impl From<CheckError> for NnError {
    fn from(e: CheckError) -> NnError {
        NnError::Check(e)
    }
}

impl From<SyncError> for NnError {
    fn from(e: SyncError) -> NnError {
        NnError::Sync(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failing_layer() {
        assert_eq!(NnError::Flow("x".into()).to_string(), "flow: x");
        assert_eq!(NnError::Config("bad flag".into()).to_string(), "bad flag");
        let e = NnError::Engine(EngineError::Construction("no artifact".into()));
        assert!(e.to_string().contains("no artifact"));
    }

    #[test]
    fn wrapped_errors_expose_a_source() {
        use std::error::Error;
        let e = NnError::Engine(EngineError::Inference("boom".into()));
        assert!(e.source().is_some());
        assert!(NnError::Flow("x".into()).source().is_none());
    }

    #[test]
    fn from_impls_pick_the_right_variant() {
        let e: NnError = crate::data::dataset::DataError("bad file".into()).into();
        assert!(matches!(e, NnError::Data(_)));
        let e: NnError = EngineError::Unsupported("shape".into()).into();
        assert!(matches!(e, NnError::Engine(_)));
    }

    #[test]
    fn overload_is_typed_and_names_itself() {
        let e = NnError::Overload("queue full (depth 64)".into());
        assert_eq!(e.to_string(), "overloaded: queue full (depth 64)");
        use std::error::Error;
        assert!(e.source().is_none());
    }

    #[test]
    fn deadline_is_typed_and_names_itself() {
        let e = NnError::Deadline("request expired 3ms before evaluation".into());
        assert_eq!(e.to_string(), "deadline exceeded: request expired 3ms before evaluation");
        use std::error::Error;
        assert!(e.source().is_none());
    }

    #[test]
    fn artifact_check_failures_surface_as_check() {
        let c = CheckError::Stage("zero stages".into());
        let e: NnError = ArtifactError::Check(c.clone()).into();
        assert!(matches!(e, NnError::Check(_)));
        assert!(e.to_string().starts_with("check: "));
        let e: NnError = c.into();
        assert!(matches!(e, NnError::Check(_)));
    }
}
