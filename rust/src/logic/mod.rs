//! Logic synthesis core: two-level minimization, multi-level optimization,
//! technology mapping, retiming, simulation, and netlist emission.
//!
//! This is the substrate stack the paper delegates to ESPRESSO-II and Xilinx
//! Vivado (DESIGN.md §4 documents the substitution):
//!
//! * [`cube`] — positional-cube algebra ([`cube::Cover`] = SOP)
//! * [`truthtable`] — dense tables + Minato–Morreale ISOP
//! * [`espresso`] — two-level minimization (EXPAND/IRREDUNDANT/REDUCE/ESSENTIAL)
//! * [`aig`] — and-inverter graph with structural hashing
//! * [`mapper`] — k-feasible-cut LUT technology mapping
//! * [`netlist`] — mapped LUT network with pipeline registers
//! * [`opt`] — compile-time netlist optimizer (fold / dedup / dead sweep)
//! * [`retime`] — min-period retiming (Leiserson–Saxe)
//! * [`sim`] — wide-lane bit-parallel netlist simulation
//! * [`check`] — structural lint: cycles, dangling signals, arity/table
//!   width, stage and schedule soundness
//! * [`verify`] — exhaustive + sampled equivalence checking
//! * [`cec`] — SAT-based combinational equivalence proofs (miter over
//!   [`crate::util::sat`])
//! * [`codegen`] — netlist-to-native lowering: emit the circuit as
//!   straight-line Rust, build with `rustc`, load via `dlopen` shims
//! * [`blif`] / [`verilog`] — interchange emitters for real FPGA tools

pub mod aig;
pub mod blif;
pub mod cec;
pub mod check;
pub mod codegen;
pub mod cube;
pub mod espresso;
pub mod mapper;
pub mod netlist;
pub mod opt;
pub mod retime;
pub mod sim;
pub mod truthtable;
pub mod verify;
pub mod verilog;
