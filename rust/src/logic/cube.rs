//! Positional-cube algebra for two-level logic (the ESPRESSO-II substrate).
//!
//! A [`Cube`] is a product term over `n` binary variables encoded 2 bits per
//! variable (the classic positional notation from Brayton et al. [36]):
//!
//! | bits | meaning                |
//! |------|------------------------|
//! | `01` | literal `x'` (allows 0)|
//! | `10` | literal `x`  (allows 1)|
//! | `11` | don't care (no literal)|
//! | `00` | empty (contradiction)  |
//!
//! A [`Cover`] is a set of cubes (an SOP). This module provides the exact
//! operations ESPRESSO is built from: intersection, containment, distance,
//! consensus, cofactor, Shannon-recursive tautology and complementation, and
//! dense-truth-table conversion used for verification.

use crate::util::bitvec::BitVec;

/// Maximum supported variable count. Neuron functions are ≤ γ·β ≤ 12 inputs
/// in the paper's architectures, but the logic core is general; 512 keeps
/// word indexing trivial while allowing layer-level covers.
pub const MAX_VARS: usize = 512;

const VARS_PER_WORD: usize = 32;

/// A product term in positional cube notation.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Cube {
    nvars: usize,
    words: Vec<u64>,
}

/// Polarity of one variable within a cube.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pol {
    /// `00` — contradictory.
    Empty,
    /// `01` — negative literal.
    Zero,
    /// `10` — positive literal.
    One,
    /// `11` — variable absent (don't care).
    DC,
}

impl Pol {
    #[inline]
    fn bits(self) -> u64 {
        match self {
            Pol::Empty => 0b00,
            Pol::Zero => 0b01,
            Pol::One => 0b10,
            Pol::DC => 0b11,
        }
    }

    #[inline]
    fn from_bits(b: u64) -> Pol {
        match b & 0b11 {
            0b00 => Pol::Empty,
            0b01 => Pol::Zero,
            0b10 => Pol::One,
            _ => Pol::DC,
        }
    }
}

impl std::fmt::Debug for Cube {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for v in 0..self.nvars {
            let c = match self.get(v) {
                Pol::Empty => '∅',
                Pol::Zero => '0',
                Pol::One => '1',
                Pol::DC => '-',
            };
            write!(f, "{c}")?;
        }
        Ok(())
    }
}

impl Cube {
    /// The universal cube (all don't-cares) over `nvars` variables.
    pub fn full(nvars: usize) -> Cube {
        assert!(nvars <= MAX_VARS);
        let nwords = nvars.div_ceil(VARS_PER_WORD).max(1);
        let mut words = vec![!0u64; nwords];
        // Zero the tail so Eq/Hash are canonical.
        let rem = nvars % VARS_PER_WORD;
        if rem != 0 {
            words[nwords - 1] = (1u64 << (2 * rem)) - 1;
        }
        if nvars == 0 {
            words[0] = 0;
        }
        Cube { nvars, words }
    }

    /// The minterm cube for `assignment` (bit `v` of the slice = value of
    /// variable `v`).
    pub fn minterm(nvars: usize, assignment: u64) -> Cube {
        let mut c = Cube::full(nvars);
        for v in 0..nvars {
            c.set(v, if (assignment >> v) & 1 == 1 { Pol::One } else { Pol::Zero });
        }
        c
    }

    /// Parse from the PLA-style string used in tests: `'0'`,`'1'`,`'-'`.
    pub fn parse(s: &str) -> Cube {
        let mut c = Cube::full(s.len());
        for (v, ch) in s.chars().enumerate() {
            c.set(
                v,
                match ch {
                    '0' => Pol::Zero,
                    '1' => Pol::One,
                    '-' => Pol::DC,
                    _ => panic!("bad cube char {ch}"),
                },
            );
        }
        c
    }

    /// Number of variables.
    #[inline]
    pub fn nvars(&self) -> usize {
        self.nvars
    }

    /// Polarity of variable `v`.
    #[inline]
    pub fn get(&self, v: usize) -> Pol {
        debug_assert!(v < self.nvars);
        Pol::from_bits(self.words[v / VARS_PER_WORD] >> (2 * (v % VARS_PER_WORD)))
    }

    /// Set variable `v` to polarity `p`.
    #[inline]
    pub fn set(&mut self, v: usize, p: Pol) {
        debug_assert!(v < self.nvars);
        let w = &mut self.words[v / VARS_PER_WORD];
        let sh = 2 * (v % VARS_PER_WORD);
        *w = (*w & !(0b11 << sh)) | (p.bits() << sh);
    }

    /// True if some variable has the empty code (cube denotes ∅).
    pub fn is_empty_cube(&self) -> bool {
        for (wi, &w) in self.words.iter().enumerate() {
            // A var is empty iff both of its bits are 0. Detect any 00 pair
            // within the active region.
            let active = self.active_mask(wi);
            let lo = w & 0x5555_5555_5555_5555;
            let hi = (w >> 1) & 0x5555_5555_5555_5555;
            if (lo | hi) & active != active {
                return true;
            }
        }
        false
    }

    /// Mask of low bits of each active var pair in word `wi`.
    #[inline]
    fn active_mask(&self, wi: usize) -> u64 {
        let full_words = self.nvars / VARS_PER_WORD;
        let base = 0x5555_5555_5555_5555u64;
        if wi < full_words {
            base
        } else {
            let rem = self.nvars % VARS_PER_WORD;
            if rem == 0 {
                0
            } else {
                base & ((1u64 << (2 * rem)) - 1)
            }
        }
    }

    /// Intersection (product) of two cubes; `None` if empty.
    pub fn intersect(&self, other: &Cube) -> Option<Cube> {
        debug_assert_eq!(self.nvars, other.nvars);
        let mut out = self.clone();
        for (a, b) in out.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
        if out.is_empty_cube() {
            None
        } else {
            Some(out)
        }
    }

    /// True if `self ⊇ other` (i.e. `self` covers every minterm of `other`).
    #[inline]
    pub fn contains(&self, other: &Cube) -> bool {
        self.words.iter().zip(&other.words).all(|(a, b)| b & !a == 0)
    }

    /// Hamming distance in the cube lattice: number of variables where the
    /// intersection is empty. Distance 0 ⇔ the cubes intersect.
    pub fn distance(&self, other: &Cube) -> usize {
        let mut d = 0;
        for (wi, (&a, &b)) in self.words.iter().zip(&other.words).enumerate() {
            let w = a & b;
            let active = self.active_mask(wi);
            let lo = w & 0x5555_5555_5555_5555;
            let hi = (w >> 1) & 0x5555_5555_5555_5555;
            d += ((!(lo | hi)) & active).count_ones() as usize;
        }
        d
    }

    /// Consensus of two cubes: defined when distance == 1; merges across the
    /// single conflicting variable.
    pub fn consensus(&self, other: &Cube) -> Option<Cube> {
        if self.distance(other) != 1 {
            return None;
        }
        let mut out = self.clone();
        for (wi, w) in out.words.iter_mut().enumerate() {
            let a = *w;
            let b = other.words[wi];
            let and = a & b;
            let active = self.active_mask(wi);
            let lo = and & 0x5555_5555_5555_5555;
            let hi = (and >> 1) & 0x5555_5555_5555_5555;
            let empty_vars = (!(lo | hi)) & active; // low bit of each empty pair
            let empty_mask = empty_vars | (empty_vars << 1);
            // conflict var becomes union; others intersection
            *w = (and & !empty_mask) | ((a | b) & empty_mask);
        }
        if out.is_empty_cube() {
            None
        } else {
            Some(out)
        }
    }

    /// Smallest cube containing both (bitwise union).
    pub fn supercube(&self, other: &Cube) -> Cube {
        let mut out = self.clone();
        for (a, b) in out.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
        out
    }

    /// Cofactor `self / p` (Espresso definition). `None` when `self ∩ p = ∅`.
    pub fn cofactor(&self, p: &Cube) -> Option<Cube> {
        if self.distance(p) != 0 {
            return None;
        }
        let mut out = self.clone();
        for (wi, w) in out.words.iter_mut().enumerate() {
            let mask = self.active_mask(wi);
            let full = mask | (mask << 1);
            *w |= !p.words[wi] & full;
        }
        Some(out)
    }

    /// Number of literals (variables not DC).
    pub fn literal_count(&self) -> usize {
        let mut n = 0;
        for (wi, &w) in self.words.iter().enumerate() {
            let active = self.active_mask(wi);
            let lo = w & 0x5555_5555_5555_5555;
            let hi = (w >> 1) & 0x5555_5555_5555_5555;
            // literal iff exactly one of (lo,hi) set
            n += ((lo ^ hi) & active).count_ones() as usize;
        }
        n
    }

    /// True if the cube is the universal cube.
    pub fn is_full(&self) -> bool {
        *self == Cube::full(self.nvars)
    }

    /// An explicitly-empty cube (variable 0 set to the `00` code). Used as
    /// a removal marker by REDUCE.
    pub fn empty_marker(nvars: usize) -> Cube {
        let mut c = Cube::full(nvars);
        if nvars > 0 {
            c.words[0] &= !0b11u64;
        } else {
            c.words[0] = 0;
        }
        c
    }

    /// Evaluate: does this cube cover the minterm `assignment`?
    #[inline]
    pub fn covers_minterm(&self, assignment: u64) -> bool {
        for v in 0..self.nvars {
            let bit = (assignment >> v) & 1;
            let p = self.get(v);
            let ok = match p {
                Pol::DC => true,
                Pol::One => bit == 1,
                Pol::Zero => bit == 0,
                Pol::Empty => false,
            };
            if !ok {
                return false;
            }
        }
        true
    }
}

/// A set of cubes interpreted as a sum of products.
#[derive(Clone, PartialEq, Eq)]
pub struct Cover {
    nvars: usize,
    pub cubes: Vec<Cube>,
}

impl std::fmt::Debug for Cover {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Cover({} vars, {} cubes):", self.nvars, self.cubes.len())?;
        for c in &self.cubes {
            writeln!(f, "  {c:?}")?;
        }
        Ok(())
    }
}

impl Cover {
    /// Empty cover (constant 0).
    pub fn empty(nvars: usize) -> Cover {
        Cover { nvars, cubes: Vec::new() }
    }

    /// Cover with the universal cube (constant 1).
    pub fn universe(nvars: usize) -> Cover {
        Cover { nvars, cubes: vec![Cube::full(nvars)] }
    }

    /// Build from cubes (all must share `nvars`).
    pub fn from_cubes(nvars: usize, cubes: Vec<Cube>) -> Cover {
        debug_assert!(cubes.iter().all(|c| c.nvars() == nvars));
        Cover { nvars, cubes }
    }

    /// Parse a newline/space separated list of PLA-style cubes.
    pub fn parse(nvars: usize, spec: &str) -> Cover {
        let cubes: Vec<Cube> = spec
            .split_whitespace()
            .map(|s| {
                assert_eq!(s.len(), nvars);
                Cube::parse(s)
            })
            .collect();
        Cover { nvars, cubes }
    }

    /// Number of variables.
    pub fn nvars(&self) -> usize {
        self.nvars
    }

    /// Number of cubes.
    pub fn len(&self) -> usize {
        self.cubes.len()
    }

    /// True if the cover has no cubes.
    pub fn is_empty(&self) -> bool {
        self.cubes.is_empty()
    }

    /// Total literal count (the secondary ESPRESSO cost).
    pub fn literal_count(&self) -> usize {
        self.cubes.iter().map(|c| c.literal_count()).sum()
    }

    /// Does the SOP evaluate to 1 on `assignment`?
    pub fn covers_minterm(&self, assignment: u64) -> bool {
        self.cubes.iter().any(|c| c.covers_minterm(assignment))
    }

    /// Remove cubes contained in another single cube (single-cube
    /// containment). O(n²) but n is small post-minimization.
    pub fn sccc_prune(&mut self) {
        let mut keep = vec![true; self.cubes.len()];
        for i in 0..self.cubes.len() {
            if !keep[i] {
                continue;
            }
            for j in 0..self.cubes.len() {
                if i != j && keep[j] && keep[i] {
                    if self.cubes[j].contains(&self.cubes[i])
                        && !(self.cubes[i] == self.cubes[j] && i < j)
                    {
                        keep[i] = false;
                    }
                }
            }
        }
        let mut idx = 0;
        self.cubes.retain(|_| {
            let k = keep[idx];
            idx += 1;
            k
        });
    }

    /// Cofactor the whole cover by cube `p` (drops cubes disjoint from `p`).
    pub fn cofactor(&self, p: &Cube) -> Cover {
        Cover {
            nvars: self.nvars,
            cubes: self.cubes.iter().filter_map(|c| c.cofactor(p)).collect(),
        }
    }

    /// Union of two covers.
    pub fn union(&self, other: &Cover) -> Cover {
        debug_assert_eq!(self.nvars, other.nvars);
        let mut cubes = self.cubes.clone();
        cubes.extend(other.cubes.iter().cloned());
        Cover { nvars: self.nvars, cubes }
    }

    /// Pick the most binate variable (appears in both polarities, max
    /// occurrences) for Shannon branching; falls back to the most frequent
    /// unate variable.
    fn binate_select(&self) -> Option<usize> {
        let n = self.nvars;
        let mut pos = vec![0u32; n];
        let mut neg = vec![0u32; n];
        for c in &self.cubes {
            for v in 0..n {
                match c.get(v) {
                    Pol::One => pos[v] += 1,
                    Pol::Zero => neg[v] += 1,
                    _ => {}
                }
            }
        }
        // Most binate: maximize min(pos,neg), tie-break max total.
        let mut best: Option<(usize, u32, u32)> = None;
        for v in 0..n {
            let key = (pos[v].min(neg[v]), pos[v] + neg[v]);
            if pos[v] + neg[v] == 0 {
                continue;
            }
            match best {
                None => best = Some((v, key.0, key.1)),
                Some((_, bk0, bk1)) => {
                    if key > (bk0, bk1) {
                        best = Some((v, key.0, key.1));
                    }
                }
            }
        }
        best.map(|(v, _, _)| v)
    }

    /// Positive/negative cofactor cubes for variable `v`.
    fn shannon_cubes(nvars: usize, v: usize) -> (Cube, Cube) {
        let mut p = Cube::full(nvars);
        p.set(v, Pol::One);
        let mut q = Cube::full(nvars);
        q.set(v, Pol::Zero);
        (p, q)
    }

    /// Tautology check (unate reduction + Shannon recursion).
    pub fn is_tautology(&self) -> bool {
        // Fast exits.
        if self.cubes.iter().any(|c| c.is_full()) {
            return true;
        }
        if self.cubes.is_empty() {
            return false;
        }
        match self.binate_select() {
            None => {
                // All cubes are the full cube (handled) or no literals at
                // all — with no literal occurrences and no full cube the
                // cover is empty of constraints only if some cube is full.
                false
            }
            Some(v) => {
                let (p, q) = Cover::shannon_cubes(self.nvars, v);
                self.cofactor(&p).is_tautology() && self.cofactor(&q).is_tautology()
            }
        }
    }

    /// Does this cover contain cube `c` (i.e. `c ⊆ self` as sets of
    /// minterms)? Implemented as tautology of the cofactor — the standard
    /// ESPRESSO containment test.
    pub fn contains_cube(&self, c: &Cube) -> bool {
        self.cofactor(c).is_tautology()
    }

    /// Complement via unate-recursive Shannon expansion:
    /// `~F = x·~F_x + x'·~F_x'` with simple-cover base cases.
    pub fn complement(&self) -> Cover {
        // Base: constant 0 → universe.
        if self.cubes.is_empty() {
            return Cover::universe(self.nvars);
        }
        // Base: contains universal cube → constant 0.
        if self.cubes.iter().any(|c| c.is_full()) {
            return Cover::empty(self.nvars);
        }
        // Base: single cube → DeMorgan.
        if self.cubes.len() == 1 {
            return self.complement_single(&self.cubes[0]);
        }
        let v = match self.binate_select() {
            Some(v) => v,
            None => return Cover::empty(self.nvars), // unreachable in practice
        };
        let (p, q) = Cover::shannon_cubes(self.nvars, v);
        let cp = self.cofactor(&p).complement();
        let cq = self.cofactor(&q).complement();
        let mut cubes = Vec::with_capacity(cp.len() + cq.len());
        for mut c in cp.cubes {
            // AND with literal x_v
            if c.get(v) == Pol::DC {
                c.set(v, Pol::One);
                cubes.push(c);
            } else if c.get(v) == Pol::One {
                cubes.push(c);
            }
            // Pol::Zero would make it empty — cofactor output never has it.
        }
        for mut c in cq.cubes {
            if c.get(v) == Pol::DC {
                c.set(v, Pol::Zero);
                cubes.push(c);
            } else if c.get(v) == Pol::Zero {
                cubes.push(c);
            }
        }
        let mut out = Cover { nvars: self.nvars, cubes };
        out.sccc_prune();
        out
    }

    fn complement_single(&self, c: &Cube) -> Cover {
        let mut cubes = Vec::new();
        for v in 0..self.nvars {
            match c.get(v) {
                Pol::One => {
                    let mut k = Cube::full(self.nvars);
                    k.set(v, Pol::Zero);
                    cubes.push(k);
                }
                Pol::Zero => {
                    let mut k = Cube::full(self.nvars);
                    k.set(v, Pol::One);
                    cubes.push(k);
                }
                _ => {}
            }
        }
        Cover { nvars: self.nvars, cubes }
    }

    /// Dense truth table of the SOP (for verification; `nvars ≤ 24`).
    /// Word-parallel: each cube is the AND of per-variable projection masks,
    /// OR-ed into the result — ~n word ops per cube instead of 2^n bit
    /// probes (hot inside the dense IRREDUNDANT; see EXPERIMENTS.md §Perf).
    pub fn to_truth_bits(&self) -> BitVec {
        assert!(self.nvars <= 24, "dense expansion limited to 24 vars");
        let size = 1usize << self.nvars;
        // Projection masks for each variable (shared across cubes).
        let vars: Vec<BitVec> = (0..self.nvars)
            .map(|v| {
                crate::logic::truthtable::TruthTable::var(self.nvars, v)
                    .bits()
                    .clone()
            })
            .collect();
        let mut out = BitVec::zeros(size);
        for cube in &self.cubes {
            let mut acc = BitVec::ones(size);
            for (v, mask) in vars.iter().enumerate() {
                match cube.get(v) {
                    Pol::One => acc.and_assign(mask),
                    Pol::Zero => {
                        let inv = mask.not();
                        acc.and_assign(&inv);
                    }
                    Pol::DC => {}
                    Pol::Empty => {
                        acc = BitVec::zeros(size);
                        break;
                    }
                }
            }
            out.or_assign(&acc);
        }
        out
    }

    /// Semantic equality of two covers (dense compare; test/verify helper).
    pub fn equivalent(&self, other: &Cover) -> bool {
        debug_assert_eq!(self.nvars, other.nvars);
        self.to_truth_bits() == other.to_truth_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_and_minterm() {
        let f = Cube::full(5);
        assert!(f.is_full());
        assert_eq!(f.literal_count(), 0);
        let m = Cube::minterm(5, 0b10110);
        assert_eq!(m.literal_count(), 5);
        assert!(m.covers_minterm(0b10110));
        assert!(!m.covers_minterm(0b10111));
        assert!(f.contains(&m));
        assert!(!m.contains(&f));
    }

    #[test]
    fn parse_roundtrip() {
        let c = Cube::parse("01-1");
        assert_eq!(c.get(0), Pol::Zero);
        assert_eq!(c.get(1), Pol::One);
        assert_eq!(c.get(2), Pol::DC);
        assert_eq!(c.get(3), Pol::One);
        assert_eq!(format!("{c:?}"), "01-1");
    }

    #[test]
    fn intersect_and_distance() {
        let a = Cube::parse("1--");
        let b = Cube::parse("-0-");
        let i = a.intersect(&b).unwrap();
        assert_eq!(format!("{i:?}"), "10-");
        let c = Cube::parse("0--");
        assert!(a.intersect(&c).is_none());
        assert_eq!(a.distance(&c), 1);
        let d = Cube::parse("01-");
        assert_eq!(a.distance(&d), 1);
        assert_eq!(Cube::parse("10-").distance(&Cube::parse("011")), 2);
    }

    #[test]
    fn consensus_merges_adjacent() {
        let a = Cube::parse("1-0");
        let b = Cube::parse("1-1");
        let c = a.consensus(&b).unwrap();
        assert_eq!(format!("{c:?}"), "1--");
        // distance 2 → no consensus
        assert!(Cube::parse("10-").consensus(&Cube::parse("011")).is_none());
        // x·y' and x'·y → consensus over x is y'·y = empty? distance is 2
        // over (x,y) so also None.
        assert!(Cube::parse("10").consensus(&Cube::parse("01")).is_none());
    }

    #[test]
    fn cofactor_removes_literal() {
        let c = Cube::parse("10-");
        let mut p = Cube::full(3);
        p.set(0, Pol::One);
        let cf = c.cofactor(&p).unwrap();
        assert_eq!(format!("{cf:?}"), "-0-");
        // disjoint → None
        let mut q = Cube::full(3);
        q.set(0, Pol::Zero);
        assert!(c.cofactor(&q).is_none());
    }

    #[test]
    fn supercube_is_union_bound() {
        let a = Cube::parse("110");
        let b = Cube::parse("100");
        let s = a.supercube(&b);
        assert_eq!(format!("{s:?}"), "1-0");
        assert!(s.contains(&a) && s.contains(&b));
    }

    #[test]
    fn tautology_basic() {
        assert!(Cover::universe(3).is_tautology());
        assert!(!Cover::empty(3).is_tautology());
        // x + x' = 1
        assert!(Cover::parse(1, "1 0").is_tautology());
        // x + y is not a tautology
        assert!(!Cover::parse(2, "1- -1").is_tautology());
        // all four minterms of 2 vars
        assert!(Cover::parse(2, "00 01 10 11").is_tautology());
        // missing one minterm
        assert!(!Cover::parse(2, "00 01 10").is_tautology());
    }

    #[test]
    fn complement_of_simple_covers() {
        // ~(x) = x'
        let f = Cover::parse(1, "1");
        let g = f.complement();
        assert_eq!(g.len(), 1);
        assert!(g.covers_minterm(0) && !g.covers_minterm(1));
        // ~0 = 1, ~1 = 0
        assert!(Cover::empty(2).complement().is_tautology());
        assert!(Cover::universe(2).complement().is_empty());
    }

    #[test]
    fn complement_is_exact_on_random_covers() {
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(0xC0FFEE);
        for trial in 0..200 {
            let nvars = 1 + (trial % 8);
            let ncubes = 1 + (rng.below(6) as usize);
            let mut cubes = Vec::new();
            for _ in 0..ncubes {
                let mut c = Cube::full(nvars);
                for v in 0..nvars {
                    match rng.below(3) {
                        0 => c.set(v, Pol::Zero),
                        1 => c.set(v, Pol::One),
                        _ => {}
                    }
                }
                cubes.push(c);
            }
            let f = Cover::from_cubes(nvars, cubes);
            let g = f.complement();
            let tf = f.to_truth_bits();
            let tg = g.to_truth_bits();
            assert_eq!(tg, tf.not(), "complement mismatch, trial {trial}\n{f:?}{g:?}");
        }
    }

    #[test]
    fn contains_cube_via_tautology() {
        let f = Cover::parse(3, "1-- -1-");
        assert!(f.contains_cube(&Cube::parse("11-")));
        assert!(f.contains_cube(&Cube::parse("1-0")));
        assert!(!f.contains_cube(&Cube::parse("--1")));
        assert!(f.contains_cube(&Cube::parse("-11")));
    }

    #[test]
    fn sccc_prune_removes_contained() {
        let mut f = Cover::parse(3, "1-- 11- 111 0-0");
        f.sccc_prune();
        assert_eq!(f.len(), 2);
        assert!(f.cubes.contains(&Cube::parse("1--")));
        assert!(f.cubes.contains(&Cube::parse("0-0")));
    }

    #[test]
    fn sccc_prune_keeps_one_of_duplicates() {
        let mut f = Cover::parse(2, "1- 1-");
        f.sccc_prune();
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn cover_semantics() {
        let f = Cover::parse(2, "1- -1"); // x + y
        assert!(!f.covers_minterm(0b00));
        assert!(f.covers_minterm(0b01)); // x=1 (var0 is bit0)
        assert!(f.covers_minterm(0b10));
        assert!(f.covers_minterm(0b11));
        let t = f.to_truth_bits();
        assert_eq!(t.count_ones(), 3);
    }

    #[test]
    fn literal_count_cover() {
        let f = Cover::parse(3, "1-- 01-");
        assert_eq!(f.literal_count(), 3);
    }
}
