//! Compiled bit-parallel netlist simulation — the inference engine.
//!
//! This is the software stand-in for the FPGA fabric: the combinational-
//! logic inference path the coordinator serves requests from. The netlist is
//! "compiled" once into flat arrays (signal codes, packed ≤6-input tables as
//! single `u64`s, and a levelized evaluation schedule) and then evaluated 64
//! samples per pass with pure word operations — no allocation, no hash
//! lookups, no `TruthTable` indirection on the hot path.
//!
//! The compiled program is **immutable and shareable**: all evaluation state
//! lives in an external [`SimScratch`], so a single `Arc<CompiledNetlist>`
//! can be hit by many worker threads concurrently. Whole batches travel as
//! [`PackedBatch`]es (one `u64` word per input signal per 64-sample lane
//! group, lane-group-major), so handing a lane group to the engine is a
//! slice borrow, not a transpose; [`CompiledNetlist::run_packed_sharded`]
//! shards the lane groups of a large batch across a
//! [`ThreadPool`](crate::util::threadpool::ThreadPool). See `rust/DESIGN.md`
//! §Serving for the measured speedup over the per-sample `Vec<bool>` path.

use std::sync::Arc;

use crate::logic::netlist::{LutNetlist, Sig};
use crate::util::bitvec::PackedBatch;
use crate::util::threadpool::ThreadPool;

/// Signal encoding: 0 = const0, 1 = const1, `2+i` = primary input `i`,
/// `2 + num_inputs + j` = LUT `j`.
type Code = u32;

/// A netlist compiled for fast repeated evaluation. Immutable after
/// [`CompiledNetlist::compile`]; evaluation state lives in [`SimScratch`].
pub struct CompiledNetlist {
    num_inputs: usize,
    /// Flattened LUT input codes.
    lut_inputs: Vec<Code>,
    /// Offset of each LUT's inputs in `lut_inputs` (len = luts + 1).
    offsets: Vec<u32>,
    /// ≤ 64-bit truth table per LUT (k ≤ 6).
    tables: Vec<u64>,
    /// Output codes + inversion flags.
    outputs: Vec<(Code, bool)>,
    /// Levelized evaluation schedule: LUT indices grouped by logic level
    /// (stable within a level, so it is also a valid topological order).
    schedule: Vec<u32>,
}

/// Per-worker evaluation state: values for [const0, const1, inputs…, luts…].
/// Create one per thread via [`CompiledNetlist::make_scratch`] and reuse it
/// across calls; it is sized for exactly one compiled netlist.
pub struct SimScratch {
    vals: Vec<u64>,
}

/// Broadcast table bit `m` across all 64 lanes.
#[inline(always)]
fn lane_mask(table: u64, m: u32) -> u64 {
    0u64.wrapping_sub((table >> m) & 1)
}

/// Specialized k = 1 Shannon fold over the packed table.
#[inline(always)]
fn fold1(t: u64, s0: u64) -> u64 {
    (!s0 & lane_mask(t, 0)) | (s0 & lane_mask(t, 1))
}

/// Specialized k = 2 Shannon fold over the packed table.
#[inline(always)]
fn fold2(t: u64, s0: u64, s1: u64) -> u64 {
    let v0 = (!s0 & lane_mask(t, 0)) | (s0 & lane_mask(t, 1));
    let v1 = (!s0 & lane_mask(t, 2)) | (s0 & lane_mask(t, 3));
    (!s1 & v0) | (s1 & v1)
}

/// Shannon fold for k = 3..6 over a fixed-width table expansion (`W = 2^k`).
/// The constant bounds let the compiler fully unroll each arity, replacing
/// the old 64-entry mux ladder whose width was only known at run time.
#[inline(always)]
fn fold_table<const W: usize>(t: u64, sel: &[u64]) -> u64 {
    debug_assert_eq!(W, 1usize << sel.len());
    let mut v = [0u64; W];
    for (m, vm) in v.iter_mut().enumerate() {
        *vm = lane_mask(t, m as u32);
    }
    let mut width = W;
    for &s in sel.iter().rev() {
        width >>= 1;
        let (lo, hi) = v.split_at_mut(width);
        for (a, &b) in lo.iter_mut().zip(hi.iter()) {
            *a = (!s & *a) | (s & b);
        }
    }
    v[0]
}

impl CompiledNetlist {
    /// Compile a netlist (all LUTs must have ≤ 6 inputs).
    pub fn compile(nl: &LutNetlist) -> CompiledNetlist {
        assert!(nl.max_arity() <= 6, "compiled simulator supports k ≤ 6");
        let code_of = |s: &Sig| -> Code { s.to_code(nl.num_inputs) };
        let mut lut_inputs = Vec::new();
        let mut offsets = vec![0u32];
        let mut tables = Vec::with_capacity(nl.luts.len());
        for lut in &nl.luts {
            for s in &lut.inputs {
                lut_inputs.push(code_of(s));
            }
            offsets.push(lut_inputs.len() as u32);
            // Pack table into u64 (2^k bits, k ≤ 6).
            let mut t = 0u64;
            for m in 0..1u64 << lut.table.nvars() {
                if lut.table.eval(m) {
                    t |= 1 << m;
                }
            }
            tables.push(t);
        }
        let outputs = nl.outputs.iter().map(|(s, inv)| (code_of(s), *inv)).collect();
        // Levelized schedule: evaluate level by level. The stable sort keeps
        // the (already topological) index order inside each level.
        let levels = nl.levels();
        let mut schedule: Vec<u32> = (0..nl.luts.len() as u32).collect();
        schedule.sort_by_key(|&j| levels[j as usize]);
        CompiledNetlist {
            num_inputs: nl.num_inputs,
            lut_inputs,
            offsets,
            tables,
            outputs,
            schedule,
        }
    }

    /// Number of primary inputs.
    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    /// Number of outputs.
    pub fn num_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// Allocate evaluation state for this netlist (one per worker thread).
    pub fn make_scratch(&self) -> SimScratch {
        SimScratch { vals: vec![0u64; 2 + self.num_inputs + self.tables.len()] }
    }

    /// Evaluate 64 samples at once. `inputs[i]` = word of input `i`;
    /// `out[j]` receives the word of output `j`.
    ///
    /// Widths are checked with real assertions (not `debug_assert!`): a
    /// wrong-width request must fail loudly in release builds too, never
    /// silently read garbage.
    pub fn run_words(&self, scratch: &mut SimScratch, inputs: &[u64], out: &mut [u64]) {
        assert_eq!(
            inputs.len(),
            self.num_inputs,
            "run_words: {} input words for a {}-input netlist",
            inputs.len(),
            self.num_inputs
        );
        assert_eq!(
            out.len(),
            self.outputs.len(),
            "run_words: {} output words for a {}-output netlist",
            out.len(),
            self.outputs.len()
        );
        let ni = self.num_inputs;
        let vals = &mut scratch.vals;
        assert_eq!(
            vals.len(),
            2 + ni + self.tables.len(),
            "run_words: scratch was built for a different netlist"
        );
        vals[0] = 0;
        vals[1] = !0u64;
        vals[2..2 + ni].copy_from_slice(inputs);
        for &j in &self.schedule {
            let j = j as usize;
            let lo = self.offsets[j] as usize;
            let hi = self.offsets[j + 1] as usize;
            let table = self.tables[j];
            let mut sel = [0u64; 6];
            for (s, &code) in sel.iter_mut().zip(&self.lut_inputs[lo..hi]) {
                *s = vals[code as usize];
            }
            vals[2 + ni + j] = match hi - lo {
                0 => lane_mask(table, 0),
                1 => fold1(table, sel[0]),
                2 => fold2(table, sel[0], sel[1]),
                3 => fold_table::<8>(table, &sel[..3]),
                4 => fold_table::<16>(table, &sel[..4]),
                5 => fold_table::<32>(table, &sel[..5]),
                _ => fold_table::<64>(table, &sel[..6]),
            };
        }
        for (o, (code, inv)) in out.iter_mut().zip(&self.outputs) {
            *o = vals[*code as usize] ^ if *inv { !0u64 } else { 0 };
        }
    }

    /// Evaluate lane groups `g0..g1` of a packed batch, writing output words
    /// group-major into `out` (`(g1 - g0) * num_outputs()` words). This is
    /// the shard body of [`CompiledNetlist::run_packed_sharded`].
    pub fn run_groups(
        &self,
        batch: &PackedBatch,
        g0: usize,
        g1: usize,
        scratch: &mut SimScratch,
        out: &mut [u64],
    ) {
        assert_eq!(
            batch.num_signals(),
            self.num_inputs,
            "run_groups: batch packs {} signals for a {}-input netlist",
            batch.num_signals(),
            self.num_inputs
        );
        assert!(g0 <= g1 && g1 <= batch.num_groups(), "run_groups: bad group range");
        let no = self.outputs.len();
        assert_eq!(out.len(), (g1 - g0) * no, "run_groups: output slice width");
        for g in g0..g1 {
            let dst = &mut out[(g - g0) * no..(g - g0 + 1) * no];
            self.run_words(scratch, batch.group_words(g), dst);
        }
    }

    /// Evaluate a whole packed batch on the calling thread; returns the
    /// packed output batch (tail lanes masked).
    pub fn run_packed(&self, batch: &PackedBatch, scratch: &mut SimScratch) -> PackedBatch {
        let groups = batch.num_groups();
        let no = self.outputs.len();
        let mut words = vec![0u64; groups * no];
        self.run_groups(batch, 0, groups, scratch, &mut words);
        PackedBatch::from_group_major_words(no, batch.num_samples(), words)
    }

    /// Evaluate a packed batch with its lane groups sharded across a worker
    /// pool, every worker sharing one `Arc<CompiledNetlist>` with its own
    /// [`SimScratch`]. Falls back to the inline path when the batch has a
    /// single lane group (or the pool a single worker). Associated function
    /// (`&Arc<Self>` is not a valid method receiver on stable Rust):
    /// `CompiledNetlist::run_packed_sharded(&sim, &pool, &batch)`.
    pub fn run_packed_sharded(
        this: &Arc<Self>,
        pool: &ThreadPool,
        batch: &Arc<PackedBatch>,
    ) -> PackedBatch {
        let groups = batch.num_groups();
        let shards = pool.size().min(groups);
        if shards <= 1 {
            let mut scratch = this.make_scratch();
            return this.run_packed(batch, &mut scratch);
        }
        let per = groups.div_ceil(shards);
        let ranges: Vec<(usize, usize)> = (0..shards)
            .map(|i| (i * per, ((i + 1) * per).min(groups)))
            .filter(|&(a, b)| a < b)
            .collect();
        let sim = Arc::clone(this);
        let shared = Arc::clone(batch);
        let no = this.outputs.len();
        let chunks = pool.par_map(ranges, move |(g0, g1)| {
            let mut scratch = sim.make_scratch();
            let mut out = vec![0u64; (g1 - g0) * sim.num_outputs()];
            sim.run_groups(&shared, g0, g1, &mut scratch, &mut out);
            out
        });
        let mut words = Vec::with_capacity(groups * no);
        for c in &chunks {
            words.extend_from_slice(c);
        }
        PackedBatch::from_group_major_words(no, batch.num_samples(), words)
    }

    /// Evaluate a batch of arbitrary size: `samples[s][i]` = input `i` of
    /// sample `s`; returns `result[s][j]` = output `j` of sample `s`.
    ///
    /// Legacy per-sample path, kept for offline evaluation and as the
    /// baseline the packed path is benchmarked against; the serving hot path
    /// uses [`CompiledNetlist::run_packed`] / `run_packed_sharded`.
    pub fn run_batch(&self, samples: &[Vec<bool>]) -> Vec<Vec<bool>> {
        let n = samples.len();
        let mut scratch = self.make_scratch();
        let mut results = vec![vec![false; self.outputs.len()]; n];
        let mut in_words = vec![0u64; self.num_inputs];
        let mut out_words = vec![0u64; self.outputs.len()];
        let mut base = 0;
        while base < n {
            let lanes = (n - base).min(64);
            for w in in_words.iter_mut() {
                *w = 0;
            }
            for lane in 0..lanes {
                let s = &samples[base + lane];
                assert_eq!(
                    s.len(),
                    self.num_inputs,
                    "run_batch: sample {} has {} bits for a {}-input netlist",
                    base + lane,
                    s.len(),
                    self.num_inputs
                );
                for (i, &b) in s.iter().enumerate() {
                    if b {
                        in_words[i] |= 1 << lane;
                    }
                }
            }
            self.run_words(&mut scratch, &in_words, &mut out_words);
            for lane in 0..lanes {
                for (j, w) in out_words.iter().enumerate() {
                    results[base + lane][j] = (w >> lane) & 1 == 1;
                }
            }
            base += lanes;
        }
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::truthtable::TruthTable;
    use crate::util::prng::Xoshiro256;

    fn random_netlist(seed: u64, num_inputs: usize, num_luts: usize) -> LutNetlist {
        let mut rng = Xoshiro256::new(seed);
        let mut nl = LutNetlist::new(num_inputs);
        for j in 0..num_luts {
            let max_sig = num_inputs + j;
            let k = 1 + rng.below(5.min(max_sig as u64)) as usize;
            let mut inputs = Vec::with_capacity(k);
            for _ in 0..k {
                let pick = rng.below(max_sig as u64) as usize;
                inputs.push(if pick < num_inputs {
                    Sig::Input(pick as u32)
                } else {
                    Sig::Lut((pick - num_inputs) as u32)
                });
            }
            let tt = TruthTable::from_fn(k, |_| rng.bernoulli(0.5));
            nl.add_lut(inputs, tt);
        }
        // outputs: last few luts with random inversion
        for j in num_luts.saturating_sub(4)..num_luts {
            nl.add_output(Sig::Lut(j as u32), rng.bernoulli(0.5));
        }
        nl.add_output(Sig::Const(true), false);
        nl.add_output(Sig::Input(0), true);
        nl
    }

    #[test]
    fn compiled_matches_reference_simulation() {
        for seed in 0..10u64 {
            let nl = random_netlist(seed, 8, 20);
            let c = CompiledNetlist::compile(&nl);
            let mut scratch = c.make_scratch();
            let mut rng = Xoshiro256::new(seed ^ 0xF00);
            let inputs: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
            let want = nl.simulate_words(&inputs);
            let mut got = vec![0u64; want.len()];
            c.run_words(&mut scratch, &inputs, &mut got);
            assert_eq!(got, want, "seed={seed}");
        }
    }

    #[test]
    fn run_batch_roundtrip() {
        let nl = random_netlist(77, 6, 15);
        let c = CompiledNetlist::compile(&nl);
        let mut rng = Xoshiro256::new(123);
        // deliberately non-multiple-of-64 batch
        let samples: Vec<Vec<bool>> = (0..150)
            .map(|_| (0..6).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let results = c.run_batch(&samples);
        for (s, r) in samples.iter().zip(&results) {
            let bits: u64 = s
                .iter()
                .enumerate()
                .map(|(i, &b)| if b { 1u64 << i } else { 0 })
                .sum();
            assert_eq!(*r, nl.eval(bits));
        }
    }

    #[test]
    fn zero_input_luts() {
        let mut nl = LutNetlist::new(1);
        let t = TruthTable::from_fn(0, |_| true); // constant-1 LUT
        let a = nl.add_lut(vec![], t);
        nl.add_output(a, false);
        nl.add_output(a, true);
        let c = CompiledNetlist::compile(&nl);
        let mut scratch = c.make_scratch();
        let mut out = vec![0u64; 2];
        c.run_words(&mut scratch, &[0u64], &mut out);
        assert_eq!(out[0], !0u64);
        assert_eq!(out[1], 0u64);
    }

    #[test]
    fn six_input_lut_exact() {
        let mut rng = Xoshiro256::new(0x6);
        let tt = TruthTable::from_fn(6, |_| rng.bernoulli(0.5));
        let mut nl = LutNetlist::new(6);
        let sig = nl.add_lut((0..6).map(Sig::Input).collect(), tt.clone());
        nl.add_output(sig, false);
        let c = CompiledNetlist::compile(&nl);
        let mut scratch = c.make_scratch();
        // exhaustive over all 64 assignments, packed in one word per input
        let inputs: Vec<u64> = (0..6)
            .map(|i| {
                let mut w = 0u64;
                for m in 0..64u64 {
                    if (m >> i) & 1 == 1 {
                        w |= 1 << m;
                    }
                }
                w
            })
            .collect();
        let mut out = vec![0u64];
        c.run_words(&mut scratch, &inputs, &mut out);
        for m in 0..64u64 {
            assert_eq!((out[0] >> m) & 1 == 1, tt.eval(m), "m={m}");
        }
    }

    #[test]
    fn run_packed_matches_run_batch() {
        let nl = random_netlist(5, 7, 18);
        let c = CompiledNetlist::compile(&nl);
        let mut rng = Xoshiro256::new(9);
        // non-multiple-of-64 so the tail group is partial
        let samples: Vec<Vec<bool>> = (0..201)
            .map(|_| (0..7).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let mut packed = PackedBatch::with_capacity(7, samples.len());
        for s in &samples {
            packed.push_sample_bools(s);
        }
        let mut scratch = c.make_scratch();
        let out = c.run_packed(&packed, &mut scratch);
        let want = c.run_batch(&samples);
        assert_eq!(out.num_samples(), samples.len());
        for (s, w) in want.iter().enumerate() {
            for (j, &b) in w.iter().enumerate() {
                assert_eq!(out.get(s, j), b, "sample {s} output {j}");
            }
        }
    }

    #[test]
    fn sharded_matches_inline_across_worker_counts() {
        let nl = random_netlist(11, 6, 22);
        let c = Arc::new(CompiledNetlist::compile(&nl));
        let mut rng = Xoshiro256::new(21);
        let samples: Vec<Vec<bool>> = (0..300)
            .map(|_| (0..6).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let mut packed = PackedBatch::with_capacity(6, samples.len());
        for s in &samples {
            packed.push_sample_bools(s);
        }
        let batch = Arc::new(packed);
        let mut scratch = c.make_scratch();
        let inline = c.run_packed(&batch, &mut scratch);
        for workers in [1usize, 2, 4] {
            let pool = ThreadPool::new(workers);
            let sharded = CompiledNetlist::run_packed_sharded(&c, &pool, &batch);
            assert_eq!(sharded, inline, "workers={workers}");
        }
    }

    #[test]
    #[should_panic(expected = "run_batch: sample 0 has 3 bits")]
    fn wrong_width_sample_is_a_real_error() {
        let nl = random_netlist(3, 6, 10);
        let c = CompiledNetlist::compile(&nl);
        let _ = c.run_batch(&[vec![false; 3]]);
    }

    #[test]
    #[should_panic(expected = "scratch was built for a different netlist")]
    fn mismatched_scratch_is_a_real_error() {
        let a = CompiledNetlist::compile(&random_netlist(1, 6, 10));
        let b = CompiledNetlist::compile(&random_netlist(2, 6, 12));
        let mut scratch = b.make_scratch();
        let mut out = vec![0u64; a.num_outputs()];
        a.run_words(&mut scratch, &[0u64; 6], &mut out);
    }
}
