//! Compiled bit-parallel netlist simulation — the inference engine.
//!
//! This is the software stand-in for the FPGA fabric: the combinational-
//! logic inference path the coordinator serves requests from. The netlist
//! is first run through the compile-time optimizer
//! ([`crate::logic::opt::optimize`]: constant folding, structural dedup,
//! dead-LUT sweep — fewer LUTs evaluated on *every* word pass), then
//! "compiled" into an **arity-segregated, schedule-ordered flat instruction
//! stream**: LUTs are levelized, stably ordered by `(level, arity)`, and
//! grouped into same-arity *runs*, so evaluation dispatches once per run
//! into a straight-line arity-specialized fold loop instead of matching on
//! arity per LUT.
//!
//! Evaluation is a **wide-lane block kernel**: `run_block::<W>` evaluates
//! `W × 64` samples per pass over `[u64; W]` value blocks (W ∈ {1, 2, 4,
//! 8}). The per-instruction Shannon fold iterates the W lane words in its
//! innermost loop — fixed trip count, no data dependence across words — so
//! LLVM auto-vectorizes it. [`CompiledNetlist::run_groups`] picks the block
//! width from what remains of the batch (8 → 4 → 2 → 1), which keeps W = 1
//! for latency-sensitive single-group batches;
//! [`CompiledNetlist::run_groups_capped`] pins a maximum width for
//! benchmarking.
//!
//! The compiled program is **immutable and shareable**: all evaluation
//! state lives in an external [`SimScratch`], so a single
//! `Arc<CompiledNetlist>` can be hit by many worker threads concurrently.
//! Whole batches travel as [`PackedBatch`]es (one `u64` word per input
//! signal per 64-sample lane group, lane-group-major), so handing a lane
//! group to the engine is a slice borrow, not a transpose. For steady-state
//! serving, [`ShardRunner`] owns a [`ScratchPool`] of per-worker scratches
//! and one persistent group-major output buffer that shard workers write
//! disjoint ranges of directly — no per-batch scratch, shard, or output
//! allocation. See `rust/DESIGN.md` §Serving.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::logic::check::CheckError;
use crate::logic::netlist::{LutNetlist, Sig};
use crate::logic::opt::OptStats;
use crate::util::bitvec::{mask_group_tail, PackedBatch};
use crate::util::sync::Mutex;
use crate::util::threadpool::ThreadPool;

/// Signal encoding: 0 = const0, 1 = const1, `2+i` = primary input `i`,
/// `2 + num_inputs + j` = LUT `j` (of the *optimized* netlist).
type Code = u32;

/// Largest lane-group block width the kernel is compiled for (`W` ≤ 8, so
/// one block is up to 512 samples per pass).
pub const MAX_BLOCK_WIDTH: usize = 8;

/// Instruction-set variant the block kernel dispatches into. Detected once
/// at [`CompiledNetlist::compile`] via `is_x86_feature_detected!` and baked
/// into the compiled program: the fold loop is re-monomorphized under
/// `#[target_feature]` so LLVM may emit 256-/512-bit vector code for the
/// `W`-word inner loop, with the plain scalar/SSE build retained as the
/// portable fallback. This is the interpreter's half of the fallback ladder
/// native codegen → SIMD interpreter → scalar (see `logic::codegen`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelIsa {
    /// Portable build — whatever the target baseline allows.
    Scalar,
    /// AVX2 monomorphization (x86-64 only).
    Avx2,
    /// AVX-512F monomorphization (x86-64 only).
    Avx512,
}

/// Pick the widest kernel the running CPU supports. Non-x86-64 targets
/// always get the portable build.
fn detect_isa() -> KernelIsa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return KernelIsa::Avx512;
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return KernelIsa::Avx2;
        }
    }
    KernelIsa::Scalar
}

/// One maximal run of equal-arity instructions in the schedule-ordered
/// stream: instructions `start .. start + count`, whose flattened input
/// codes begin at `input_start` (`arity` codes per instruction).
struct Run {
    arity: u32,
    start: u32,
    count: u32,
    input_start: u32,
}

/// Process-unique id source for compiled netlists: scratches are bound to
/// the id, not the netlist's address, so moving a `CompiledNetlist` (e.g.
/// into an `Arc`) after `make_scratch` stays valid, and a recycled
/// allocation can never masquerade as the scratch's owner.
static NEXT_SIM_ID: AtomicUsize = AtomicUsize::new(0);

/// A netlist compiled for fast repeated evaluation. Immutable after
/// [`CompiledNetlist::compile`]; evaluation state lives in [`SimScratch`].
pub struct CompiledNetlist {
    /// Process-unique identity (from `NEXT_SIM_ID`).
    id: usize,
    num_inputs: usize,
    /// LUT count after optimization (sizes the value array).
    num_luts: usize,
    /// Output codes + inversion flags.
    outputs: Vec<(Code, bool)>,
    /// Same-arity runs over the schedule-ordered stream below.
    runs: Vec<Run>,
    /// ≤ 64-bit packed truth table per instruction, schedule order.
    s_tables: Vec<u64>,
    /// Destination value index (`2 + num_inputs + j`) per instruction.
    s_dest: Vec<Code>,
    /// Flattened input codes, `arity` per instruction, schedule order.
    s_inputs: Vec<Code>,
    /// What the compile-time optimizer removed.
    opt: OptStats,
    /// Kernel variant selected at compile time (runtime CPU detection).
    isa: KernelIsa,
}

/// Per-worker evaluation state: `W` lane words per value slot
/// (`[const0, const1, inputs…, luts…]`, signal-major with stride `W`).
/// Create one per thread via [`CompiledNetlist::make_scratch`] and reuse it
/// across calls; it grows once to the widest block it has served and is
/// allocation-free afterwards. It is bound to exactly one compiled netlist.
pub struct SimScratch {
    /// Value slots (2 consts + inputs + LUTs) of the owning netlist.
    slots: usize,
    /// [`CompiledNetlist`] id this scratch was built for, so cross-netlist
    /// use fails loudly even when slot counts collide.
    owner: usize,
    vals: Vec<u64>,
}

/// Broadcast table bit `m` across all 64 lanes.
#[inline(always)]
fn lane_mask(table: u64, m: u32) -> u64 {
    0u64.wrapping_sub((table >> m) & 1)
}

/// Shannon fold of a packed table over `W`-word selector blocks
/// (`T = 2^k` table entries, `sel.len() = k`). The mux ladder's innermost
/// loop runs over the `W` lane words of the block — fixed trip count, no
/// cross-word dependence — which is the loop LLVM vectorizes.
#[inline(always)]
fn fold_block<const W: usize, const T: usize>(t: u64, sel: &[[u64; W]]) -> [u64; W] {
    debug_assert_eq!(T, 1usize << sel.len());
    let mut v = [[0u64; W]; T];
    for (m, vm) in v.iter_mut().enumerate() {
        let lm = lane_mask(t, m as u32);
        for x in vm.iter_mut() {
            *x = lm;
        }
    }
    let mut width = T;
    for s in sel.iter().rev() {
        width >>= 1;
        let (lo, hi) = v.split_at_mut(width);
        for (a, b) in lo.iter_mut().zip(hi.iter()) {
            for w in 0..W {
                a[w] = (!s[w] & a[w]) | (s[w] & b[w]);
            }
        }
    }
    v[0]
}

impl CompiledNetlist {
    /// Compile a netlist (all LUTs must have ≤ 6 inputs), running the
    /// compile-time optimizer first — constant folding, structural dedup,
    /// and dead-LUT removal ([`crate::logic::opt`]); the removal counts are
    /// available via [`CompiledNetlist::opt_stats`]. The compiled program
    /// is bit-exact against the input netlist's [`LutNetlist::eval`].
    pub fn compile(nl: &LutNetlist) -> CompiledNetlist {
        Self::build(nl, true)
    }

    /// Compile without the optimizer pass — the benchmark baseline the
    /// optimized kernel is measured against (`nullanet bench`).
    pub fn compile_unoptimized(nl: &LutNetlist) -> CompiledNetlist {
        Self::build(nl, false)
    }

    fn build(src: &LutNetlist, run_optimizer: bool) -> CompiledNetlist {
        assert!(src.max_arity() <= 6, "compiled simulator supports k ≤ 6");
        let optimized;
        let (nl, opt) = if run_optimizer {
            let (o, s) = crate::logic::opt::optimize(src);
            optimized = o;
            (&optimized, s)
        } else {
            (src, OptStats::unchanged(src.num_luts()))
        };
        let ni = nl.num_inputs;
        let code_of = |s: &Sig| -> Code { s.to_code(ni) };

        // Levelized schedule, stably sub-ordered by arity inside each
        // level: LUTs at one level never feed each other, so any
        // within-level permutation is still topological, and grouping by
        // arity lets equal-arity neighbors (often spanning several levels)
        // merge into one dispatch run.
        let levels = nl.levels();
        let mut order: Vec<u32> = (0..nl.luts.len() as u32).collect();
        order.sort_by_key(|&j| (levels[j as usize], nl.luts[j as usize].arity()));

        let mut runs: Vec<Run> = Vec::new();
        let mut s_tables = Vec::with_capacity(nl.luts.len());
        let mut s_dest = Vec::with_capacity(nl.luts.len());
        let mut s_inputs: Vec<Code> = Vec::new();
        for (pos, &j) in order.iter().enumerate() {
            let lut = &nl.luts[j as usize];
            let k = lut.arity() as u32;
            match runs.last_mut() {
                Some(r) if r.arity == k => r.count += 1,
                _ => runs.push(Run {
                    arity: k,
                    start: pos as u32,
                    count: 1,
                    input_start: s_inputs.len() as u32,
                }),
            }
            for s in &lut.inputs {
                s_inputs.push(code_of(s));
            }
            // Pack the table into a u64 (2^k bits, k ≤ 6).
            let mut t = 0u64;
            for m in 0..1u64 << lut.table.nvars() {
                if lut.table.eval(m) {
                    t |= 1 << m;
                }
            }
            s_tables.push(t);
            s_dest.push(2 + ni as u32 + j);
        }
        let outputs = nl.outputs.iter().map(|(s, inv)| (code_of(s), *inv)).collect();
        let compiled = CompiledNetlist {
            id: NEXT_SIM_ID.fetch_add(1, Ordering::Relaxed),
            num_inputs: ni,
            num_luts: nl.luts.len(),
            outputs,
            runs,
            s_tables,
            s_dest,
            s_inputs,
            opt,
            isa: detect_isa(),
        };
        // Debug builds gate every compile behind the structural lint: the
        // source netlist (which `pub` fields allow constructing without
        // `add_lut`'s ordering asserts) and the schedule just emitted.
        #[cfg(debug_assertions)]
        {
            crate::logic::check::lint_netlist(nl, 6)
                .and_then(|()| compiled.lint())
                .expect("CompiledNetlist::compile produced or received an unsound netlist");
        }
        compiled
    }

    /// Structural lint of the compiled instruction stream: runs must tile
    /// the stream contiguously with arity ≤ 6, every instruction may only
    /// read slots written earlier in the schedule (or constants/inputs),
    /// every destination slot is written exactly once (no scratch-slot
    /// aliasing), packed truth tables carry no bits beyond `2^arity`, and
    /// outputs read driven slots. Runs automatically in debug compiles and
    /// on demand from `nullanet check`.
    pub fn lint(&self) -> Result<(), CheckError> {
        let fail = |m: String| Err(CheckError::Schedule(m));
        let slots = self.slots();
        let ni = self.num_inputs;
        let total: usize = self.runs.iter().map(|r| r.count as usize).sum();
        if total != self.num_luts || self.s_dest.len() != total || self.s_tables.len() != total
        {
            return fail(format!(
                "runs cover {total} instructions but the stream has {} dests, {} tables, \
                 {} LUTs",
                self.s_dest.len(),
                self.s_tables.len(),
                self.num_luts
            ));
        }
        let mut pos = 0usize;
        let mut inp = 0usize;
        for (ri, r) in self.runs.iter().enumerate() {
            if r.arity > 6 {
                return fail(format!("run {ri} has arity {} (fabric is k ≤ 6)", r.arity));
            }
            if r.start as usize != pos || r.input_start as usize != inp {
                return fail(format!("run {ri} does not tile the stream contiguously"));
            }
            pos += r.count as usize;
            inp += (r.count * r.arity) as usize;
        }
        if inp != self.s_inputs.len() {
            return fail(format!(
                "runs consume {inp} input codes, stream has {}",
                self.s_inputs.len()
            ));
        }
        // Single-assignment schedule walk: consts and inputs are pre-driven.
        let mut written = vec![false; slots];
        for w in written.iter_mut().take(2 + ni) {
            *w = true;
        }
        let mut inp = 0usize;
        for r in &self.runs {
            for i in r.start as usize..(r.start + r.count) as usize {
                for _ in 0..r.arity {
                    let c = self.s_inputs[inp] as usize;
                    inp += 1;
                    if c >= slots {
                        return fail(format!("instruction {i} reads out-of-range slot {c}"));
                    }
                    if !written[c] {
                        return fail(format!(
                            "instruction {i} reads slot {c} before the schedule writes it"
                        ));
                    }
                }
                let d = self.s_dest[i] as usize;
                if d < 2 + ni || d >= slots {
                    return fail(format!("instruction {i} writes non-LUT slot {d}"));
                }
                if written[d] {
                    return fail(format!(
                        "instruction {i} rewrites slot {d} (scratch-slot aliasing)"
                    ));
                }
                written[d] = true;
                if r.arity < 6 && self.s_tables[i] >> (1u32 << r.arity) != 0 {
                    return fail(format!(
                        "instruction {i} truth table has bits beyond 2^{}",
                        r.arity
                    ));
                }
            }
        }
        for (oi, &(code, _)) in self.outputs.iter().enumerate() {
            let c = code as usize;
            if c >= slots || !written[c] {
                return fail(format!("output {oi} reads undriven slot {c}"));
            }
        }
        Ok(())
    }

    /// Number of primary inputs.
    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    /// Number of outputs.
    pub fn num_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// LUTs evaluated per word pass (after optimization).
    pub fn num_luts(&self) -> usize {
        self.num_luts
    }

    /// What the compile-time optimizer removed (`luts_before` is the raw
    /// netlist, `luts_after` what every word pass now evaluates).
    pub fn opt_stats(&self) -> &OptStats {
        &self.opt
    }

    /// Kernel variant the runtime CPU detection selected at compile time.
    pub fn kernel_isa(&self) -> KernelIsa {
        self.isa
    }

    /// Test hook: downgrade to the portable kernel so the detected SIMD
    /// monomorphization can be differential-tested against it.
    #[cfg(test)]
    fn with_isa(mut self, isa: KernelIsa) -> Self {
        self.isa = isa;
        self
    }

    /// Crate-internal view of the compiled instruction stream for the
    /// native code generator (`logic::codegen`): one
    /// `(arity, packed table, dest code, input codes)` tuple per
    /// instruction, in schedule order. Codes use the signal encoding at the
    /// top of this file (0/1 consts, `2+i` inputs, `2+num_inputs+j` LUTs).
    pub(crate) fn instructions(&self) -> Vec<(u32, u64, u32, &[u32])> {
        let mut v = Vec::with_capacity(self.num_luts);
        for r in &self.runs {
            let k = r.arity as usize;
            for off in 0..r.count as usize {
                let i = r.start as usize + off;
                let inp = r.input_start as usize + off * k;
                v.push((r.arity, self.s_tables[i], self.s_dest[i], &self.s_inputs[inp..inp + k]));
            }
        }
        v
    }

    /// Crate-internal view of the output list (code, inverted) for the
    /// native code generator.
    pub(crate) fn output_codes(&self) -> &[(u32, bool)] {
        &self.outputs
    }

    /// Value slots per lane word: 2 consts + inputs + (optimized) LUTs.
    fn slots(&self) -> usize {
        2 + self.num_inputs + self.num_luts
    }

    /// Allocate evaluation state for this netlist (one per worker thread).
    pub fn make_scratch(&self) -> SimScratch {
        SimScratch { slots: self.slots(), owner: self.id, vals: Vec::new() }
    }

    /// Pool of reusable scratches for shard workers (see [`ScratchPool`]).
    pub fn make_scratch_pool(&self) -> ScratchPool {
        ScratchPool {
            slots: self.slots(),
            owner: self.id,
            free: Mutex::named("sim.scratch_pool", Vec::new()),
            created: AtomicUsize::new(0),
        }
    }

    /// Check the scratch belongs to this netlist and hand back its value
    /// array sized for block width `width` (growing it at most once per
    /// width increase — steady state is allocation-free).
    fn checked_vals<'a>(&self, scratch: &'a mut SimScratch, width: usize) -> &'a mut [u64] {
        assert_eq!(
            scratch.slots,
            self.slots(),
            "scratch was built for a different netlist"
        );
        assert_eq!(scratch.owner, self.id, "scratch was built for a different netlist");
        let need = self.slots() * width;
        if scratch.vals.len() < need {
            scratch.vals.resize(need, 0);
        }
        &mut scratch.vals[..need]
    }

    /// The straight-line block kernel: consts + inputs are already loaded
    /// into `vals` (signal-major, stride `W`); evaluates every run, one
    /// arity dispatch per run. `inline(always)` so the `target_feature`
    /// wrappers below re-monomorphize the whole fold under AVX2/AVX-512.
    #[inline(always)]
    fn exec<const W: usize>(&self, vals: &mut [u64]) {
        for x in vals[..W].iter_mut() {
            *x = 0;
        }
        for x in vals[W..2 * W].iter_mut() {
            *x = !0u64;
        }
        for run in &self.runs {
            let start = run.start as usize;
            let count = run.count as usize;
            let inp = run.input_start as usize;
            match run.arity {
                0 => self.exec_run::<W, 0, 1>(vals, start, count, inp),
                1 => self.exec_run::<W, 1, 2>(vals, start, count, inp),
                2 => self.exec_run::<W, 2, 4>(vals, start, count, inp),
                3 => self.exec_run::<W, 3, 8>(vals, start, count, inp),
                4 => self.exec_run::<W, 4, 16>(vals, start, count, inp),
                5 => self.exec_run::<W, 5, 32>(vals, start, count, inp),
                _ => self.exec_run::<W, 6, 64>(vals, start, count, inp),
            }
        }
    }

    /// One same-arity run (`K` inputs, `T = 2^K` table entries): gather the
    /// K selector blocks, fold, store — no per-LUT dispatch.
    #[inline(always)]
    fn exec_run<const W: usize, const K: usize, const T: usize>(
        &self,
        vals: &mut [u64],
        start: usize,
        count: usize,
        mut inp: usize,
    ) {
        for i in start..start + count {
            let mut sel = [[0u64; W]; K];
            for s in sel.iter_mut() {
                let code = self.s_inputs[inp] as usize * W;
                s.copy_from_slice(&vals[code..code + W]);
                inp += 1;
            }
            let out = fold_block::<W, T>(self.s_tables[i], &sel);
            let dest = self.s_dest[i] as usize * W;
            vals[dest..dest + W].copy_from_slice(&out);
        }
    }

    /// Evaluate one `W`-group block of a packed batch (groups `g0 .. g0+W`),
    /// writing output words group-major into `out` (`W * num_outputs()`).
    /// Dispatches once into the kernel monomorphization selected at compile
    /// time (see [`KernelIsa`]); every variant runs the same portable body.
    fn run_block<const W: usize>(
        &self,
        batch: &PackedBatch,
        g0: usize,
        scratch: &mut SimScratch,
        out: &mut [u64],
    ) {
        match self.isa {
            KernelIsa::Scalar => self.run_block_body::<W>(batch, g0, scratch, out),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `self.isa` is only ever set to `Avx2` by `detect_isa`
            // after `is_x86_feature_detected!("avx2")` returned true on this
            // very CPU, so the target-feature contract holds.
            KernelIsa::Avx2 => unsafe { self.run_block_avx2::<W>(batch, g0, scratch, out) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: as above — `Avx512` is only selected when
            // `is_x86_feature_detected!("avx512f")` returned true.
            KernelIsa::Avx512 => unsafe { self.run_block_avx512::<W>(batch, g0, scratch, out) },
            #[cfg(not(target_arch = "x86_64"))]
            _ => self.run_block_body::<W>(batch, g0, scratch, out),
        }
    }

    /// AVX2 monomorphization of the block kernel: same body, recompiled
    /// with 256-bit vectors available to the fold's inner `W`-word loop.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn run_block_avx2<const W: usize>(
        &self,
        batch: &PackedBatch,
        g0: usize,
        scratch: &mut SimScratch,
        out: &mut [u64],
    ) {
        self.run_block_body::<W>(batch, g0, scratch, out)
    }

    /// AVX-512F monomorphization of the block kernel.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx512f")]
    unsafe fn run_block_avx512<const W: usize>(
        &self,
        batch: &PackedBatch,
        g0: usize,
        scratch: &mut SimScratch,
        out: &mut [u64],
    ) {
        self.run_block_body::<W>(batch, g0, scratch, out)
    }

    /// Portable body of the block kernel (ISA-agnostic; inlined into each
    /// `target_feature` wrapper above so the fold re-vectorizes).
    #[inline(always)]
    fn run_block_body<const W: usize>(
        &self,
        batch: &PackedBatch,
        g0: usize,
        scratch: &mut SimScratch,
        out: &mut [u64],
    ) {
        let ni = self.num_inputs;
        let vals = self.checked_vals(scratch, W);
        let words = batch.words();
        for i in 0..ni {
            for w in 0..W {
                vals[(2 + i) * W + w] = words[(g0 + w) * ni + i];
            }
        }
        self.exec::<W>(vals);
        let no = self.outputs.len();
        for w in 0..W {
            for (j, (code, inv)) in self.outputs.iter().enumerate() {
                out[w * no + j] =
                    vals[*code as usize * W + w] ^ if *inv { !0u64 } else { 0 };
            }
        }
    }

    /// Evaluate 64 samples at once. `inputs[i]` = word of input `i`;
    /// `out[j]` receives the word of output `j`.
    ///
    /// Widths are checked with real assertions (not `debug_assert!`): a
    /// wrong-width request must fail loudly in release builds too, never
    /// silently read garbage.
    pub fn run_words(&self, scratch: &mut SimScratch, inputs: &[u64], out: &mut [u64]) {
        assert_eq!(
            inputs.len(),
            self.num_inputs,
            "run_words: {} input words for a {}-input netlist",
            inputs.len(),
            self.num_inputs
        );
        assert_eq!(
            out.len(),
            self.outputs.len(),
            "run_words: {} output words for a {}-output netlist",
            out.len(),
            self.outputs.len()
        );
        let ni = self.num_inputs;
        let vals = self.checked_vals(scratch, 1);
        vals[2..2 + ni].copy_from_slice(inputs);
        self.exec::<1>(vals);
        for (o, (code, inv)) in out.iter_mut().zip(&self.outputs) {
            *o = vals[*code as usize] ^ if *inv { !0u64 } else { 0 };
        }
    }

    /// Evaluate lane groups `g0..g1` of a packed batch, writing output words
    /// group-major into `out` (`(g1 - g0) * num_outputs()` words), stepping
    /// through the widest block the remaining range supports (8 → 4 → 2 →
    /// 1). This is the shard body of the sharded serving path.
    pub fn run_groups(
        &self,
        batch: &PackedBatch,
        g0: usize,
        g1: usize,
        scratch: &mut SimScratch,
        out: &mut [u64],
    ) {
        self.run_groups_capped(batch, g0, g1, scratch, out, MAX_BLOCK_WIDTH)
    }

    /// [`CompiledNetlist::run_groups`] with the block width capped at
    /// `max_width` ∈ {1, 2, 4, 8} — the per-width benchmark entry point
    /// (`nullanet bench` sweeps it); serving always uses the full cap.
    pub fn run_groups_capped(
        &self,
        batch: &PackedBatch,
        g0: usize,
        g1: usize,
        scratch: &mut SimScratch,
        out: &mut [u64],
        max_width: usize,
    ) {
        assert_eq!(
            batch.num_signals(),
            self.num_inputs,
            "run_groups: batch packs {} signals for a {}-input netlist",
            batch.num_signals(),
            self.num_inputs
        );
        assert!(g0 <= g1 && g1 <= batch.num_groups(), "run_groups: bad group range");
        assert!(
            matches!(max_width, 1 | 2 | 4 | 8),
            "run_groups: block width must be 1, 2, 4, or 8"
        );
        let no = self.outputs.len();
        assert_eq!(out.len(), (g1 - g0) * no, "run_groups: output slice width");
        let mut g = g0;
        while g < g1 {
            let rem = g1 - g;
            let off = (g - g0) * no;
            if rem >= 8 && max_width >= 8 {
                self.run_block::<8>(batch, g, scratch, &mut out[off..off + 8 * no]);
                g += 8;
            } else if rem >= 4 && max_width >= 4 {
                self.run_block::<4>(batch, g, scratch, &mut out[off..off + 4 * no]);
                g += 4;
            } else if rem >= 2 && max_width >= 2 {
                self.run_block::<2>(batch, g, scratch, &mut out[off..off + 2 * no]);
                g += 2;
            } else {
                self.run_block::<1>(batch, g, scratch, &mut out[off..off + no]);
                g += 1;
            }
        }
    }

    /// Evaluate a whole packed batch on the calling thread; returns the
    /// packed output batch (tail lanes masked). Allocates the output —
    /// steady-state callers use [`CompiledNetlist::run_packed_into`].
    pub fn run_packed(&self, batch: &PackedBatch, scratch: &mut SimScratch) -> PackedBatch {
        let groups = batch.num_groups();
        let no = self.outputs.len();
        let mut words = vec![0u64; groups * no];
        self.run_groups(batch, 0, groups, scratch, &mut words);
        // `from_group_major_words` masks the tail lanes.
        PackedBatch::from_group_major_words(no, batch.num_samples(), words)
    }

    /// Evaluate a whole packed batch into a reusable group-major word
    /// buffer (`num_groups() * num_outputs()` words, tail lanes masked).
    /// `out`'s capacity is reused: after the first batch of a given size,
    /// no allocation happens here.
    pub fn run_packed_into(
        &self,
        batch: &PackedBatch,
        scratch: &mut SimScratch,
        out: &mut Vec<u64>,
    ) {
        let groups = batch.num_groups();
        let no = self.outputs.len();
        out.clear();
        out.resize(groups * no, 0);
        self.run_groups(batch, 0, groups, scratch, &mut out[..]);
        mask_group_tail(out, no, batch.num_samples());
    }

    /// Evaluate a packed batch with its lane groups sharded across a worker
    /// pool, every worker sharing one `Arc<CompiledNetlist>`. Convenience
    /// wrapper that allocates a fresh [`ShardRunner`] (and therefore fresh
    /// buffers) per call — the steady-state serving path keeps one
    /// `ShardRunner` alive instead. Associated function (`&Arc<Self>` is
    /// not a valid method receiver on stable Rust):
    /// `CompiledNetlist::run_packed_sharded(&sim, &pool, &batch)`.
    pub fn run_packed_sharded(
        this: &Arc<Self>,
        pool: &ThreadPool,
        batch: &Arc<PackedBatch>,
    ) -> PackedBatch {
        let mut runner = ShardRunner::new(this);
        let words = runner.run(this, pool, batch).to_vec();
        PackedBatch::from_group_major_words(this.outputs.len(), batch.num_samples(), words)
    }

    /// Evaluate a batch of arbitrary size: `samples[s][i]` = input `i` of
    /// sample `s`; returns `result[s][j]` = output `j` of sample `s`.
    ///
    /// Legacy per-sample-container path, kept for offline evaluation and as
    /// the baseline the packed path is benchmarked against. The transpose
    /// packs each sample's bools into words and pushes them word-level
    /// ([`PackedBatch::push_sample_words`]); evaluation then runs the block
    /// kernel.
    pub fn run_batch(&self, samples: &[Vec<bool>]) -> Vec<Vec<bool>> {
        let n = samples.len();
        let ni = self.num_inputs;
        let no = self.outputs.len();
        let mut packed = PackedBatch::with_capacity(ni, n);
        let mut wordbuf = vec![0u64; ni.div_ceil(64)];
        for (s_idx, s) in samples.iter().enumerate() {
            assert_eq!(
                s.len(),
                ni,
                "run_batch: sample {} has {} bits for a {}-input netlist",
                s_idx,
                s.len(),
                ni
            );
            for w in wordbuf.iter_mut() {
                *w = 0;
            }
            for (i, &b) in s.iter().enumerate() {
                if b {
                    wordbuf[i >> 6] |= 1 << (i & 63);
                }
            }
            packed.push_sample_words(&wordbuf);
        }
        let mut scratch = self.make_scratch();
        let out = self.run_packed(&packed, &mut scratch);
        (0..n)
            .map(|s| (0..no).map(|j| out.get(s, j)).collect())
            .collect()
    }
}

/// A pool of reusable [`SimScratch`]es keyed to one compiled netlist.
/// Shard workers take a scratch per shard and return it afterwards, so the
/// number of scratches ever allocated equals the peak shard concurrency —
/// not the batch count. [`ScratchPool::created`] exposes the allocation
/// count as the zero-allocation test hook.
pub struct ScratchPool {
    slots: usize,
    owner: usize,
    free: Mutex<Vec<SimScratch>>,
    created: AtomicUsize,
}

impl ScratchPool {
    fn take(&self) -> SimScratch {
        if let Some(s) = self.free.lock().pop() {
            return s;
        }
        self.created.fetch_add(1, Ordering::Relaxed);
        SimScratch { slots: self.slots, owner: self.owner, vals: Vec::new() }
    }

    fn put(&self, s: SimScratch) {
        self.free.lock().push(s);
    }

    /// Scratches ever created (stable once every worker has one).
    pub fn created(&self) -> usize {
        self.created.load(Ordering::Relaxed)
    }
}

/// Raw base pointer of the shared output buffer, smuggled into shard jobs.
/// Safety rests on the shard ranges being disjoint and `par_map` acting as
/// a barrier (see [`ShardRunner::run`]).
#[derive(Clone, Copy)]
struct SendPtr(*mut u64);
// SAFETY: the pointer is only dereferenced inside `ShardRunner::run`'s shard
// jobs, each of which carves out a word range disjoint from every other
// shard's (asserted there before spawning), and the pointee buffer outlives
// the jobs because `par_map` blocks until all of them finish while `self`
// keeps the buffer borrowed. Sending the raw pointer across threads is
// therefore no more than sending the (unique) range each job writes.
unsafe impl Send for SendPtr {}
// SAFETY: shard jobs never write overlapping ranges (see above), so shared
// references to the wrapper across threads cannot race.
unsafe impl Sync for SendPtr {}

/// Persistent state for the sharded serving path: a [`ScratchPool`] of
/// per-worker scratches plus one group-major output buffer that shard
/// workers write disjoint ranges of **directly** — no per-shard `Vec`s, no
/// concatenation after the barrier, and (past the first batch of a given
/// size) no allocation at all. One `ShardRunner` lives inside each
/// [`crate::coordinator::engine::PackedLogicEngine`].
pub struct ShardRunner {
    scratches: Arc<ScratchPool>,
    out: Vec<u64>,
    grows: usize,
}

impl ShardRunner {
    /// Runner bound to `sim` (scratches and buffers are sized for it).
    pub fn new(sim: &CompiledNetlist) -> ShardRunner {
        ShardRunner {
            scratches: Arc::new(sim.make_scratch_pool()),
            out: Vec::new(),
            grows: 0,
        }
    }

    /// Evaluate `batch`, sharding its lane groups across `pool`; returns
    /// the group-major output words (`num_groups() * num_outputs()`, tail
    /// lanes masked). Falls back to an inline single-scratch pass when the
    /// batch has one group (or the pool one worker).
    pub fn run(
        &mut self,
        sim: &Arc<CompiledNetlist>,
        pool: &ThreadPool,
        batch: &Arc<PackedBatch>,
    ) -> &[u64] {
        let groups = batch.num_groups();
        let no = sim.num_outputs();
        let need = groups * no;
        if self.out.capacity() < need {
            self.grows += 1;
        }
        self.out.clear();
        self.out.resize(need, 0);
        let shards = pool.size().min(groups);
        if shards <= 1 {
            let mut scratch = self.scratches.take();
            sim.run_groups(batch, 0, groups, &mut scratch, &mut self.out[..]);
            self.scratches.put(scratch);
        } else {
            let per = groups.div_ceil(shards);
            let ranges: Vec<(usize, usize)> = (0..shards)
                .map(|i| (i * per, ((i + 1) * per).min(groups)))
                .filter(|&(a, b)| a < b)
                .collect();
            // The disjointness invariant the raw-pointer writes below rely
            // on: shard ranges must tile `[0, groups)` contiguously with no
            // overlap and no gap.
            debug_assert!(!ranges.is_empty() && ranges[0].0 == 0);
            debug_assert_eq!(ranges.last().unwrap().1, groups);
            debug_assert!(
                ranges.windows(2).all(|w| w[0].1 == w[1].0),
                "shard ranges must be non-overlapping and contiguous: {ranges:?}"
            );
            let base = SendPtr(self.out.as_mut_ptr());
            let sim2 = Arc::clone(sim);
            let shared = Arc::clone(batch);
            let scratches = Arc::clone(&self.scratches);
            let _: Vec<()> = pool.par_map(ranges, move |(g0, g1)| {
                // SAFETY: this shard writes only the word range
                // `[g0*no, g1*no)` of the buffer behind `base`; the ranges
                // partition `[0, groups*no)` (asserted above), so no two
                // shards alias. `par_map` does not return until every job
                // has finished (its remaining-counter barrier), and `self`
                // is mutably borrowed for this whole call, so the buffer is
                // neither read, resized, moved, nor dropped while any shard
                // holds the pointer.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(base.0.add(g0 * no), (g1 - g0) * no)
                };
                let mut scratch = scratches.take();
                sim2.run_groups(&shared, g0, g1, &mut scratch, dst);
                scratches.put(scratch);
            });
        }
        mask_group_tail(&mut self.out, no, batch.num_samples());
        &self.out
    }

    /// Zero-allocation test hook: (scratches ever created across shard
    /// workers, output-buffer capacity growths). Both stabilize after the
    /// first batches of the steady-state size.
    pub fn alloc_stats(&self) -> (usize, usize) {
        (self.scratches.created(), self.grows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::truthtable::TruthTable;
    use crate::util::prng::Xoshiro256;

    fn random_netlist(seed: u64, num_inputs: usize, num_luts: usize) -> LutNetlist {
        let mut rng = Xoshiro256::new(seed);
        let mut nl = LutNetlist::new(num_inputs);
        for j in 0..num_luts {
            let max_sig = num_inputs + j;
            let k = 1 + rng.below(5.min(max_sig as u64)) as usize;
            let mut inputs = Vec::with_capacity(k);
            for _ in 0..k {
                let pick = rng.below(max_sig as u64) as usize;
                inputs.push(if pick < num_inputs {
                    Sig::Input(pick as u32)
                } else {
                    Sig::Lut((pick - num_inputs) as u32)
                });
            }
            let tt = TruthTable::from_fn(k, |_| rng.bernoulli(0.5));
            nl.add_lut(inputs, tt);
        }
        // outputs: last few luts with random inversion
        for j in num_luts.saturating_sub(4)..num_luts {
            nl.add_output(Sig::Lut(j as u32), rng.bernoulli(0.5));
        }
        nl.add_output(Sig::Const(true), false);
        nl.add_output(Sig::Input(0), true);
        nl
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn compiled_matches_reference_simulation() {
        for seed in 0..10u64 {
            let nl = random_netlist(seed, 8, 20);
            let c = CompiledNetlist::compile(&nl);
            let mut scratch = c.make_scratch();
            let mut rng = Xoshiro256::new(seed ^ 0xF00);
            let inputs: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
            let want = nl.simulate_words(&inputs);
            let mut got = vec![0u64; want.len()];
            c.run_words(&mut scratch, &inputs, &mut got);
            assert_eq!(got, want, "seed={seed}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn unoptimized_compile_matches_optimized() {
        for seed in 0..10u64 {
            let nl = random_netlist(seed ^ 0xAB, 7, 24);
            let opt = CompiledNetlist::compile(&nl);
            let raw = CompiledNetlist::compile_unoptimized(&nl);
            assert!(opt.num_luts() <= raw.num_luts(), "seed={seed}");
            assert_eq!(raw.opt_stats().removed(), 0);
            let mut so = opt.make_scratch();
            let mut sr = raw.make_scratch();
            let mut rng = Xoshiro256::new(seed);
            let inputs: Vec<u64> = (0..7).map(|_| rng.next_u64()).collect();
            let mut go = vec![0u64; opt.num_outputs()];
            let mut gr = vec![0u64; raw.num_outputs()];
            opt.run_words(&mut so, &inputs, &mut go);
            raw.run_words(&mut sr, &inputs, &mut gr);
            assert_eq!(go, gr, "seed={seed}");
        }
    }

    #[test]
    fn optimizer_stats_partition_on_handcrafted_duplicates() {
        // Two identical ANDs + a dead XOR: one dedup, one dead removal.
        let and_tt = TruthTable::from_fn(2, |m| m == 3);
        let xor_tt = TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 1);
        let mut nl = LutNetlist::new(2);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], and_tt.clone());
        let b = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], and_tt);
        let _dead = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor_tt);
        nl.add_output(a, false);
        nl.add_output(b, true);
        let c = CompiledNetlist::compile(&nl);
        let s = c.opt_stats();
        assert_eq!(s.luts_before, 3);
        assert_eq!(s.luts_after, 1);
        assert_eq!(s.deduped, 1);
        assert_eq!(s.dead_removed, 1);
        assert_eq!(c.num_luts(), 1);
        // Function preserved: out0 = AND, out1 = !AND.
        let mut scratch = c.make_scratch();
        let mut out = vec![0u64; 2];
        c.run_words(&mut scratch, &[0b1010, 0b1100], &mut out);
        assert_eq!(out[0] & 0xF, 0b1000);
        assert_eq!(out[1] & 0xF, 0b0111);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn run_batch_roundtrip() {
        let nl = random_netlist(77, 6, 15);
        let c = CompiledNetlist::compile(&nl);
        let mut rng = Xoshiro256::new(123);
        // deliberately non-multiple-of-64 batch
        let samples: Vec<Vec<bool>> = (0..150)
            .map(|_| (0..6).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let results = c.run_batch(&samples);
        for (s, r) in samples.iter().zip(&results) {
            let bits: u64 = s
                .iter()
                .enumerate()
                .map(|(i, &b)| if b { 1u64 << i } else { 0 })
                .sum();
            assert_eq!(*r, nl.eval(bits));
        }
    }

    #[test]
    fn zero_input_luts() {
        let mut nl = LutNetlist::new(1);
        let t = TruthTable::from_fn(0, |_| true); // constant-1 LUT
        let a = nl.add_lut(vec![], t);
        nl.add_output(a, false);
        nl.add_output(a, true);
        for c in [CompiledNetlist::compile(&nl), CompiledNetlist::compile_unoptimized(&nl)] {
            let mut scratch = c.make_scratch();
            let mut out = vec![0u64; 2];
            c.run_words(&mut scratch, &[0u64], &mut out);
            assert_eq!(out[0], !0u64);
            assert_eq!(out[1], 0u64);
        }
    }

    #[test]
    fn six_input_lut_exact() {
        let mut rng = Xoshiro256::new(0x6);
        let tt = TruthTable::from_fn(6, |_| rng.bernoulli(0.5));
        let mut nl = LutNetlist::new(6);
        let sig = nl.add_lut((0..6).map(Sig::Input).collect(), tt.clone());
        nl.add_output(sig, false);
        let c = CompiledNetlist::compile(&nl);
        let mut scratch = c.make_scratch();
        // exhaustive over all 64 assignments, packed in one word per input
        let inputs: Vec<u64> = (0..6)
            .map(|i| {
                let mut w = 0u64;
                for m in 0..64u64 {
                    if (m >> i) & 1 == 1 {
                        w |= 1 << m;
                    }
                }
                w
            })
            .collect();
        let mut out = vec![0u64];
        c.run_words(&mut scratch, &inputs, &mut out);
        for m in 0..64u64 {
            assert_eq!((out[0] >> m) & 1 == 1, tt.eval(m), "m={m}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn run_packed_matches_run_batch() {
        let nl = random_netlist(5, 7, 18);
        let c = CompiledNetlist::compile(&nl);
        let mut rng = Xoshiro256::new(9);
        // non-multiple-of-64 so the tail group is partial
        let samples: Vec<Vec<bool>> = (0..201)
            .map(|_| (0..7).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let mut packed = PackedBatch::with_capacity(7, samples.len());
        for s in &samples {
            packed.push_sample_bools(s);
        }
        let mut scratch = c.make_scratch();
        let out = c.run_packed(&packed, &mut scratch);
        let want = c.run_batch(&samples);
        assert_eq!(out.num_samples(), samples.len());
        for (s, w) in want.iter().enumerate() {
            for (j, &b) in w.iter().enumerate() {
                assert_eq!(out.get(s, j), b, "sample {s} output {j}");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn every_block_width_matches_reference_eval() {
        // 520 samples = 9 lane groups: exercises the 8-, 4-, 2-, and
        // 1-group block paths in one run for every width cap.
        let nl = random_netlist(31, 9, 26);
        let c = CompiledNetlist::compile(&nl);
        let mut rng = Xoshiro256::new(77);
        let samples: Vec<u64> = (0..520).map(|_| rng.next_u64() & 0x1FF).collect();
        let mut packed = PackedBatch::with_capacity(9, samples.len());
        for &bits in &samples {
            packed.push_sample_word(bits);
        }
        let groups = packed.num_groups();
        let no = c.num_outputs();
        let mut scratch = c.make_scratch();
        for cap in [1usize, 2, 4, 8] {
            let mut out = vec![0u64; groups * no];
            c.run_groups_capped(&packed, 0, groups, &mut scratch, &mut out, cap);
            for (s, &bits) in samples.iter().enumerate() {
                let want = nl.eval(bits);
                for (j, &w) in want.iter().enumerate() {
                    let got = (out[(s >> 6) * no + j] >> (s & 63)) & 1 == 1;
                    assert_eq!(got, w, "cap={cap} sample={s} output={j}");
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn run_packed_into_reuses_the_buffer() {
        let nl = random_netlist(13, 6, 20);
        let c = CompiledNetlist::compile(&nl);
        let mut rng = Xoshiro256::new(5);
        let mut packed = PackedBatch::with_capacity(6, 200);
        for _ in 0..200 {
            packed.push_sample_word(rng.next_u64() & 0x3F);
        }
        let mut scratch = c.make_scratch();
        let mut out = Vec::new();
        c.run_packed_into(&packed, &mut scratch, &mut out);
        let cap = out.capacity();
        let first = out.clone();
        for _ in 0..5 {
            c.run_packed_into(&packed, &mut scratch, &mut out);
        }
        assert_eq!(out, first, "same batch ⇒ same words");
        assert_eq!(out.capacity(), cap, "steady state must not reallocate");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn sharded_matches_inline_across_worker_counts() {
        let nl = random_netlist(11, 6, 22);
        let c = Arc::new(CompiledNetlist::compile(&nl));
        let mut rng = Xoshiro256::new(21);
        let samples: Vec<Vec<bool>> = (0..300)
            .map(|_| (0..6).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let mut packed = PackedBatch::with_capacity(6, samples.len());
        for s in &samples {
            packed.push_sample_bools(s);
        }
        let batch = Arc::new(packed);
        let mut scratch = c.make_scratch();
        let inline = c.run_packed(&batch, &mut scratch);
        for workers in [1usize, 2, 4] {
            let pool = ThreadPool::new(workers);
            let sharded = CompiledNetlist::run_packed_sharded(&c, &pool, &batch);
            assert_eq!(sharded, inline, "workers={workers}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn shard_runner_is_allocation_stable_across_batches() {
        let nl = random_netlist(17, 8, 30);
        let c = Arc::new(CompiledNetlist::compile(&nl));
        let pool = ThreadPool::new(4);
        let mut rng = Xoshiro256::new(3);
        let mut packed = PackedBatch::with_capacity(8, 640);
        for _ in 0..640 {
            packed.push_sample_word(rng.next_u64() & 0xFF);
        }
        let batch = Arc::new(packed);
        let mut runner = ShardRunner::new(&c);
        let first = runner.run(&c, &pool, &batch).to_vec();
        let warm_grows = runner.alloc_stats().1;
        for _ in 0..6 {
            let words = runner.run(&c, &pool, &batch);
            assert_eq!(words, &first[..], "sharded output must be deterministic");
        }
        // A smaller batch must also reuse the (larger) buffers.
        let mut small = PackedBatch::with_capacity(8, 100);
        for _ in 0..100 {
            small.push_sample_word(rng.next_u64() & 0xFF);
        }
        let small = Arc::new(small);
        let _ = runner.run(&c, &pool, &small);
        let (created, grows) = runner.alloc_stats();
        assert_eq!(grows, warm_grows, "steady state must not grow the output buffer");
        // Scratch allocations are bounded by peak shard concurrency (4
        // here), never by the batch count (8 runs).
        assert!(created <= 4, "created {created} scratches for 4 shards");
    }

    #[test]
    #[should_panic(expected = "run_batch: sample 0 has 3 bits")]
    fn wrong_width_sample_is_a_real_error() {
        let nl = random_netlist(3, 6, 10);
        let c = CompiledNetlist::compile(&nl);
        let _ = c.run_batch(&[vec![false; 3]]);
    }

    #[test]
    #[should_panic(expected = "scratch was built for a different netlist")]
    fn mismatched_scratch_is_a_real_error() {
        let a = CompiledNetlist::compile(&random_netlist(1, 6, 10));
        let b = CompiledNetlist::compile(&random_netlist(2, 6, 12));
        let mut scratch = b.make_scratch();
        let mut out = vec![0u64; a.num_outputs()];
        a.run_words(&mut scratch, &[0u64; 6], &mut out);
    }

    #[test]
    #[should_panic(expected = "scratch was built for a different netlist")]
    fn scratch_of_a_dropped_netlist_is_rejected_after_realloc() {
        // Same seed ⇒ identical shape ⇒ identical slot count, and dropping
        // `a` first invites the allocator to recycle its address for `b`.
        // Only the monotonic compile-generation id distinguishes them — an
        // address-keyed owner check would accept the stale scratch here.
        let a = CompiledNetlist::compile(&random_netlist(1, 6, 10));
        let mut scratch = a.make_scratch();
        drop(a);
        let b = CompiledNetlist::compile(&random_netlist(1, 6, 10));
        let mut out = vec![0u64; b.num_outputs()];
        b.run_words(&mut scratch, &[0u64; 6], &mut out);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // large batches; the small shard smoke covers Miri
    fn detected_simd_kernel_matches_the_portable_kernel() {
        // Differential check of the `target_feature` monomorphizations: on
        // a machine without AVX this degenerates to scalar-vs-scalar, which
        // is fine — CI x86-64 runners exercise the AVX2 path.
        let nl = random_netlist(29, 8, 24);
        let detected = CompiledNetlist::compile(&nl);
        let portable = CompiledNetlist::compile(&nl).with_isa(KernelIsa::Scalar);
        let mut rng = Xoshiro256::new(101);
        let mut packed = PackedBatch::with_capacity(8, 600);
        for _ in 0..600 {
            packed.push_sample_word(rng.next_u64() & 0xFF);
        }
        let mut sd = detected.make_scratch();
        let mut sp = portable.make_scratch();
        let got = detected.run_packed(&packed, &mut sd);
        let want = portable.run_packed(&packed, &mut sp);
        assert_eq!(got, want, "isa={:?}", detected.kernel_isa());
    }

    #[test]
    fn sharded_smoke_exercises_raw_pointer_path() {
        // Small enough to run under Miri, which is what sanitizer-checks
        // the SendPtr disjoint-write invariant on every CI run.
        let nl = random_netlist(41, 5, 8);
        let c = Arc::new(CompiledNetlist::compile(&nl));
        let mut rng = Xoshiro256::new(19);
        let mut packed = PackedBatch::with_capacity(5, 130);
        let samples: Vec<u64> = (0..130).map(|_| rng.next_u64() & 0x1F).collect();
        for &bits in &samples {
            packed.push_sample_word(bits);
        }
        let batch = Arc::new(packed);
        let pool = ThreadPool::new(2);
        let mut runner = ShardRunner::new(&c);
        let out = runner.run(&c, &pool, &batch);
        let no = c.num_outputs();
        for (s, &bits) in samples.iter().enumerate() {
            let want = nl.eval(bits);
            for (j, &w) in want.iter().enumerate() {
                let got = (out[(s >> 6) * no + j] >> (s & 63)) & 1 == 1;
                assert_eq!(got, w, "sample={s} output={j}");
            }
        }
    }

    #[test]
    fn compiled_stream_passes_its_own_lint() {
        for seed in [1u64, 9, 23] {
            let c = CompiledNetlist::compile(&random_netlist(seed, 7, 18));
            assert_eq!(c.lint(), Ok(()));
        }
    }

    #[test]
    fn lint_catches_a_tampered_schedule() {
        let nl = random_netlist(6, 6, 12);
        // Skip the optimizer so the stream shape is exactly the 12
        // constructed LUTs (all arity 1..=5): the tampers below need at
        // least two instructions, a sub-6 run, and a non-empty input list.
        let mut c = CompiledNetlist::compile_unoptimized(&nl);

        // Read-before-write: point an input code at the last dest slot.
        let last_dest = *c.s_dest.last().unwrap();
        let orig = c.s_inputs[0];
        c.s_inputs[0] = last_dest;
        assert!(matches!(c.lint(), Err(CheckError::Schedule(_))));
        c.s_inputs[0] = orig;
        assert_eq!(c.lint(), Ok(()));

        // Scratch-slot aliasing: two instructions writing one slot.
        let first_dest = c.s_dest[0];
        let orig = *c.s_dest.last().unwrap();
        *c.s_dest.last_mut().unwrap() = first_dest;
        assert!(matches!(c.lint(), Err(CheckError::Schedule(_))));
        *c.s_dest.last_mut().unwrap() = orig;

        // Truth table wider than the instruction's arity.
        let narrow = c
            .runs
            .iter()
            .find(|r| r.arity < 6)
            .map(|r| r.start as usize)
            .expect("random netlist has a sub-6 arity run");
        c.s_tables[narrow] |= 1u64 << 63;
        assert!(matches!(c.lint(), Err(CheckError::Schedule(_))));
    }
}
