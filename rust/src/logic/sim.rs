//! Compiled bit-parallel netlist simulation — the inference engine.
//!
//! This is the software stand-in for the FPGA fabric: the combinational-
//! logic inference path the coordinator serves requests from. The netlist is
//! "compiled" once into flat arrays (signal codes, packed ≤6-input tables as
//! single `u64`s) and then evaluated 64 samples per pass with pure word
//! operations — no allocation, no hash lookups, no `TruthTable` indirection
//! on the hot path. See EXPERIMENTS.md §Perf for the measured speedup over
//! the naive [`LutNetlist::simulate_words`] path.

use crate::logic::netlist::{LutNetlist, Sig};

/// Signal encoding: 0 = const0, 1 = const1, `2+i` = primary input `i`,
/// `2 + num_inputs + j` = LUT `j`.
type Code = u32;

/// A netlist compiled for fast repeated evaluation.
pub struct CompiledNetlist {
    num_inputs: usize,
    /// Flattened LUT input codes.
    lut_inputs: Vec<Code>,
    /// Offset of each LUT's inputs in `lut_inputs` (len = luts + 1).
    offsets: Vec<u32>,
    /// ≤ 64-bit truth table per LUT (k ≤ 6).
    tables: Vec<u64>,
    /// Output codes + inversion flags.
    outputs: Vec<(Code, bool)>,
    /// Scratch buffer: values for [const0, const1, inputs…, luts…].
    scratch: Vec<u64>,
}

impl CompiledNetlist {
    /// Compile a netlist (all LUTs must have ≤ 6 inputs).
    pub fn compile(nl: &LutNetlist) -> CompiledNetlist {
        assert!(nl.max_arity() <= 6, "compiled simulator supports k ≤ 6");
        let code_of = |s: &Sig| -> Code {
            match s {
                Sig::Const(false) => 0,
                Sig::Const(true) => 1,
                Sig::Input(i) => 2 + *i,
                Sig::Lut(j) => 2 + nl.num_inputs as u32 + *j,
            }
        };
        let mut lut_inputs = Vec::new();
        let mut offsets = vec![0u32];
        let mut tables = Vec::with_capacity(nl.luts.len());
        for lut in &nl.luts {
            for s in &lut.inputs {
                lut_inputs.push(code_of(s));
            }
            offsets.push(lut_inputs.len() as u32);
            // Pack table into u64 (2^k bits, k ≤ 6).
            let mut t = 0u64;
            for m in 0..1u64 << lut.table.nvars() {
                if lut.table.eval(m) {
                    t |= 1 << m;
                }
            }
            tables.push(t);
        }
        let outputs = nl.outputs.iter().map(|(s, inv)| (code_of(s), *inv)).collect();
        let scratch = vec![0u64; 2 + nl.num_inputs + nl.luts.len()];
        CompiledNetlist {
            num_inputs: nl.num_inputs,
            lut_inputs,
            offsets,
            tables,
            outputs,
            scratch,
        }
    }

    /// Number of primary inputs.
    pub fn num_inputs(&self) -> usize {
        self.num_inputs
    }

    /// Number of outputs.
    pub fn num_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// Evaluate 64 samples at once. `inputs[i]` = word of input `i`;
    /// `out[j]` receives the word of output `j`.
    pub fn run_words(&mut self, inputs: &[u64], out: &mut [u64]) {
        debug_assert_eq!(inputs.len(), self.num_inputs);
        debug_assert_eq!(out.len(), self.outputs.len());
        let ni = self.num_inputs;
        self.scratch[0] = 0;
        self.scratch[1] = !0u64;
        self.scratch[2..2 + ni].copy_from_slice(inputs);
        let nluts = self.tables.len();
        for j in 0..nluts {
            let lo = self.offsets[j] as usize;
            let hi = self.offsets[j + 1] as usize;
            let k = hi - lo;
            let table = self.tables[j];
            // Shannon mux ladder over input words: expand table bits by
            // halves. Unrolled per arity for the common cases.
            let v = match k {
                0 => {
                    if table & 1 == 1 {
                        !0u64
                    } else {
                        0
                    }
                }
                _ => {
                    // Iterative halving: tbl(2^k entries) folded by inputs
                    // from the top variable down.
                    let mut vals = [0u64; 64];
                    let span = 1usize << k;
                    for (m, v) in vals.iter_mut().enumerate().take(span) {
                        *v = if (table >> m) & 1 == 1 { !0u64 } else { 0 };
                    }
                    let mut width = span;
                    for bit in (0..k).rev() {
                        let sel = self.scratch[self.lut_inputs[lo + bit] as usize];
                        width /= 2;
                        for m in 0..width {
                            let w0 = vals[m];
                            let w1 = vals[m + width];
                            vals[m] = (!sel & w0) | (sel & w1);
                        }
                    }
                    vals[0]
                }
            };
            self.scratch[2 + ni + j] = v;
        }
        for (o, (code, inv)) in out.iter_mut().zip(&self.outputs) {
            *o = self.scratch[*code as usize] ^ if *inv { !0u64 } else { 0 };
        }
    }

    /// Evaluate a batch of arbitrary size: `samples[s][i]` = input `i` of
    /// sample `s`; returns `result[s][j]` = output `j` of sample `s`.
    pub fn run_batch(&mut self, samples: &[Vec<bool>]) -> Vec<Vec<bool>> {
        let n = samples.len();
        let mut results = vec![vec![false; self.outputs.len()]; n];
        let mut in_words = vec![0u64; self.num_inputs];
        let mut out_words = vec![0u64; self.outputs.len()];
        let mut base = 0;
        while base < n {
            let lanes = (n - base).min(64);
            for w in in_words.iter_mut() {
                *w = 0;
            }
            for lane in 0..lanes {
                let s = &samples[base + lane];
                debug_assert_eq!(s.len(), self.num_inputs);
                for (i, &b) in s.iter().enumerate() {
                    if b {
                        in_words[i] |= 1 << lane;
                    }
                }
            }
            self.run_words(&in_words, &mut out_words);
            for lane in 0..lanes {
                for (j, w) in out_words.iter().enumerate() {
                    results[base + lane][j] = (w >> lane) & 1 == 1;
                }
            }
            base += lanes;
        }
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::truthtable::TruthTable;
    use crate::util::prng::Xoshiro256;

    fn random_netlist(seed: u64, num_inputs: usize, num_luts: usize) -> LutNetlist {
        let mut rng = Xoshiro256::new(seed);
        let mut nl = LutNetlist::new(num_inputs);
        for j in 0..num_luts {
            let max_sig = num_inputs + j;
            let k = 1 + rng.below(5.min(max_sig as u64)) as usize;
            let mut inputs = Vec::with_capacity(k);
            for _ in 0..k {
                let pick = rng.below(max_sig as u64) as usize;
                inputs.push(if pick < num_inputs {
                    Sig::Input(pick as u32)
                } else {
                    Sig::Lut((pick - num_inputs) as u32)
                });
            }
            let tt = TruthTable::from_fn(k, |_| rng.bernoulli(0.5));
            nl.add_lut(inputs, tt);
        }
        // outputs: last few luts with random inversion
        for j in num_luts.saturating_sub(4)..num_luts {
            nl.add_output(Sig::Lut(j as u32), rng.bernoulli(0.5));
        }
        nl.add_output(Sig::Const(true), false);
        nl.add_output(Sig::Input(0), true);
        nl
    }

    #[test]
    fn compiled_matches_reference_simulation() {
        for seed in 0..10u64 {
            let nl = random_netlist(seed, 8, 20);
            let mut c = CompiledNetlist::compile(&nl);
            let mut rng = Xoshiro256::new(seed ^ 0xF00);
            let inputs: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
            let want = nl.simulate_words(&inputs);
            let mut got = vec![0u64; want.len()];
            c.run_words(&inputs, &mut got);
            assert_eq!(got, want, "seed={seed}");
        }
    }

    #[test]
    fn run_batch_roundtrip() {
        let nl = random_netlist(77, 6, 15);
        let mut c = CompiledNetlist::compile(&nl);
        let mut rng = Xoshiro256::new(123);
        // deliberately non-multiple-of-64 batch
        let samples: Vec<Vec<bool>> = (0..150)
            .map(|_| (0..6).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let results = c.run_batch(&samples);
        for (s, r) in samples.iter().zip(&results) {
            let bits: u64 = s
                .iter()
                .enumerate()
                .map(|(i, &b)| if b { 1u64 << i } else { 0 })
                .sum();
            assert_eq!(*r, nl.eval(bits));
        }
    }

    #[test]
    fn zero_input_luts() {
        let mut nl = LutNetlist::new(1);
        let t = TruthTable::from_fn(0, |_| true); // constant-1 LUT
        let a = nl.add_lut(vec![], t);
        nl.add_output(a, false);
        nl.add_output(a, true);
        let mut c = CompiledNetlist::compile(&nl);
        let mut out = vec![0u64; 2];
        c.run_words(&[0u64], &mut out);
        assert_eq!(out[0], !0u64);
        assert_eq!(out[1], 0u64);
    }

    #[test]
    fn six_input_lut_exact() {
        let mut rng = Xoshiro256::new(0x6);
        let tt = TruthTable::from_fn(6, |_| rng.bernoulli(0.5));
        let mut nl = LutNetlist::new(6);
        let sig = nl.add_lut((0..6).map(Sig::Input).collect(), tt.clone());
        nl.add_output(sig, false);
        let mut c = CompiledNetlist::compile(&nl);
        // exhaustive over all 64 assignments, packed in one word per input
        let inputs: Vec<u64> = (0..6)
            .map(|i| {
                let mut w = 0u64;
                for m in 0..64u64 {
                    if (m >> i) & 1 == 1 {
                        w |= 1 << m;
                    }
                }
                w
            })
            .collect();
        let mut out = vec![0u64];
        c.run_words(&inputs, &mut out);
        for m in 0..64u64 {
            assert_eq!((out[0] >> m) & 1 == 1, tt.eval(m), "m={m}");
        }
    }
}
