//! Compile-time netlist optimization: constant folding, structural
//! deduplication, and dead-logic removal over a [`LutNetlist`].
//!
//! The word-parallel simulator ([`crate::logic::sim`]) evaluates every
//! scheduled LUT on every single word pass, so a LUT removed here is work
//! saved on *each* 64×W-lane batch for the lifetime of the serving process.
//! NeuraLUT and FPGN make the same observation for hardware LUT fabrics:
//! sharing, folding, and dead-logic removal at the LUT level is where the
//! latency/area wins live. [`optimize`] runs three passes:
//!
//! 1. **Constant/wire folding** — every LUT's table is rebuilt over its
//!    *distinct, constant-free* inputs (constant inputs are cofactored
//!    away, duplicate inputs merged, vacuous variables dropped, and
//!    inversions absorbed into consumer tables). A table that collapses to
//!    a constant or a single wire replaces the LUT outright.
//! 2. **Structural dedup** — two LUTs with identical `(inputs, table)`
//!    pairs compute the same signal; the later one is rewired to the
//!    earlier. Folding feeds this: dedup works on *resolved* inputs, so a
//!    chain of folds can expose equalities the raw netlist hides.
//! 3. **Dead sweep** — LUTs unreachable from any primary output are
//!    dropped (a mark from the outputs over the folded netlist).
//!
//! The result is functionally identical to the input netlist — same
//! primary inputs, same outputs in the same order — which the differential
//! property suite pins against [`LutNetlist::eval`]
//! (`rust/tests/property_logic.rs`). [`OptStats`] reports what each pass
//! removed; [`crate::fpga::report::format_opt_stats`] renders it, and the
//! serving registry surfaces the counts per model through the `depth`
//! admin command.
//!
//! Runs inside [`crate::logic::sim::CompiledNetlist::compile`] (so every
//! serving engine gets it) and per layer inside
//! [`crate::flow::run_flow`] (so emitted/persisted circuits shrink too).

use std::collections::HashMap;

use crate::logic::netlist::{LutNetlist, Sig};
use crate::logic::truthtable::TruthTable;

/// What [`optimize`] did to a netlist. The passes partition the removed
/// LUTs: `luts_before − luts_after = const_folded + deduped + dead_removed`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptStats {
    /// LUT count of the input netlist.
    pub luts_before: usize,
    /// LUT count of the optimized netlist.
    pub luts_after: usize,
    /// LUTs that collapsed to a constant or a plain wire (constant inputs
    /// cofactored away, duplicate/vacuous variables merged or dropped).
    pub const_folded: usize,
    /// LUTs structurally identical to an earlier LUT after folding.
    pub deduped: usize,
    /// LUTs unreachable from any primary output.
    pub dead_removed: usize,
}

impl OptStats {
    /// Stats for a netlist the optimizer did not touch (the
    /// `compile_unoptimized` baseline).
    pub fn unchanged(luts: usize) -> OptStats {
        OptStats { luts_before: luts, luts_after: luts, ..Default::default() }
    }

    /// Total LUTs removed.
    pub fn removed(&self) -> usize {
        self.luts_before - self.luts_after
    }

    /// Accumulate another stats record (per-layer totals in the flow).
    pub fn absorb(&mut self, other: &OptStats) {
        self.luts_before += other.luts_before;
        self.luts_after += other.luts_after;
        self.const_folded += other.const_folded;
        self.deduped += other.deduped;
        self.dead_removed += other.dead_removed;
    }
}

/// How one original table variable resolves after substitution.
enum Occ {
    /// The input is a known constant; the table is cofactored on it.
    Fixed(bool),
    /// The input is the `idx`-th distinct live signal, possibly inverted.
    Var { idx: usize, inv: bool },
}

/// Optimize a netlist. Returns a functionally identical netlist (same
/// inputs, same outputs in the same order) with constant-derivable LUTs
/// folded, structural duplicates merged, and dead logic removed.
pub fn optimize(nl: &LutNetlist) -> (LutNetlist, OptStats) {
    let mut stats = OptStats::unchanged(nl.num_luts());

    // ---- pass 1+2: fold + dedup, in one topological sweep ----
    // subst[j] = what original LUT j's output became: a signal in `mid`
    // (or a constant / primary input), plus an inversion flag that
    // consumers absorb into their tables and outputs absorb into their
    // inversion bits.
    let mut subst: Vec<(Sig, bool)> = Vec::with_capacity(nl.luts.len());
    let mut mid = LutNetlist::new(nl.num_inputs);
    let mut seen: HashMap<(Vec<Sig>, TruthTable), Sig> = HashMap::new();

    for lut in &nl.luts {
        // Resolve every input through the substitution map and classify it
        // as a fixed bit or an occurrence of a distinct live signal.
        let mut occ: Vec<Occ> = Vec::with_capacity(lut.inputs.len());
        let mut vars: Vec<Sig> = Vec::new();
        for s in &lut.inputs {
            let (sig, inv) = match s {
                Sig::Lut(j) => subst[*j as usize],
                other => (*other, false),
            };
            match sig {
                Sig::Const(b) => occ.push(Occ::Fixed(b ^ inv)),
                _ => {
                    let idx = match vars.iter().position(|&u| u == sig) {
                        Some(i) => i,
                        None => {
                            vars.push(sig);
                            vars.len() - 1
                        }
                    };
                    occ.push(Occ::Var { idx, inv });
                }
            }
        }

        // Rebuild the table over the distinct, constant-free variables
        // (constants cofactored, duplicates merged, inversions absorbed).
        let mut table = TruthTable::from_fn(vars.len(), |m| {
            let mut a = 0u64;
            for (v, o) in occ.iter().enumerate() {
                let bit = match o {
                    Occ::Fixed(b) => *b,
                    Occ::Var { idx, inv } => (((m >> *idx) & 1) == 1) ^ *inv,
                };
                if bit {
                    a |= 1 << v;
                }
            }
            lut.table.eval(a)
        });

        // Drop variables the rebuilt function does not depend on.
        let mut v = vars.len();
        while v > 0 {
            v -= 1;
            if !table.depends_on(v) {
                table = remove_var(&table, v);
                vars.remove(v);
            }
        }

        if table.is_zero() {
            subst.push((Sig::Const(false), false));
            stats.const_folded += 1;
            continue;
        }
        if table.is_ones() {
            subst.push((Sig::Const(true), false));
            stats.const_folded += 1;
            continue;
        }
        if vars.len() == 1 {
            // Depends on exactly one variable and is not constant: it is a
            // buffer or an inverter — a wire either way (the inversion is
            // absorbed downstream).
            let inverted = table.eval(0);
            subst.push((vars[0], inverted));
            stats.const_folded += 1;
            continue;
        }

        let key = (vars, table);
        if let Some(&existing) = seen.get(&key) {
            subst.push((existing, false));
            stats.deduped += 1;
            continue;
        }
        let sig = mid.add_lut(key.0.clone(), key.1.clone());
        seen.insert(key, sig);
        subst.push((sig, false));
    }

    for (s, inv) in &nl.outputs {
        let (sig, sinv) = match s {
            Sig::Lut(j) => subst[*j as usize],
            other => (*other, false),
        };
        mid.add_output(sig, sinv ^ inv);
    }

    // ---- pass 3: dead sweep from the outputs ----
    let mut live = vec![false; mid.luts.len()];
    let mut stack: Vec<usize> = Vec::new();
    for (s, _) in &mid.outputs {
        if let Sig::Lut(j) = s {
            stack.push(*j as usize);
        }
    }
    while let Some(j) = stack.pop() {
        if live[j] {
            continue;
        }
        live[j] = true;
        for s in &mid.luts[j].inputs {
            if let Sig::Lut(i) = s {
                if !live[*i as usize] {
                    stack.push(*i as usize);
                }
            }
        }
    }

    let mut out = LutNetlist::new(mid.num_inputs);
    let mut remap: Vec<Sig> = Vec::with_capacity(mid.luts.len());
    for (j, lut) in mid.luts.iter().enumerate() {
        if !live[j] {
            stats.dead_removed += 1;
            // Placeholder: a dead LUT is, by construction, never referenced
            // by a live LUT or an output.
            remap.push(Sig::Const(false));
            continue;
        }
        let inputs: Vec<Sig> = lut
            .inputs
            .iter()
            .map(|s| match s {
                Sig::Lut(i) => remap[*i as usize],
                other => *other,
            })
            .collect();
        remap.push(out.add_lut(inputs, lut.table.clone()));
    }
    for (s, inv) in &mid.outputs {
        let sig = match s {
            Sig::Lut(j) => remap[*j as usize],
            other => *other,
        };
        out.add_output(sig, *inv);
    }

    stats.luts_after = out.num_luts();
    (out, stats)
}

/// Remove variable `v` from a table that does not depend on it
/// (compacting the remaining variables down by one position).
fn remove_var(t: &TruthTable, v: usize) -> TruthTable {
    debug_assert!(!t.depends_on(v));
    TruthTable::from_fn(t.nvars() - 1, |m| {
        let low = m & ((1u64 << v) - 1);
        let high = (m >> v) << (v + 1);
        t.eval(high | low)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::verify::{exhaustive_netlists, EquivResult};
    use crate::util::prng::Xoshiro256;

    fn and_tt() -> TruthTable {
        TruthTable::from_fn(2, |m| m == 3)
    }

    fn xor_tt() -> TruthTable {
        TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 1)
    }

    fn assert_equivalent(a: &LutNetlist, b: &LutNetlist) {
        match exhaustive_netlists(a, b).expect("same signature by construction") {
            EquivResult::Equivalent => {}
            EquivResult::Mismatch { input_bits, got, want, .. } => {
                panic!("optimizer changed the function at {input_bits:#b}: {got:?} vs {want:?}")
            }
        }
    }

    #[test]
    fn constant_input_folds_the_lut() {
        // AND(in0, const0) = const0; AND(in0, const1) = in0 (a wire).
        let mut nl = LutNetlist::new(1);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Const(false)], and_tt());
        let b = nl.add_lut(vec![Sig::Input(0), Sig::Const(true)], and_tt());
        nl.add_output(a, false);
        nl.add_output(b, false);
        let (o, s) = optimize(&nl);
        assert_equivalent(&nl, &o);
        assert_eq!(o.num_luts(), 0, "both LUTs must fold away");
        assert_eq!(s.const_folded, 2);
        assert_eq!(o.outputs, vec![(Sig::Const(false), false), (Sig::Input(0), false)]);
    }

    #[test]
    fn inverter_chain_folds_to_wire_with_inversion() {
        // NOT(NOT(in0)) = in0; the inner NOT becomes an inverted wire the
        // outer LUT absorbs into its table, then the outer folds too.
        let inv = TruthTable::from_fn(1, |m| m == 0);
        let mut nl = LutNetlist::new(1);
        let a = nl.add_lut(vec![Sig::Input(0)], inv.clone());
        let b = nl.add_lut(vec![a], inv);
        nl.add_output(b, false);
        nl.add_output(a, false);
        let (o, s) = optimize(&nl);
        assert_equivalent(&nl, &o);
        assert_eq!(o.num_luts(), 0);
        assert_eq!(s.const_folded, 2);
        assert_eq!(o.outputs, vec![(Sig::Input(0), false), (Sig::Input(0), true)]);
    }

    #[test]
    fn duplicate_inputs_merge_and_cascade() {
        // XOR(a, a) = 0 — the duplicate occurrence merges into one
        // variable, the table stops depending on it, and the LUT folds.
        let mut nl = LutNetlist::new(2);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], and_tt());
        let x = nl.add_lut(vec![a, a], xor_tt());
        nl.add_output(x, false);
        let (o, s) = optimize(&nl);
        assert_equivalent(&nl, &o);
        assert_eq!(o.num_luts(), 0, "XOR(a,a) folds to const0, AND goes dead");
        assert_eq!(o.outputs, vec![(Sig::Const(false), false)]);
        assert_eq!(s.const_folded, 1);
        assert_eq!(s.dead_removed, 1);
    }

    #[test]
    fn structural_duplicates_share_one_lut() {
        // Two identical ANDs; a consumer XORs them — after dedup the XOR
        // sees the same signal twice and folds to const0.
        let mut nl = LutNetlist::new(2);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], and_tt());
        let b = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], and_tt());
        let x = nl.add_lut(vec![a, b], xor_tt());
        nl.add_output(x, false);
        nl.add_output(a, false);
        let (o, s) = optimize(&nl);
        assert_equivalent(&nl, &o);
        assert_eq!(s.deduped, 1, "the second AND is a structural duplicate");
        assert_eq!(s.const_folded, 1, "XOR(a,a) folds");
        assert_eq!(o.num_luts(), 1, "one AND survives (it feeds an output)");
    }

    #[test]
    fn dead_logic_is_swept() {
        let mut nl = LutNetlist::new(2);
        let _dead = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor_tt());
        let live = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], and_tt());
        nl.add_output(live, true);
        let (o, s) = optimize(&nl);
        assert_equivalent(&nl, &o);
        assert_eq!(o.num_luts(), 1);
        assert_eq!(s.dead_removed, 1);
    }

    #[test]
    fn stats_partition_the_removed_luts_on_random_netlists() {
        for seed in 0..20u64 {
            let mut rng = Xoshiro256::new(seed);
            let nin = 1 + rng.below(8) as usize;
            let nluts = 1 + rng.below(30) as usize;
            let mut nl = LutNetlist::new(nin);
            for j in 0..nluts {
                let navail = nin + j;
                let k = rng.below(5) as usize; // arities 0..=4 incl. const LUTs
                let inputs: Vec<Sig> = (0..k)
                    .map(|_| {
                        // constants, duplicates, and LUT refs all occur
                        match rng.below(8) {
                            0 => Sig::Const(rng.bernoulli(0.5)),
                            _ => {
                                let pick = rng.below(navail as u64) as usize;
                                if pick < nin {
                                    Sig::Input(pick as u32)
                                } else {
                                    Sig::Lut((pick - nin) as u32)
                                }
                            }
                        }
                    })
                    .collect();
                let tt = TruthTable::from_fn(k, |_| rng.bernoulli(0.5));
                nl.add_lut(inputs, tt);
            }
            for j in 0..nluts.min(3) {
                nl.add_output(Sig::Lut(j as u32), rng.bernoulli(0.5));
            }
            nl.add_output(Sig::Input(0), true);
            let (o, s) = optimize(&nl);
            assert_equivalent(&nl, &o);
            assert_eq!(s.luts_before, nl.num_luts(), "seed {seed}");
            assert_eq!(s.luts_after, o.num_luts(), "seed {seed}");
            assert_eq!(
                s.removed(),
                s.const_folded + s.deduped + s.dead_removed,
                "seed {seed}: passes must partition the removed LUTs"
            );
        }
    }

    #[test]
    fn optimize_is_idempotent() {
        let mut rng = Xoshiro256::new(0xD0);
        let mut nl = LutNetlist::new(4);
        for j in 0..12 {
            let navail = 4 + j;
            let k = 1 + rng.below(3) as usize;
            let inputs: Vec<Sig> = (0..k)
                .map(|_| {
                    let pick = rng.below(navail as u64) as usize;
                    if pick < 4 {
                        Sig::Input(pick as u32)
                    } else {
                        Sig::Lut((pick - 4) as u32)
                    }
                })
                .collect();
            let tt = TruthTable::from_fn(k, |_| rng.bernoulli(0.5));
            nl.add_lut(inputs, tt);
        }
        nl.add_output(Sig::Lut(11), false);
        let (once, _) = optimize(&nl);
        let (twice, s2) = optimize(&once);
        assert_eq!(once.num_luts(), twice.num_luts(), "second pass must find nothing");
        assert_eq!(s2.removed(), 0);
        assert_equivalent(&nl, &twice);
    }
}
