//! REDUCE — shrink each cube to the smallest cube that still covers the
//! part of the function no other cube covers.
//!
//! `reduce(c) = c ∩ SCCC((F ∖ {c} ∪ D) cofactored by c)` where SCCC is the
//! smallest cube containing the *complement* of the cofactor. Reduction
//! deliberately un-primes cubes so the next EXPAND can escape local minima —
//! the heart of the ESPRESSO iteration.

use crate::logic::cube::{Cover, Cube};

/// One REDUCE pass. Cubes are processed largest-first; each sees the
/// already-reduced versions of its predecessors (in-place update), matching
/// the sequential semantics of the original algorithm.
pub fn reduce(f: &Cover, dc: &Cover) -> Cover {
    let nvars = f.nvars();
    let mut cubes: Vec<Cube> = f.cubes.clone();
    let mut order: Vec<usize> = (0..cubes.len()).collect();
    order.sort_by_key(|&i| cubes[i].literal_count());

    for &i in &order {
        let c = cubes[i].clone();
        // G = (F \ {c}) ∪ D, cofactored by c.
        let mut rest = Vec::with_capacity(cubes.len() + dc.cubes.len());
        for (j, other) in cubes.iter().enumerate() {
            if j != i {
                rest.push(other.clone());
            }
        }
        rest.extend(dc.cubes.iter().cloned());
        let g = Cover::from_cubes(nvars, rest).cofactor(&c);

        if g.is_tautology() {
            // c entirely covered by the rest: shrink to empty (drop below).
            cubes[i] = Cube::empty_marker(nvars);
            continue;
        }
        // SCCC: supercube of the complement of g.
        let comp = g.complement();
        if comp.is_empty() {
            cubes[i] = Cube::empty_marker(nvars);
            continue;
        }
        let mut sccc = comp.cubes[0].clone();
        for k in &comp.cubes[1..] {
            sccc = sccc.supercube(k);
        }
        if let Some(reduced) = c.intersect(&sccc) {
            cubes[i] = reduced;
        } else {
            cubes[i] = Cube::empty_marker(nvars);
        }
    }
    let cubes: Vec<Cube> = cubes.into_iter().filter(|c| !c.is_empty_cube()).collect();
    Cover::from_cubes(nvars, cubes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::espresso::expand::expand;
    use crate::logic::truthtable::TruthTable;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn reduce_preserves_function() {
        let mut rng = Xoshiro256::new(0x4ED);
        for trial in 0..60 {
            let nvars = 2 + (trial % 5);
            let tt = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.4));
            let f = TruthTable::isop(&tt, &TruthTable::zeros(nvars));
            let r = reduce(&f, &Cover::empty(nvars));
            assert_eq!(TruthTable::from_cover(&r), tt, "trial {trial}");
        }
    }

    #[test]
    fn reduce_shrinks_overlapping_primes() {
        // F = {x, y} over 2 vars: reduce(x) given y stays x (it uniquely
        // covers x·y'), but reduce can never grow cubes.
        let f = Cover::parse(2, "1- -1");
        let r = reduce(&f, &Cover::empty(2));
        assert!(r.literal_count() >= f.literal_count());
        assert_eq!(
            TruthTable::from_cover(&r),
            TruthTable::from_cover(&f)
        );
    }

    #[test]
    fn reduce_drops_fully_covered_cube() {
        // x·y is inside x; reduce should eliminate it entirely.
        let f = Cover::parse(2, "1- 11");
        let r = reduce(&f, &Cover::empty(2));
        assert_eq!(TruthTable::from_cover(&r), TruthTable::from_cover(&f));
        assert!(r.len() <= 2);
        // After a reduce→expand roundtrip the cover stays equivalent.
        let off = TruthTable::from_cover(&f).not();
        let offc = TruthTable::isop(&off, &TruthTable::zeros(2));
        let e = expand(&r, &offc);
        assert_eq!(TruthTable::from_cover(&e), TruthTable::from_cover(&f));
    }

    #[test]
    fn reduce_with_dc_keeps_on_covered() {
        let mut rng = Xoshiro256::new(0xDC0);
        for trial in 0..40 {
            let nvars = 3 + (trial % 4);
            let on = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.3));
            let dcm = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.2));
            let dc_tt = dcm.and(&on.not());
            let f = TruthTable::isop(&on, &dc_tt);
            let dc_cover = TruthTable::isop(&dc_tt, &TruthTable::zeros(nvars));
            let r = reduce(&f, &dc_cover);
            let rtt = TruthTable::from_cover(&r);
            assert!(on.implies(&rtt), "ON lost in reduce, trial {trial}");
            assert!(rtt.implies(&on.or(&dc_tt)), "reduce exceeded ON∪DC");
        }
    }
}
