//! Essential-prime detection.
//!
//! A prime `p` is *essential* iff it covers a minterm no other prime of the
//! function covers. ESPRESSO's test (Brayton et al., §4.4): form
//! `H = CONS((F ∖ {p}) ∪ D, p)` — cubes of the rest that touch `p` plus all
//! distance-1 consensus terms with `p` — then `p` is essential iff
//! `p ⊄ H ∪ D`. Essentials are frozen into the don't-care set during the
//! REDUCE/EXPAND/IRREDUNDANT loop and restored at the end, shrinking the
//! iteration space.

use crate::logic::cube::{Cover, Cube};

/// Is prime `p` essential w.r.t. cover `rest` (= F without p) and `dc`?
pub fn is_essential(p: &Cube, rest: &Cover, dc: &Cover) -> bool {
    let nvars = rest.nvars();
    let mut h: Vec<Cube> = Vec::new();
    for q in rest.cubes.iter().chain(dc.cubes.iter()) {
        match q.distance(p) {
            0 => h.push(q.clone()),
            1 => {
                if let Some(c) = q.consensus(p) {
                    h.push(c);
                }
            }
            _ => {}
        }
    }
    let h = Cover::from_cubes(nvars, h);
    !h.contains_cube(p)
}

/// Split `f` into (essential, non-essential) cubes.
pub fn partition_essential(f: &Cover, dc: &Cover) -> (Cover, Cover) {
    let nvars = f.nvars();
    let mut ess = Vec::new();
    let mut rest = Vec::new();
    for (i, c) in f.cubes.iter().enumerate() {
        let others: Vec<Cube> = f
            .cubes
            .iter()
            .enumerate()
            .filter(|(j, _)| *j != i)
            .map(|(_, c)| c.clone())
            .collect();
        let others = Cover::from_cubes(nvars, others);
        if is_essential(c, &others, dc) {
            ess.push(c.clone());
        } else {
            rest.push(c.clone());
        }
    }
    (Cover::from_cubes(nvars, ess), Cover::from_cubes(nvars, rest))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::espresso::{expand::expand, irredundant::irredundant};
    use crate::logic::truthtable::TruthTable;

    #[test]
    fn lone_cube_is_essential() {
        let f = Cover::parse(2, "1-");
        let (ess, rest) = partition_essential(&f, &Cover::empty(2));
        assert_eq!(ess.len(), 1);
        assert!(rest.is_empty());
    }

    #[test]
    fn consensus_covered_prime_not_essential() {
        // f = x·y + x'·z + y·z  (all primes). y·z is non-essential.
        let f = Cover::parse(3, "11- 0-1 -11");
        let (ess, rest) = partition_essential(&f, &Cover::empty(3));
        assert_eq!(ess.len(), 2, "x·y and x'·z are essential");
        assert_eq!(rest.len(), 1);
        assert_eq!(format!("{:?}", rest.cubes[0]), "-11");
    }

    #[test]
    fn essential_detection_matches_bruteforce() {
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(0xE55);
        for trial in 0..40 {
            let nvars = 2 + (trial % 4);
            let tt = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.45));
            if tt.is_zero() {
                continue;
            }
            // Build a prime & irredundant cover first.
            let seed = TruthTable::isop(&tt, &TruthTable::zeros(nvars));
            let offc = TruthTable::isop(&tt.not(), &TruthTable::zeros(nvars));
            let f = irredundant(&expand(&seed, &offc), &Cover::empty(nvars));

            let (ess, _) = partition_essential(&f, &Cover::empty(nvars));
            // Brute force: p essential iff ∃ ON-minterm covered by p and by
            // no OTHER PRIME of the function (enumerate all primes).
            let mut primes: Vec<Cube> = Vec::new();
            let ncubes = 3usize.pow(nvars as u32);
            let mut all: Vec<Cube> = Vec::new();
            for code in 0..ncubes {
                use crate::logic::cube::Pol;
                let mut c = Cube::full(nvars);
                let mut rem = code;
                for v in 0..nvars {
                    match rem % 3 {
                        0 => c.set(v, Pol::Zero),
                        1 => c.set(v, Pol::One),
                        _ => {}
                    }
                    rem /= 3;
                }
                if (0..1u64 << nvars).all(|m| !c.covers_minterm(m) || tt.eval(m)) {
                    all.push(c);
                }
            }
            for c in &all {
                if !all.iter().any(|d| d != c && d.contains(c)) {
                    primes.push(c.clone());
                }
            }
            for c in &f.cubes {
                let mut unique = false;
                for m in 0..1u64 << nvars {
                    if c.covers_minterm(m)
                        && tt.eval(m)
                        && !primes.iter().any(|o| o != c && o.covers_minterm(m))
                    {
                        unique = true;
                        break;
                    }
                }
                let flagged = ess.cubes.contains(c);
                assert_eq!(flagged, unique, "cube {c:?} trial {trial}");
            }
        }
    }
}
