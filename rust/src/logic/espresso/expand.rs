//! EXPAND — raise cubes to primes against the OFF-set.
//!
//! Each cube is greedily enlarged (literals raised to don't-care) while it
//! stays disjoint from every OFF-set cube; the result is a prime implicant.
//! Raising order follows the classic blocking-matrix heuristic: prefer the
//! variable whose raise conflicts with the fewest OFF cubes, so the cube
//! grows toward the direction with most freedom and tends to cover (and thus
//! delete) the most sibling cubes.

use crate::logic::cube::{Cover, Cube, Pol};

/// Expand every cube of `f` into a prime against `off`; covered cubes are
/// removed. `off` must be exactly the complement of ON ∪ DC.
pub fn expand(f: &Cover, off: &Cover) -> Cover {
    let nvars = f.nvars();
    let mut cubes: Vec<Cube> = f.cubes.clone();
    // Expand biggest cubes first (fewest literals) — they are most likely
    // to swallow others, matching ESPRESSO's weight ordering.
    cubes.sort_by_key(|c| c.literal_count());

    let mut result: Vec<Cube> = Vec::with_capacity(cubes.len());
    let mut covered = vec![false; cubes.len()];

    for i in 0..cubes.len() {
        if covered[i] {
            continue;
        }
        let prime = expand_one(&cubes[i], off, nvars);
        // Mark the remaining cubes this prime now covers.
        for (j, c) in cubes.iter().enumerate().skip(i + 1) {
            if !covered[j] && prime.contains(c) {
                covered[j] = true;
            }
        }
        // Also drop earlier results strictly contained in the new prime
        // (possible when a later small cube expands past an earlier prime).
        result.retain(|r| !prime.contains(r) || *r == prime);
        if !result.iter().any(|r| r.contains(&prime)) {
            result.push(prime);
        }
    }
    Cover::from_cubes(nvars, result)
}

/// Expand a single cube into a prime implicant of ¬OFF.
pub fn expand_one(cube: &Cube, off: &Cover, nvars: usize) -> Cube {
    let mut c = cube.clone();
    loop {
        // Candidate raises: literals whose removal keeps c ∩ OFF = ∅.
        // Score = number of OFF cubes that *block* the raise (distance
        // becomes 0 after raising). Pick the raise with the fewest blockers
        // = 0 required; among the feasible ones pick greedily by how many
        // other raises stay feasible — approximated by choosing the
        // feasible raise whose var appears least in OFF.
        let mut best: Option<usize> = None;
        let mut best_score = usize::MAX;
        for v in 0..nvars {
            let p = c.get(v);
            if p == Pol::DC {
                continue;
            }
            let mut raised = c.clone();
            raised.set(v, Pol::DC);
            // Feasible iff raised is still disjoint from all OFF cubes.
            let mut feasible = true;
            let mut tension = 0usize;
            for o in &off.cubes {
                let d = raised.distance(o);
                if d == 0 {
                    feasible = false;
                    break;
                }
                if d == 1 {
                    tension += 1; // near-blocking cubes: prefer fewer
                }
            }
            if feasible && tension < best_score {
                best_score = tension;
                best = Some(v);
            }
        }
        match best {
            Some(v) => c.set(v, Pol::DC),
            None => return c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::truthtable::TruthTable;
    use crate::util::prng::Xoshiro256;

    fn is_prime(c: &Cube, off: &Cover, nvars: usize) -> bool {
        // prime iff no single literal can be raised without hitting OFF
        for v in 0..nvars {
            if c.get(v) != Pol::DC {
                let mut r = c.clone();
                r.set(v, Pol::DC);
                if off.cubes.iter().all(|o| r.distance(o) > 0) {
                    return false;
                }
            }
        }
        true
    }

    #[test]
    fn expands_to_prime() {
        // f = x0 (on), off = x0'. The minterm 11 should expand to "1-".
        let on = Cover::parse(2, "11");
        let off = Cover::parse(2, "0-");
        let e = expand(&on, &off);
        assert_eq!(e.len(), 1);
        assert_eq!(format!("{:?}", e.cubes[0]), "1-");
    }

    #[test]
    fn expansion_swallows_covered_cubes() {
        // Both minterms of x0 expand to the same prime.
        let on = Cover::parse(2, "10 11");
        let off = Cover::parse(2, "0-");
        let e = expand(&on, &off);
        assert_eq!(e.len(), 1);
    }

    #[test]
    fn expanded_cover_equivalent_within_dc() {
        let mut rng = Xoshiro256::new(0xEAB);
        for trial in 0..60 {
            let nvars = 2 + (trial % 6);
            let on_tt = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.35));
            let off_tt = on_tt.not();
            let on = TruthTable::isop(&on_tt, &TruthTable::zeros(nvars));
            let off = TruthTable::isop(&off_tt, &TruthTable::zeros(nvars));
            let e = expand(&on, &off);
            // Every original ON minterm still covered; nothing in OFF covered.
            let ett = TruthTable::from_cover(&e);
            assert_eq!(ett, on_tt, "expand must preserve the function exactly when DC=∅");
            // All results prime.
            for c in &e.cubes {
                assert!(is_prime(c, &off, nvars), "non-prime cube {c:?}");
            }
        }
    }

    #[test]
    fn expand_with_dc_can_grow_beyond_on() {
        // ON = minterm 11, DC = minterm 01 ⇒ OFF = {00, 10} = x1'.
        // The ON cube can expand to "-1" using the DC.
        let on = Cover::parse(2, "11");
        let off = Cover::parse(2, "-0");
        let e = expand(&on, &off);
        assert_eq!(e.len(), 1);
        assert_eq!(format!("{:?}", e.cubes[0]), "-1");
    }

    #[test]
    fn empty_cover_stays_empty() {
        let e = expand(&Cover::empty(3), &Cover::universe(3));
        assert!(e.is_empty());
    }
}
