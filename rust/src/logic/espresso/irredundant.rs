//! IRREDUNDANT — extract a minimal subcover.
//!
//! ESPRESSO partitions the cover into relatively-essential cubes `E_r`
//! (must stay), totally-redundant cubes (covered by `E_r ∪ D`, dropped) and
//! partially-redundant cubes `R_p`, then solves a covering problem to pick a
//! minimum subset of `R_p`. The original solves MINCOV on a symbolic
//! covering matrix; since every function this project minimizes has ≤ ~16
//! inputs, we build the covering problem on the *dense* minterm sets —
//! exact branch-and-bound for small instances, greedy otherwise — which is
//! both simpler and strictly better at escaping cyclic covers than the
//! textbook one-cube-at-a-time deletion. A symbolic fallback handles wide
//! covers (> [`DENSE_VAR_LIMIT`] vars).

use crate::logic::cube::Cover;
use crate::util::bitvec::BitVec;

/// Covers wider than this use the symbolic (cofactor-tautology) fallback.
pub const DENSE_VAR_LIMIT: usize = 16;

/// Exact set-cover search is attempted below this candidate count.
const EXACT_LIMIT: usize = 24;

/// Return an irredundant subset of `f` equivalent to `f` modulo `dc`.
pub fn irredundant(f: &Cover, dc: &Cover) -> Cover {
    if f.nvars() <= DENSE_VAR_LIMIT {
        irredundant_dense(f, dc)
    } else {
        irredundant_symbolic(f, dc)
    }
}

fn cube_bits(f: &Cover, idx: usize) -> BitVec {
    Cover::from_cubes(f.nvars(), vec![f.cubes[idx].clone()]).to_truth_bits()
}

fn irredundant_dense(f: &Cover, dc: &Cover) -> Cover {
    let nvars = f.nvars();
    let n = f.cubes.len();
    if n == 0 {
        return f.clone();
    }
    let size = 1usize << nvars;
    let cube_sets: Vec<BitVec> = (0..n).map(|i| cube_bits(f, i)).collect();
    let dc_set = dc.to_truth_bits();
    let mut f_set = BitVec::zeros(size);
    for cb in &cube_sets {
        f_set.or_assign(cb);
    }

    // Relatively essential: cube has a minterm covered by no other cube nor DC.
    let mut essential = vec![false; n];
    for i in 0..n {
        let mut others = dc_set.clone();
        for (j, cb) in cube_sets.iter().enumerate() {
            if j != i {
                others.or_assign(cb);
            }
        }
        if !cube_sets[i].is_subset_of(&others) {
            essential[i] = true;
        }
    }

    // Base coverage from essentials + DC.
    let mut covered = dc_set.clone();
    for i in 0..n {
        if essential[i] {
            covered.or_assign(&cube_sets[i]);
        }
    }
    // Target: minterms of F not yet covered.
    let mut target = f_set.clone();
    target.and_assign(&covered.not());

    let mut chosen: Vec<usize> = (0..n).filter(|&i| essential[i]).collect();
    if !target.is_zero() {
        // Candidates: partially-redundant cubes that cover some target bit.
        let cands: Vec<usize> = (0..n)
            .filter(|&i| !essential[i] && cube_sets[i].intersects(&target))
            .collect();
        let picked = if cands.len() <= EXACT_LIMIT {
            exact_cover(&cands, &cube_sets, &target, f)
        } else {
            greedy_cover(&cands, &cube_sets, &target, f)
        };
        chosen.extend(picked);
    }
    chosen.sort_unstable();
    Cover::from_cubes(nvars, chosen.iter().map(|&i| f.cubes[i].clone()).collect())
}

/// Greedy weighted set cover: repeatedly take the candidate covering the
/// most uncovered minterms (ties: fewer literals).
fn greedy_cover(cands: &[usize], sets: &[BitVec], target: &BitVec, f: &Cover) -> Vec<usize> {
    let mut remaining = target.clone();
    let mut picked = Vec::new();
    let mut avail: Vec<usize> = cands.to_vec();
    while !remaining.is_zero() {
        let mut best: Option<(usize, (usize, usize))> = None;
        for &i in &avail {
            let mut s = sets[i].clone();
            s.and_assign(&remaining);
            let key = (s.count_ones(), usize::MAX - f.cubes[i].literal_count());
            if best.map(|(_, bk)| key > bk).unwrap_or(true) {
                best = Some((i, key));
            }
        }
        let (best, _) = best.expect("target coverable by candidates");
        picked.push(best);
        remaining.and_assign(&sets[best].not());
        avail.retain(|&i| i != best);
    }
    picked
}

/// Exact minimum set cover by depth-bounded branch and bound.
fn exact_cover(cands: &[usize], sets: &[BitVec], target: &BitVec, f: &Cover) -> Vec<usize> {
    // Upper bound from greedy.
    let greedy = greedy_cover(cands, sets, target, f);
    let mut best = greedy.clone();
    let mut stack_choice: Vec<usize> = Vec::new();
    bb(cands, sets, target, &mut stack_choice, &mut best);
    best
}

fn bb(
    cands: &[usize],
    sets: &[BitVec],
    remaining: &BitVec,
    chosen: &mut Vec<usize>,
    best: &mut Vec<usize>,
) {
    if remaining.is_zero() {
        if chosen.len() < best.len() {
            *best = chosen.clone();
        }
        return;
    }
    if chosen.len() + 1 >= best.len() {
        return; // bound
    }
    // Branch on the first uncovered minterm: one of its covering cubes must
    // be chosen (standard covering branching — complete, and the mandatory
    // minterm keeps the tree narrow at this scale).
    let first = remaining.iter_ones().next().unwrap();
    for &i in cands {
        if sets[i].get(first) && !chosen.contains(&i) {
            let mut rem = remaining.clone();
            rem.and_assign(&sets[i].not());
            chosen.push(i);
            bb(cands, sets, &rem, chosen, best);
            chosen.pop();
        }
    }
}

/// Symbolic fallback for wide covers: one-at-a-time removal, most
/// specialized first.
fn irredundant_symbolic(f: &Cover, dc: &Cover) -> Cover {
    let nvars = f.nvars();
    let mut order: Vec<usize> = (0..f.cubes.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(f.cubes[i].literal_count()));
    let mut alive = vec![true; f.cubes.len()];
    for &i in &order {
        let mut rest = Vec::with_capacity(f.cubes.len() + dc.cubes.len());
        for (j, c) in f.cubes.iter().enumerate() {
            if j != i && alive[j] {
                rest.push(c.clone());
            }
        }
        rest.extend(dc.cubes.iter().cloned());
        let rest = Cover::from_cubes(nvars, rest);
        if rest.contains_cube(&f.cubes[i]) {
            alive[i] = false;
        }
    }
    let cubes = f
        .cubes
        .iter()
        .zip(&alive)
        .filter(|(_, &a)| a)
        .map(|(c, _)| c.clone())
        .collect();
    Cover::from_cubes(nvars, cubes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::truthtable::TruthTable;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn removes_consensus_redundancy() {
        // x·y + x'·z + y·z : the y·z term is redundant (consensus).
        let f = Cover::parse(3, "11- 0-1 -11");
        let g = irredundant(&f, &Cover::empty(3));
        assert_eq!(g.len(), 2);
        assert!(TruthTable::from_cover(&g) == TruthTable::from_cover(&f));
    }

    #[test]
    fn keeps_needed_cubes() {
        let f = Cover::parse(2, "1- -1");
        let g = irredundant(&f, &Cover::empty(2));
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn dc_makes_cube_redundant() {
        let f = Cover::parse(1, "1");
        let dc = Cover::parse(1, "1");
        let g = irredundant(&f, &dc);
        assert!(g.is_empty());
    }

    #[test]
    fn solves_cyclic_cover_minimally() {
        // All six 2-minterm primes of Σm(0,1,2,5,6,7): minimum subcover = 3.
        let f = Cover::parse(3, "-00 0-0 10- 01- 1-1 -11");
        let g = irredundant(&f, &Cover::empty(3));
        assert_eq!(TruthTable::from_cover(&g), TruthTable::from_cover(&f));
        assert_eq!(g.len(), 3, "{g:?}");
    }

    #[test]
    fn no_cube_removable_afterwards() {
        let mut rng = Xoshiro256::new(0x1DD);
        for trial in 0..40 {
            let nvars = 2 + (trial % 5);
            let tt = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.45));
            let f = TruthTable::isop(&tt, &TruthTable::zeros(nvars));
            let g = irredundant(&f, &Cover::empty(nvars));
            assert_eq!(TruthTable::from_cover(&g), tt);
            for i in 0..g.len() {
                let mut cubes = g.cubes.clone();
                cubes.remove(i);
                let smaller = Cover::from_cubes(nvars, cubes);
                assert_ne!(
                    TruthTable::from_cover(&smaller),
                    tt,
                    "cube {i} still redundant"
                );
            }
        }
    }

    #[test]
    fn symbolic_fallback_agrees_semantically() {
        let mut rng = Xoshiro256::new(0x51B);
        for _ in 0..20 {
            let nvars = 5;
            let tt = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.4));
            let f = TruthTable::isop(&tt, &TruthTable::zeros(nvars));
            let a = irredundant_dense(&f, &Cover::empty(nvars));
            let b = irredundant_symbolic(&f, &Cover::empty(nvars));
            assert_eq!(TruthTable::from_cover(&a), tt);
            assert_eq!(TruthTable::from_cover(&b), tt);
            assert!(a.len() <= b.len(), "dense must not be worse");
        }
    }
}
