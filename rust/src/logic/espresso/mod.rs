//! ESPRESSO-II two-level logic minimization (Brayton et al. [36]).
//!
//! The paper feeds every neuron's enumerated truth table through ESPRESSO-II
//! before multi-level synthesis; this module is a faithful in-tree
//! implementation of the classic loop:
//!
//! ```text
//! F ← ISOP(on, dc)            # compact seed (Minato–Morreale)
//! R ← complement(on ∪ dc)     # OFF-set, unate-recursive complement
//! F ← EXPAND(F, R); F ← IRREDUNDANT(F, D)
//! (E, F) ← ESSENTIAL(F, D); D ← D ∪ E
//! repeat
//!     F ← REDUCE(F, D); F ← EXPAND(F, R); F ← IRREDUNDANT(F, D)
//! until cost stops improving
//! return F ∪ E
//! ```
//!
//! Cost is (cube count, literal count), compared lexicographically. The
//! LAST_GASP/SUPER_GASP escape phases of the original are omitted (they
//! matter for large PLAs, not ≤16-input neuron functions); the property
//! suite in `rust/tests/property_logic.rs` checks minimality against a
//! brute-force exact minimizer on small functions.

pub mod essential;
pub mod expand;
pub mod irredundant;
pub mod reduce;

use crate::logic::cube::Cover;
use crate::logic::truthtable::TruthTable;

/// Outcome statistics of a minimization run (recorded by the flow report).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EspressoStats {
    pub initial_cubes: usize,
    pub final_cubes: usize,
    pub final_literals: usize,
    pub iterations: usize,
    pub essential_primes: usize,
}

/// Minimize the incompletely-specified function (`on`, `dc`) given as dense
/// truth tables. Returns a prime, irredundant cover `C` with
/// `on ⊆ C ⊆ on ∪ dc`, plus run statistics.
pub fn minimize_tt(on: &TruthTable, dc: &TruthTable) -> (Cover, EspressoStats) {
    let nvars = on.nvars();
    debug_assert!(on.and(dc).is_zero(), "ON and DC must be disjoint");
    let off_tt = on.or(dc).not();
    let f0 = TruthTable::isop(on, dc);
    let dc_cover = TruthTable::isop(dc, &TruthTable::zeros(nvars));
    let off = TruthTable::isop(&off_tt, &TruthTable::zeros(nvars));
    minimize_covers(&f0, &dc_cover, &off)
}

/// Minimize starting from explicit covers. `off` must be the exact
/// complement of `on ∪ dc` (callers that only have covers can use
/// [`Cover::complement`]).
pub fn minimize_covers(
    f0: &Cover,
    dc: &Cover,
    off: &Cover,
) -> (Cover, EspressoStats) {
    let nvars = f0.nvars();
    let initial_cubes = f0.len();

    // Trivial cases.
    if f0.is_empty() {
        return (
            Cover::empty(nvars),
            EspressoStats {
                initial_cubes,
                final_cubes: 0,
                final_literals: 0,
                iterations: 0,
                essential_primes: 0,
            },
        );
    }
    if off.is_empty() {
        let c = Cover::universe(nvars);
        return (
            c,
            EspressoStats {
                initial_cubes,
                final_cubes: 1,
                final_literals: 0,
                iterations: 0,
                essential_primes: 0,
            },
        );
    }

    let mut f = expand::expand(f0, off);
    f = irredundant::irredundant(&f, dc);

    // Extract essentials and fold them into the DC set for the loop.
    let (ess, non_ess) = essential::partition_essential(&f, dc);
    let dc_loop = dc.union(&ess);
    f = non_ess;

    let mut cost = (f.len(), f.literal_count());
    let mut iterations = 0usize;
    loop {
        iterations += 1;
        let r = reduce::reduce(&f, &dc_loop);
        let e = expand::expand(&r, off);
        let i = irredundant::irredundant(&e, &dc_loop);
        let new_cost = (i.len(), i.literal_count());
        if new_cost < cost {
            f = i;
            cost = new_cost;
        } else {
            // LAST_GASP: reduce each cube maximally *in isolation*, expand
            // the reductions toward covering each other, and re-solve the
            // covering problem over old ∪ new primes. Escapes cyclic traps
            // the sequential REDUCE order cannot.
            let g = last_gasp(&f, &dc_loop, off);
            let g_cost = (g.len(), g.literal_count());
            if g_cost < cost {
                f = g;
                cost = g_cost;
                continue;
            }
            break;
        }
        if iterations > 20 {
            break; // safety net; never hit in practice
        }
    }

    let mut result = f.union(&ess);
    result.sccc_prune();
    let stats = EspressoStats {
        initial_cubes,
        final_cubes: result.len(),
        final_literals: result.literal_count(),
        iterations,
        essential_primes: ess.len(),
    };
    (result, stats)
}

/// LAST_GASP (Brayton et al. §4.7): independent maximal reduction of every
/// cube, pairwise supercube expansion between reduced cubes, then a global
/// IRREDUNDANT over the union of old and new primes.
fn last_gasp(f: &Cover, dc: &Cover, off: &Cover) -> Cover {
    let nvars = f.nvars();
    if f.len() < 2 {
        return f.clone();
    }
    // Maximal reduction of each cube against the ORIGINAL cover.
    let mut reduced: Vec<crate::logic::cube::Cube> = Vec::with_capacity(f.len());
    for (i, c) in f.cubes.iter().enumerate() {
        let mut rest: Vec<crate::logic::cube::Cube> = f
            .cubes
            .iter()
            .enumerate()
            .filter(|(j, _)| *j != i)
            .map(|(_, c)| c.clone())
            .collect();
        rest.extend(dc.cubes.iter().cloned());
        let g = Cover::from_cubes(nvars, rest).cofactor(c);
        if g.is_tautology() {
            continue; // totally redundant; contributes nothing
        }
        let comp = g.complement();
        if comp.is_empty() {
            continue;
        }
        let mut sccc = comp.cubes[0].clone();
        for k in &comp.cubes[1..] {
            sccc = sccc.supercube(k);
        }
        if let Some(r) = c.intersect(&sccc) {
            reduced.push(r);
        }
    }
    // Pairwise supercube expansion: a new prime is interesting iff the
    // supercube of two reduced cubes avoids OFF.
    let mut new_primes: Vec<crate::logic::cube::Cube> = Vec::new();
    for i in 0..reduced.len() {
        for j in (i + 1)..reduced.len() {
            let sc = reduced[i].supercube(&reduced[j]);
            if off.cubes.iter().all(|o| sc.distance(o) > 0) {
                let p = expand::expand_one(&sc, off, nvars);
                if !new_primes.contains(&p) && !f.cubes.contains(&p) {
                    new_primes.push(p);
                }
            }
        }
    }
    if new_primes.is_empty() {
        return f.clone();
    }
    let mut all = f.cubes.clone();
    all.extend(new_primes);
    irredundant::irredundant(&Cover::from_cubes(nvars, all), dc)
}

/// Exact minimum cube count via Quine–McCluskey + exhaustive set cover.
/// Exponential; only used by tests (≤ ~5 vars) as a minimality oracle.
pub fn exact_minimum_cubes(on: &TruthTable, dc: &TruthTable) -> usize {
    let nvars = on.nvars();
    assert!(nvars <= 5, "exact minimizer is a test oracle only");
    // All primes: expand every ON∪DC minterm against OFF.
    let care = on.or(dc);
    let off_tt = care.not();
    let off = TruthTable::isop(&off_tt, &TruthTable::zeros(nvars));
    let mut primes = Vec::new();
    for m in 0..1u64 << nvars {
        if care.eval(m) {
            let p = expand::expand_one(
                &crate::logic::cube::Cube::minterm(nvars, m),
                &off,
                nvars,
            );
            if !primes.contains(&p) {
                primes.push(p);
            }
        }
    }
    // NOTE: greedy expansion from minterms may miss some primes, so grow the
    // set by raising every literal subset (feasible at ≤5 vars: enumerate all
    // cubes and keep implicants that are prime).
    primes.clear();
    let ncubes = 3usize.pow(nvars as u32);
    let mut all: Vec<crate::logic::cube::Cube> = Vec::new();
    for code in 0..ncubes {
        let mut c = crate::logic::cube::Cube::full(nvars);
        let mut rem = code;
        for v in 0..nvars {
            match rem % 3 {
                0 => c.set(v, crate::logic::cube::Pol::Zero),
                1 => c.set(v, crate::logic::cube::Pol::One),
                _ => {}
            }
            rem /= 3;
        }
        // implicant iff disjoint from OFF
        if (0..1u64 << nvars).all(|m| !c.covers_minterm(m) || care.eval(m)) {
            all.push(c);
        }
    }
    for c in &all {
        let prime = !all.iter().any(|d| d != c && d.contains(c));
        if prime {
            primes.push(c.clone());
        }
    }
    // Exhaustive set cover over ON minterms (≤ 32 at 5 vars).
    let on_minterms: Vec<u64> = (0..1u64 << nvars).filter(|&m| on.eval(m)).collect();
    if on_minterms.is_empty() {
        return 0;
    }
    for k in 1..=primes.len() {
        if cover_exists(&primes, &on_minterms, k, 0, &mut Vec::new()) {
            return k;
        }
    }
    unreachable!("primes must cover ON")
}

fn cover_exists(
    primes: &[crate::logic::cube::Cube],
    minterms: &[u64],
    k: usize,
    start: usize,
    chosen: &mut Vec<usize>,
) -> bool {
    if chosen.len() == k {
        return minterms
            .iter()
            .all(|&m| chosen.iter().any(|&i| primes[i].covers_minterm(m)));
    }
    for i in start..primes.len() {
        chosen.push(i);
        if cover_exists(primes, minterms, k, i + 1, chosen) {
            chosen.pop();
            return true;
        }
        chosen.pop();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn minimizes_classic_examples() {
        // f = Σm(0,1,2,5,6,7) over 3 vars — minimum is 3 cubes? This is the
        // classic cyclic cover example; minimum = 3.
        let on = TruthTable::from_fn(3, |m| [0, 1, 2, 5, 6, 7].contains(&m));
        let dc = TruthTable::zeros(3);
        let (c, stats) = minimize_tt(&on, &dc);
        assert_eq!(TruthTable::from_cover(&c), on);
        assert_eq!(c.len(), 3, "cyclic example minimum is 3 cubes\n{c:?}");
        assert_eq!(stats.final_cubes, 3);
    }

    #[test]
    fn exploits_dont_cares() {
        // 7-segment style: ON = {1,2}, DC = {10..15} at 4 vars lets cubes
        // grow across unused codes.
        let on = TruthTable::from_fn(4, |m| m == 1 || m == 2);
        let dc = TruthTable::from_fn(4, |m| m >= 10);
        let (c, _) = minimize_tt(&on, &dc);
        let ctt = TruthTable::from_cover(&c);
        assert!(on.implies(&ctt));
        assert!(ctt.implies(&on.or(&dc)));
        // Without DC this needs 2 cubes of 4 literals; with DC the literal
        // count must not be worse.
        let (c_nodc, _) = minimize_tt(&on, &TruthTable::zeros(4));
        assert!(c.literal_count() <= c_nodc.literal_count());
    }

    #[test]
    fn result_is_prime_and_irredundant() {
        let mut rng = Xoshiro256::new(0x9999);
        for trial in 0..40 {
            let nvars = 2 + (trial % 5);
            let on = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.4));
            let dc = TruthTable::zeros(nvars);
            let (c, _) = minimize_tt(&on, &dc);
            assert_eq!(TruthTable::from_cover(&c), on, "function changed");
            // primality: raising any literal hits OFF
            let off = TruthTable::isop(&on.not(), &TruthTable::zeros(nvars));
            for cube in &c.cubes {
                for v in 0..nvars {
                    use crate::logic::cube::Pol;
                    if cube.get(v) != Pol::DC {
                        let mut r = cube.clone();
                        r.set(v, Pol::DC);
                        assert!(
                            off.cubes.iter().any(|o| r.distance(o) == 0),
                            "cube {cube:?} not prime at var {v}"
                        );
                    }
                }
            }
            // irredundancy
            for i in 0..c.len() {
                let mut cubes = c.cubes.clone();
                cubes.remove(i);
                let smaller = Cover::from_cubes(nvars, cubes);
                assert_ne!(TruthTable::from_cover(&smaller), on, "cube {i} redundant");
            }
        }
    }

    #[test]
    fn matches_exact_minimum_on_small_functions() {
        let mut rng = Xoshiro256::new(0xE5A);
        let mut total_gap = 0usize;
        let mut checked = 0usize;
        for _ in 0..60 {
            let nvars = 3 + (rng.below(2) as usize); // 3..4 vars
            let on = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.45));
            let dc = TruthTable::zeros(nvars);
            let (c, _) = minimize_tt(&on, &dc);
            let exact = exact_minimum_cubes(&on, &dc);
            assert!(c.len() >= exact);
            total_gap += c.len() - exact;
            checked += 1;
            // heuristic should be within 1 cube of optimal on tiny functions
            assert!(
                c.len() <= exact + 1,
                "espresso {} vs exact {} on {on:?}",
                c.len(),
                exact
            );
        }
        // and on average essentially optimal
        assert!(checked > 0 && (total_gap as f64 / checked as f64) < 0.25);
    }

    #[test]
    fn constants() {
        let z = TruthTable::zeros(4);
        let o = TruthTable::ones(4);
        let (c0, _) = minimize_tt(&z, &z);
        assert!(c0.is_empty());
        let (c1, _) = minimize_tt(&o, &z);
        assert_eq!(c1.len(), 1);
        assert_eq!(c1.literal_count(), 0);
    }
}
