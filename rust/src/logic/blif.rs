//! BLIF (Berkeley Logic Interchange Format) emission.
//!
//! Lets the generated circuits flow into real FPGA/ASIC tools (ABC, Yosys,
//! VTR) for independent verification of the LUT counts this repository
//! reports. Pipelined circuits emit `.latch` lines for every register a
//! stage boundary implies.

use crate::logic::cube::Pol;
use crate::logic::netlist::{LutNetlist, PipelinedCircuit, Sig};
use crate::logic::truthtable::TruthTable;

fn sig_name(s: &Sig) -> String {
    match s {
        Sig::Const(false) => "gnd".to_string(),
        Sig::Const(true) => "vcc".to_string(),
        Sig::Input(i) => format!("pi{i}"),
        Sig::Lut(j) => format!("n{j}"),
    }
}

/// Emit a combinational netlist as BLIF.
pub fn netlist_to_blif(nl: &LutNetlist, model_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(".model {model_name}\n"));
    out.push_str(".inputs");
    for i in 0..nl.num_inputs {
        out.push_str(&format!(" pi{i}"));
    }
    out.push('\n');
    out.push_str(".outputs");
    for (j, _) in nl.outputs.iter().enumerate() {
        out.push_str(&format!(" po{j}"));
    }
    out.push('\n');
    // constants (only if referenced)
    let uses_const = nl
        .luts
        .iter()
        .flat_map(|l| l.inputs.iter())
        .chain(nl.outputs.iter().map(|(s, _)| s))
        .any(|s| matches!(s, Sig::Const(_)));
    if uses_const {
        out.push_str(".names gnd\n");
        out.push_str(".names vcc\n1\n");
    }
    for (j, lut) in nl.luts.iter().enumerate() {
        out.push_str(".names");
        for s in &lut.inputs {
            out.push_str(&format!(" {}", sig_name(s)));
        }
        out.push_str(&format!(" n{j}\n"));
        out.push_str(&table_to_pla(&lut.table));
    }
    for (j, (s, inv)) in nl.outputs.iter().enumerate() {
        // buffer / inverter row
        out.push_str(&format!(".names {} po{j}\n", sig_name(s)));
        out.push_str(if *inv { "0 1\n" } else { "1 1\n" });
    }
    out.push_str(".end\n");
    out
}

/// Emit a pipelined circuit: combinational body + `.latch` for each register
/// stage crossing (named `name_sN`).
pub fn pipelined_to_blif(c: &PipelinedCircuit, model_name: &str) -> String {
    // For interchange purposes registers are emitted at stage boundaries on
    // every crossing signal; downstream consumers reference the latched
    // name of the producing signal at their own stage.
    let nl = &c.netlist;
    let mut out = String::new();
    out.push_str(&format!(".model {model_name}\n"));
    out.push_str(".inputs");
    for i in 0..nl.num_inputs {
        out.push_str(&format!(" pi{i}"));
    }
    out.push('\n');
    out.push_str(".outputs");
    for (j, _) in nl.outputs.iter().enumerate() {
        out.push_str(&format!(" po{j}"));
    }
    out.push('\n');
    out.push_str(".names gnd\n.names vcc\n1\n");

    // Name of signal `s` as seen at stage `stage`.
    let stage_of = |s: &Sig| -> i64 {
        match s {
            Sig::Lut(j) => c.stage_of_lut[*j as usize] as i64,
            _ => -1,
        }
    };
    let name_at = |s: &Sig, stage: i64| -> String {
        let p = stage_of(s);
        let base = sig_name(s);
        if matches!(s, Sig::Const(_)) || stage <= p {
            base
        } else {
            format!("{base}_s{stage}")
        }
    };

    // Latches: for each signal and each boundary it crosses.
    use std::collections::HashMap;
    let mut last_use: HashMap<Sig, i64> = HashMap::new();
    for (i, lut) in nl.luts.iter().enumerate() {
        let si = c.stage_of_lut[i] as i64;
        for s in &lut.inputs {
            if !matches!(s, Sig::Const(_)) {
                let e = last_use.entry(*s).or_insert(i64::MIN);
                *e = (*e).max(si);
            }
        }
    }
    for (s, _) in &nl.outputs {
        if !matches!(s, Sig::Const(_)) {
            let e = last_use.entry(*s).or_insert(i64::MIN);
            *e = (*e).max(c.num_stages as i64 - 1);
        }
    }
    let mut latch_lines: Vec<String> = Vec::new();
    for (s, last) in &last_use {
        let p = stage_of(s);
        let mut st = p.max(0) + 1;
        while st <= *last {
            latch_lines.push(format!(
                ".latch {} {} re clk 0\n",
                name_at(s, st - 1),
                format!("{}_s{st}", sig_name(s))
            ));
            st += 1;
        }
    }
    latch_lines.sort();
    for l in &latch_lines {
        out.push_str(l);
    }

    for (j, lut) in nl.luts.iter().enumerate() {
        let si = c.stage_of_lut[j] as i64;
        out.push_str(".names");
        for s in &lut.inputs {
            out.push_str(&format!(" {}", name_at(s, si)));
        }
        out.push_str(&format!(" n{j}\n"));
        out.push_str(&table_to_pla(&lut.table));
    }
    for (j, (s, inv)) in nl.outputs.iter().enumerate() {
        out.push_str(&format!(
            ".names {} po{j}\n",
            name_at(s, c.num_stages as i64 - 1)
        ));
        out.push_str(if *inv { "0 1\n" } else { "1 1\n" });
    }
    out.push_str(".end\n");
    out
}

/// PLA rows for a LUT function (via ISOP so emitted BLIF stays compact).
fn table_to_pla(t: &TruthTable) -> String {
    if t.is_zero() {
        return String::new(); // no rows = constant 0 in BLIF
    }
    if t.nvars() == 0 {
        return "1\n".to_string();
    }
    let cover = TruthTable::isop(t, &TruthTable::zeros(t.nvars()));
    let mut s = String::new();
    for cube in &cover.cubes {
        for v in 0..t.nvars() {
            s.push(match cube.get(v) {
                Pol::Zero => '0',
                Pol::One => '1',
                Pol::DC => '-',
                Pol::Empty => unreachable!("empty cube in ISOP"),
            });
        }
        s.push_str(" 1\n");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::netlist::Sig;

    fn simple_netlist() -> LutNetlist {
        let mut nl = LutNetlist::new(3);
        let xor = TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 1);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor.clone());
        let b = nl.add_lut(vec![a, Sig::Input(2)], xor);
        nl.add_output(b, false);
        nl.add_output(a, true);
        nl
    }

    #[test]
    fn blif_structure() {
        let blif = netlist_to_blif(&simple_netlist(), "parity3");
        assert!(blif.starts_with(".model parity3\n"));
        assert!(blif.contains(".inputs pi0 pi1 pi2"));
        assert!(blif.contains(".outputs po0 po1"));
        assert!(blif.contains(".names pi0 pi1 n0"));
        assert!(blif.contains(".names n0 pi2 n1"));
        // inverter row for po1
        assert!(blif.contains(".names n0 po1\n0 1"));
        assert!(blif.ends_with(".end\n"));
    }

    #[test]
    fn xor_rows_cover_exactly_odd_minterms() {
        let blif = netlist_to_blif(&simple_netlist(), "m");
        // xor PLA: rows "01 1" and "10 1"
        assert!(blif.contains("01 1\n") && blif.contains("10 1\n"));
    }

    #[test]
    fn pipelined_emits_latches() {
        let nl = simple_netlist();
        let c = PipelinedCircuit {
            netlist: nl,
            stage_of_lut: vec![0, 1],
            num_stages: 2,
        };
        let blif = pipelined_to_blif(&c, "piped");
        assert!(blif.contains(".latch"), "stage crossing must produce a latch:\n{blif}");
        // n0 crosses boundary 0→1
        assert!(blif.contains(".latch n0 n0_s1"));
        // consumer at stage 1 reads the latched name
        assert!(blif.contains(".names n0_s1 pi2_s1 n1") || blif.contains("n0_s1"));
    }

    #[test]
    fn constant_zero_lut_has_no_rows() {
        let mut nl = LutNetlist::new(1);
        let z = nl.add_lut(vec![Sig::Input(0)], TruthTable::zeros(1));
        nl.add_output(z, false);
        let blif = netlist_to_blif(&nl, "z");
        // ".names pi0 n0" followed immediately by output buffer section
        assert!(blif.contains(".names pi0 n0\n.names"));
    }
}
