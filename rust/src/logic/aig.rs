//! And-inverter graphs with structural hashing.
//!
//! The multi-level optimization substrate (the role Vivado's synthesis
//! engine plays in the paper). Minimized SOPs from ESPRESSO are factored
//! into balanced AND/OR trees over an AIG; structural hashing, constant
//! propagation, and two-level local rules (`a∧a = a`, `a∧¬a = 0`) remove
//! redundant structure across neuron boundaries *for free* — two neurons
//! that compute the same subfunction share nodes, which is one source of the
//! paper's LUT reductions vs LogicNets.
//!
//! Literals are `2·node + inverted` (`lit 0` = constant false, `lit 1` =
//! constant true, node 0 is reserved for the constant).

use std::collections::HashMap;

use crate::logic::cube::{Cover, Pol};

/// An AIG literal: node index shifted left once, LSB = inversion flag.
pub type Lit = u32;

/// Constant false literal.
pub const LIT_FALSE: Lit = 0;
/// Constant true literal.
pub const LIT_TRUE: Lit = 1;

/// Complement a literal.
#[inline]
pub fn lit_not(l: Lit) -> Lit {
    l ^ 1
}

/// Node index of a literal.
#[inline]
pub fn lit_node(l: Lit) -> usize {
    (l >> 1) as usize
}

/// Is the literal inverted?
#[inline]
pub fn lit_inv(l: Lit) -> bool {
    l & 1 == 1
}

/// One AIG node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Node {
    /// The constant-false node (index 0 only).
    Const,
    /// Primary input with an external index.
    Input(u32),
    /// Two-input AND of literals (canonical order: `a ≤ b`).
    And(Lit, Lit),
}

/// And-inverter graph with structural hashing and multiple outputs.
#[derive(Clone, Debug)]
pub struct Aig {
    nodes: Vec<Node>,
    strash: HashMap<(Lit, Lit), Lit>,
    outputs: Vec<Lit>,
    num_inputs: u32,
}

impl Default for Aig {
    fn default() -> Self {
        Self::new()
    }
}

impl Aig {
    /// Empty graph (just the constant node).
    pub fn new() -> Aig {
        Aig {
            nodes: vec![Node::Const],
            strash: HashMap::new(),
            outputs: Vec::new(),
            num_inputs: 0,
        }
    }

    /// Add a primary input; returns its literal.
    pub fn add_input(&mut self) -> Lit {
        let idx = self.num_inputs;
        self.num_inputs += 1;
        self.nodes.push(Node::Input(idx));
        ((self.nodes.len() - 1) as u32) << 1
    }

    /// Number of primary inputs.
    pub fn num_inputs(&self) -> u32 {
        self.num_inputs
    }

    /// Number of nodes (including constant and inputs).
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Number of AND nodes (the size metric optimizers report).
    pub fn num_ands(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::And(..))).count()
    }

    /// Node accessor.
    pub fn node(&self, i: usize) -> Node {
        self.nodes[i]
    }

    /// Register an output literal; returns its output index.
    pub fn add_output(&mut self, l: Lit) -> usize {
        self.outputs.push(l);
        self.outputs.len() - 1
    }

    /// Output literals.
    pub fn outputs(&self) -> &[Lit] {
        &self.outputs
    }

    /// AND with structural hashing and local simplification.
    pub fn and(&mut self, a: Lit, b: Lit) -> Lit {
        // Constant / trivial rules.
        if a == LIT_FALSE || b == LIT_FALSE {
            return LIT_FALSE;
        }
        if a == LIT_TRUE {
            return b;
        }
        if b == LIT_TRUE {
            return a;
        }
        if a == b {
            return a;
        }
        if a == lit_not(b) {
            return LIT_FALSE;
        }
        let (a, b) = if a <= b { (a, b) } else { (b, a) };
        if let Some(&l) = self.strash.get(&(a, b)) {
            return l;
        }
        self.nodes.push(Node::And(a, b));
        let l = ((self.nodes.len() - 1) as u32) << 1;
        self.strash.insert((a, b), l);
        l
    }

    /// OR via De Morgan.
    pub fn or(&mut self, a: Lit, b: Lit) -> Lit {
        let n = self.and(lit_not(a), lit_not(b));
        lit_not(n)
    }

    /// XOR (three ANDs after strashing).
    pub fn xor(&mut self, a: Lit, b: Lit) -> Lit {
        let n_ab = self.and(a, lit_not(b));
        let n_ba = self.and(lit_not(a), b);
        self.or(n_ab, n_ba)
    }

    /// 2:1 multiplexer `s ? t : e`.
    pub fn mux(&mut self, s: Lit, t: Lit, e: Lit) -> Lit {
        let a = self.and(s, t);
        let b = self.and(lit_not(s), e);
        self.or(a, b)
    }

    /// Balanced AND over many literals (logic depth ⌈log₂ n⌉).
    pub fn and_many(&mut self, lits: &[Lit]) -> Lit {
        self.tree(lits, true)
    }

    /// Balanced OR over many literals.
    pub fn or_many(&mut self, lits: &[Lit]) -> Lit {
        self.tree(lits, false)
    }

    fn tree(&mut self, lits: &[Lit], is_and: bool) -> Lit {
        match lits.len() {
            0 => {
                if is_and {
                    LIT_TRUE
                } else {
                    LIT_FALSE
                }
            }
            1 => lits[0],
            n => {
                let (lo, hi) = lits.split_at(n / 2);
                let a = self.tree(lo, is_and);
                let b = self.tree(hi, is_and);
                if is_and {
                    self.and(a, b)
                } else {
                    self.or(a, b)
                }
            }
        }
    }

    /// Build the literal computing SOP `cover` over `input_lits` (one literal
    /// per cover variable). This is how ESPRESSO results enter the AIG.
    pub fn from_cover(&mut self, cover: &Cover, input_lits: &[Lit]) -> Lit {
        assert_eq!(cover.nvars(), input_lits.len());
        let mut terms = Vec::with_capacity(cover.len());
        for cube in &cover.cubes {
            let mut lits = Vec::new();
            for (v, &il) in input_lits.iter().enumerate() {
                match cube.get(v) {
                    Pol::One => lits.push(il),
                    Pol::Zero => lits.push(lit_not(il)),
                    Pol::DC => {}
                    Pol::Empty => return LIT_FALSE,
                }
            }
            terms.push(self.and_many(&lits));
        }
        self.or_many(&terms)
    }

    /// Logic level of every node (inputs/const at level 0).
    pub fn levels(&self) -> Vec<u32> {
        let mut lv = vec![0u32; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            if let Node::And(a, b) = n {
                lv[i] = 1 + lv[lit_node(*a)].max(lv[lit_node(*b)]);
            }
        }
        lv
    }

    /// Depth of the graph at its outputs.
    pub fn depth(&self) -> u32 {
        let lv = self.levels();
        self.outputs.iter().map(|&o| lv[lit_node(o)]).max().unwrap_or(0)
    }

    /// 64-way bit-parallel simulation: `input_words[i]` carries 64 samples
    /// of input `i`; returns one word per output.
    pub fn simulate_words(&self, input_words: &[u64]) -> Vec<u64> {
        assert_eq!(input_words.len(), self.num_inputs as usize);
        let mut val = vec![0u64; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            val[i] = match n {
                Node::Const => 0,
                Node::Input(k) => input_words[*k as usize],
                Node::And(a, b) => {
                    let va = val[lit_node(*a)] ^ if lit_inv(*a) { !0 } else { 0 };
                    let vb = val[lit_node(*b)] ^ if lit_inv(*b) { !0 } else { 0 };
                    va & vb
                }
            };
        }
        self.outputs
            .iter()
            .map(|&o| val[lit_node(o)] ^ if lit_inv(o) { !0 } else { 0 })
            .collect()
    }

    /// Evaluate one assignment (bit `i` of `input_bits` = input `i`).
    pub fn eval(&self, input_bits: u64) -> Vec<bool> {
        let words: Vec<u64> = (0..self.num_inputs as usize)
            .map(|i| if (input_bits >> i) & 1 == 1 { !0u64 } else { 0 })
            .collect();
        self.simulate_words(&words).iter().map(|&w| w & 1 == 1).collect()
    }

    /// Garbage-collect nodes unreachable from the outputs; returns the
    /// compacted AIG (node/literal identities change).
    pub fn sweep(&self) -> Aig {
        let mut mark = vec![false; self.nodes.len()];
        mark[0] = true;
        // Inputs always survive (their external indices must stay dense).
        for (i, n) in self.nodes.iter().enumerate() {
            if matches!(n, Node::Input(_)) {
                mark[i] = true;
            }
        }
        let mut stack: Vec<usize> = self.outputs.iter().map(|&o| lit_node(o)).collect();
        while let Some(i) = stack.pop() {
            if mark[i] {
                continue;
            }
            mark[i] = true;
            if let Node::And(a, b) = self.nodes[i] {
                stack.push(lit_node(a));
                stack.push(lit_node(b));
            }
        }
        let mut remap = vec![u32::MAX; self.nodes.len()];
        let mut out = Aig::new();
        out.num_inputs = self.num_inputs;
        remap[0] = 0;
        for (i, n) in self.nodes.iter().enumerate().skip(1) {
            if !mark[i] {
                continue;
            }
            let new_idx = out.nodes.len() as u32;
            match n {
                Node::Const => unreachable!(),
                Node::Input(k) => out.nodes.push(Node::Input(*k)),
                Node::And(a, b) => {
                    let ra = (remap[lit_node(*a)] << 1) | (*a & 1);
                    let rb = (remap[lit_node(*b)] << 1) | (*b & 1);
                    let (ra, rb) = if ra <= rb { (ra, rb) } else { (rb, ra) };
                    out.nodes.push(Node::And(ra, rb));
                    out.strash.insert((ra, rb), new_idx << 1);
                }
            }
            remap[i] = new_idx;
        }
        out.outputs = self
            .outputs
            .iter()
            .map(|&o| (remap[lit_node(o)] << 1) | (o & 1))
            .collect();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::truthtable::TruthTable;

    #[test]
    fn constant_rules() {
        let mut g = Aig::new();
        let a = g.add_input();
        assert_eq!(g.and(a, LIT_FALSE), LIT_FALSE);
        assert_eq!(g.and(a, LIT_TRUE), a);
        assert_eq!(g.and(a, a), a);
        assert_eq!(g.and(a, lit_not(a)), LIT_FALSE);
        assert_eq!(g.num_ands(), 0, "no nodes created by trivial rules");
    }

    #[test]
    fn strashing_dedupes() {
        let mut g = Aig::new();
        let a = g.add_input();
        let b = g.add_input();
        let x = g.and(a, b);
        let y = g.and(b, a);
        assert_eq!(x, y);
        assert_eq!(g.num_ands(), 1);
    }

    #[test]
    fn xor_truth() {
        let mut g = Aig::new();
        let a = g.add_input();
        let b = g.add_input();
        let x = g.xor(a, b);
        g.add_output(x);
        for bits in 0..4u64 {
            let want = ((bits & 1) ^ ((bits >> 1) & 1)) == 1;
            assert_eq!(g.eval(bits)[0], want, "bits={bits:02b}");
        }
    }

    #[test]
    fn mux_truth() {
        let mut g = Aig::new();
        let s = g.add_input();
        let t = g.add_input();
        let e = g.add_input();
        let m = g.mux(s, t, e);
        g.add_output(m);
        for bits in 0..8u64 {
            let (sv, tv, ev) = (bits & 1 == 1, (bits >> 1) & 1 == 1, (bits >> 2) & 1 == 1);
            let want = if sv { tv } else { ev };
            assert_eq!(g.eval(bits)[0], want);
        }
    }

    #[test]
    fn and_many_is_balanced() {
        let mut g = Aig::new();
        let ins: Vec<Lit> = (0..8).map(|_| g.add_input()).collect();
        let out = g.and_many(&ins);
        g.add_output(out);
        assert_eq!(g.depth(), 3, "8-input AND should have depth log2(8)=3");
        assert!(g.eval(0xFF)[0]);
        assert!(!g.eval(0x7F)[0]);
    }

    #[test]
    fn from_cover_matches_sop_semantics() {
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(0xA16);
        for trial in 0..40 {
            let nvars = 2 + trial % 5;
            let tt = TruthTable::from_fn(nvars, |_| rng.bernoulli(0.4));
            let cover = TruthTable::isop(&tt, &TruthTable::zeros(nvars));
            let mut g = Aig::new();
            let ins: Vec<Lit> = (0..nvars).map(|_| g.add_input()).collect();
            let o = g.from_cover(&cover, &ins);
            g.add_output(o);
            for m in 0..1u64 << nvars {
                assert_eq!(g.eval(m)[0], tt.eval(m), "m={m} trial={trial}");
            }
        }
    }

    #[test]
    fn shared_structure_across_outputs() {
        // Two identical functions must share all AND nodes.
        let mut g = Aig::new();
        let a = g.add_input();
        let b = g.add_input();
        let c = g.add_input();
        let f1 = {
            let t = g.and(a, b);
            g.or(t, c)
        };
        let f2 = {
            let t = g.and(b, a);
            g.or(t, c)
        };
        assert_eq!(f1, f2);
        g.add_output(f1);
        g.add_output(f2);
        assert_eq!(g.num_ands(), 2);
    }

    #[test]
    fn simulate_words_matches_eval() {
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(0x51A);
        let mut g = Aig::new();
        let ins: Vec<Lit> = (0..5).map(|_| g.add_input()).collect();
        let x = g.xor(ins[0], ins[1]);
        let y = g.and(ins[2], x);
        let z = g.mux(ins[3], y, ins[4]);
        g.add_output(z);
        g.add_output(y);
        let words: Vec<u64> = (0..5).map(|_| rng.next_u64()).collect();
        let out = g.simulate_words(&words);
        for lane in 0..64 {
            let bits: u64 = (0..5).map(|i| ((words[i] >> lane) & 1) << i).sum();
            let e = g.eval(bits);
            assert_eq!((out[0] >> lane) & 1 == 1, e[0]);
            assert_eq!((out[1] >> lane) & 1 == 1, e[1]);
        }
    }

    #[test]
    fn sweep_removes_dead_nodes() {
        let mut g = Aig::new();
        let a = g.add_input();
        let b = g.add_input();
        let keep = g.and(a, b);
        let _dead = g.or(a, b); // never used as output
        g.add_output(keep);
        let swept = g.sweep();
        assert_eq!(swept.num_ands(), 1);
        assert_eq!(swept.num_inputs(), 2);
        for m in 0..4u64 {
            assert_eq!(swept.eval(m)[0], g.eval(m)[0]);
        }
    }

    #[test]
    fn sweep_preserves_multi_output_semantics() {
        let mut g = Aig::new();
        let ins: Vec<Lit> = (0..4).map(|_| g.add_input()).collect();
        let f1 = g.xor(ins[0], ins[2]);
        let f2 = g.and_many(&ins);
        let _dead = g.or(ins[1], ins[3]);
        g.add_output(f1);
        g.add_output(lit_not(f2));
        let swept = g.sweep();
        for m in 0..16u64 {
            assert_eq!(swept.eval(m), g.eval(m));
        }
        assert!(swept.num_nodes() < g.num_nodes());
    }
}
