//! Synthesizable Verilog emission.
//!
//! Produces the RTL a user would hand to Vivado to reproduce the paper's
//! hardware numbers on a real VU9P: one `assign`/`LUT` expression per mapped
//! LUT and a register stage per pipeline boundary. LUT functions are emitted
//! as sums of products from their ISOP covers.

use crate::logic::cube::Pol;
use crate::logic::netlist::{LutNetlist, PipelinedCircuit, Sig};
use crate::logic::truthtable::TruthTable;

fn sig_expr(s: &Sig) -> String {
    match s {
        Sig::Const(false) => "1'b0".to_string(),
        Sig::Const(true) => "1'b1".to_string(),
        Sig::Input(i) => format!("pi[{i}]"),
        Sig::Lut(j) => format!("n{j}"),
    }
}

/// SOP expression for a LUT over named input expressions.
fn lut_expr(table: &TruthTable, inputs: &[String]) -> String {
    if table.is_zero() {
        return "1'b0".to_string();
    }
    if table.is_ones() {
        return "1'b1".to_string();
    }
    let cover = TruthTable::isop(table, &TruthTable::zeros(table.nvars()));
    let mut terms = Vec::new();
    for cube in &cover.cubes {
        let mut lits = Vec::new();
        for (v, name) in inputs.iter().enumerate() {
            match cube.get(v) {
                Pol::One => lits.push(name.clone()),
                Pol::Zero => lits.push(format!("~{name}")),
                Pol::DC => {}
                Pol::Empty => unreachable!(),
            }
        }
        terms.push(if lits.is_empty() {
            "1'b1".to_string()
        } else {
            lits.join(" & ")
        });
    }
    terms
        .iter()
        .map(|t| format!("({t})"))
        .collect::<Vec<_>>()
        .join(" | ")
}

/// Emit a combinational netlist as a Verilog module.
pub fn netlist_to_verilog(nl: &LutNetlist, module_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "module {module_name} (\n    input  wire [{}:0] pi,\n    output wire [{}:0] po\n);\n",
        nl.num_inputs.max(1) - 1,
        nl.outputs.len().max(1) - 1
    ));
    for (j, lut) in nl.luts.iter().enumerate() {
        let ins: Vec<String> = lut.inputs.iter().map(sig_expr).collect();
        out.push_str(&format!(
            "    wire n{j};\n    assign n{j} = {};\n",
            lut_expr(&lut.table, &ins)
        ));
    }
    for (j, (s, inv)) in nl.outputs.iter().enumerate() {
        let e = sig_expr(s);
        out.push_str(&format!(
            "    assign po[{j}] = {}{e};\n",
            if *inv { "~" } else { "" }
        ));
    }
    out.push_str("endmodule\n");
    out
}

/// Emit a pipelined circuit: registered inputs, a register stage after every
/// pipeline boundary, registered outputs (the fmax-measurement convention).
pub fn pipelined_to_verilog(c: &PipelinedCircuit, module_name: &str) -> String {
    let nl = &c.netlist;
    let mut out = String::new();
    out.push_str(&format!(
        "module {module_name} (\n    input  wire clk,\n    input  wire [{}:0] pi,\n    output reg  [{}:0] po\n);\n",
        nl.num_inputs.max(1) - 1,
        nl.outputs.len().max(1) - 1
    ));
    // Input registers.
    out.push_str(&format!(
        "    reg [{}:0] pi_q;\n    always @(posedge clk) pi_q <= pi;\n",
        nl.num_inputs.max(1) - 1
    ));
    let stage_of = |s: &Sig| -> i64 {
        match s {
            Sig::Lut(j) => c.stage_of_lut[*j as usize] as i64,
            _ => -1,
        }
    };
    // Registered aliases for crossing signals.
    use std::collections::HashMap;
    let mut last_use: HashMap<Sig, i64> = HashMap::new();
    for (i, lut) in nl.luts.iter().enumerate() {
        let si = c.stage_of_lut[i] as i64;
        for s in &lut.inputs {
            if !matches!(s, Sig::Const(_)) {
                let e = last_use.entry(*s).or_insert(i64::MIN);
                *e = (*e).max(si);
            }
        }
    }
    for (s, _) in &nl.outputs {
        if !matches!(s, Sig::Const(_)) {
            let e = last_use.entry(*s).or_insert(i64::MIN);
            *e = (*e).max(c.num_stages as i64 - 1);
        }
    }
    let base_name = |s: &Sig| -> String {
        match s {
            Sig::Input(i) => format!("pi_q[{i}]"),
            Sig::Lut(j) => format!("n{j}"),
            Sig::Const(b) => format!("1'b{}", *b as u8),
        }
    };
    let flat = |s: &Sig| -> String {
        match s {
            Sig::Input(i) => format!("pi{i}"),
            Sig::Lut(j) => format!("n{j}"),
            Sig::Const(_) => unreachable!(),
        }
    };
    let name_at = |s: &Sig, stage: i64| -> String {
        let p = stage_of(s);
        if matches!(s, Sig::Const(_)) || stage <= p.max(0) {
            base_name(s)
        } else {
            format!("{}_s{stage}", flat(s))
        }
    };
    // Emit pipeline registers, ordered for readability.
    let mut regs: Vec<String> = Vec::new();
    for (s, last) in &last_use {
        let p = stage_of(s);
        let mut st = p.max(0) + 1;
        while st <= *last {
            regs.push(format!(
                "    reg {n}; always @(posedge clk) {n} <= {prev};\n",
                n = format!("{}_s{st}", flat(s)),
                prev = name_at(s, st - 1),
            ));
            st += 1;
        }
    }
    regs.sort();
    for r in &regs {
        out.push_str(r);
    }
    // Combinational LUTs reading stage-local names.
    for (j, lut) in nl.luts.iter().enumerate() {
        let si = c.stage_of_lut[j] as i64;
        let ins: Vec<String> = lut.inputs.iter().map(|s| name_at(s, si)).collect();
        out.push_str(&format!(
            "    wire n{j};\n    assign n{j} = {};\n",
            lut_expr(&lut.table, &ins)
        ));
    }
    // Output registers.
    out.push_str("    always @(posedge clk) begin\n");
    for (j, (s, inv)) in nl.outputs.iter().enumerate() {
        let e = name_at(s, c.num_stages as i64 - 1);
        out.push_str(&format!(
            "        po[{j}] <= {}{e};\n",
            if *inv { "~" } else { "" }
        ));
    }
    out.push_str("    end\nendmodule\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::netlist::Sig;

    fn simple_netlist() -> LutNetlist {
        let mut nl = LutNetlist::new(3);
        let xor = TruthTable::from_fn(2, |m| (m.count_ones() & 1) == 1);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], xor.clone());
        let b = nl.add_lut(vec![a, Sig::Input(2)], xor);
        nl.add_output(b, false);
        nl.add_output(a, true);
        nl
    }

    #[test]
    fn verilog_module_shape() {
        let v = netlist_to_verilog(&simple_netlist(), "parity3");
        assert!(v.starts_with("module parity3"));
        assert!(v.contains("input  wire [2:0] pi"));
        assert!(v.contains("output wire [1:0] po"));
        assert!(v.contains("assign n0 ="));
        assert!(v.contains("assign po[1] = ~n0;"));
        assert!(v.ends_with("endmodule\n"));
    }

    #[test]
    fn xor_expression() {
        let v = netlist_to_verilog(&simple_netlist(), "m");
        // xor of pi[0], pi[1]: two product terms
        assert!(
            v.contains("(~pi[0] & pi[1]) | (pi[0] & ~pi[1])")
                || v.contains("(pi[0] & ~pi[1]) | (~pi[0] & pi[1])"),
            "{v}"
        );
    }

    #[test]
    fn pipelined_has_clk_and_regs() {
        let c = PipelinedCircuit {
            netlist: simple_netlist(),
            stage_of_lut: vec![0, 1],
            num_stages: 2,
        };
        let v = pipelined_to_verilog(&c, "piped");
        assert!(v.contains("input  wire clk"));
        assert!(v.contains("pi_q <= pi"));
        assert!(v.contains("n0_s1"), "crossing signal must be registered:\n{v}");
        assert!(v.contains("po[0] <="));
    }

    #[test]
    fn constant_luts() {
        let mut nl = LutNetlist::new(1);
        let z = nl.add_lut(vec![Sig::Input(0)], TruthTable::zeros(1));
        let o = nl.add_lut(vec![Sig::Input(0)], TruthTable::ones(1));
        nl.add_output(z, false);
        nl.add_output(o, false);
        let v = netlist_to_verilog(&nl, "consts");
        assert!(v.contains("assign n0 = 1'b0;"));
        assert!(v.contains("assign n1 = 1'b1;"));
    }
}
