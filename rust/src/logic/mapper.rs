//! K-feasible-cut LUT technology mapping (the ABC-style "if" mapper).
//!
//! Maps an [`Aig`] onto k-input LUTs using priority cuts: every AND node
//! keeps a small, dominance-pruned set of cuts ranked by (arrival depth,
//! area flow); mapping extraction walks the best cuts from the outputs. The
//! LUT function for each selected cut is derived by dense simulation of the
//! cut's cone. Depth-optimal for the stored cut sets (the standard
//! guarantee); area is first-order optimized via area flow and can be
//! traded with [`MapConfig::sort_by_area`].

use std::collections::HashMap;

use crate::logic::aig::{lit_inv, lit_node, Aig, Node};
use crate::logic::netlist::{LutNetlist, Sig};
use crate::logic::truthtable::TruthTable;

/// Mapper configuration.
#[derive(Clone, Copy, Debug)]
pub struct MapConfig {
    /// LUT input count (VU9P native: 6).
    pub k: usize,
    /// Cuts retained per node.
    pub cuts_per_node: usize,
    /// Rank primarily by area flow instead of depth.
    pub sort_by_area: bool,
}

impl Default for MapConfig {
    fn default() -> Self {
        MapConfig { k: 6, cuts_per_node: 8, sort_by_area: false }
    }
}

/// One cut: sorted leaf node indices.
#[derive(Clone, Debug, PartialEq)]
struct Cut {
    leaves: Vec<u32>,
    depth: u32,
    area_flow: f32,
}

impl Cut {
    fn dominates(&self, other: &Cut) -> bool {
        self.leaves.len() <= other.leaves.len()
            && self.leaves.iter().all(|l| other.leaves.contains(l))
    }
}

fn merge_leaves(a: &[u32], b: &[u32], k: usize) -> Option<Vec<u32>> {
    let mut out = Vec::with_capacity(k + 1);
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        let next = if i < a.len() && (j >= b.len() || a[i] <= b[j]) {
            if j < b.len() && a[i] == b[j] {
                j += 1;
            }
            let v = a[i];
            i += 1;
            v
        } else {
            let v = b[j];
            j += 1;
            v
        };
        out.push(next);
        if out.len() > k {
            return None;
        }
    }
    Some(out)
}

/// Result of mapping: the netlist plus per-output provenance.
pub struct MapResult {
    pub netlist: LutNetlist,
    /// Mapped depth (LUT levels on the critical path).
    pub depth: u32,
}

/// Map `aig` to a K-LUT netlist.
pub fn map_aig(aig: &Aig, cfg: &MapConfig) -> MapResult {
    let n = aig.num_nodes();
    let mut cuts: Vec<Vec<Cut>> = vec![Vec::new(); n];
    let mut arrival: Vec<u32> = vec![0; n];
    // Reference counts for area flow (fanout estimation).
    let mut nref: Vec<u32> = vec![0; n];
    for i in 0..n {
        if let Node::And(a, b) = aig.node(i) {
            nref[lit_node(a)] += 1;
            nref[lit_node(b)] += 1;
        }
    }
    for &o in aig.outputs() {
        nref[lit_node(o)] += 1;
    }

    for i in 0..n {
        match aig.node(i) {
            Node::Const => {
                cuts[i] = vec![Cut { leaves: vec![], depth: 0, area_flow: 0.0 }];
            }
            Node::Input(_) => {
                cuts[i] =
                    vec![Cut { leaves: vec![i as u32], depth: 0, area_flow: 0.0 }];
                arrival[i] = 0;
            }
            Node::And(la, lb) => {
                let (na, nb) = (lit_node(la), lit_node(lb));
                let mut set: Vec<Cut> = Vec::new();
                for ca in &cuts[na] {
                    for cb in &cuts[nb] {
                        if let Some(leaves) = merge_leaves(&ca.leaves, &cb.leaves, cfg.k)
                        {
                            let depth = 1 + leaves
                                .iter()
                                .map(|&l| arrival[l as usize])
                                .max()
                                .unwrap_or(0);
                            let area_flow = 1.0
                                + leaves
                                    .iter()
                                    .map(|&l| {
                                        let refs = nref[l as usize].max(1) as f32;
                                        flow_of(&cuts[l as usize]) / refs
                                    })
                                    .sum::<f32>();
                            let cut = Cut { leaves, depth, area_flow };
                            if !set.iter().any(|c| c.dominates(&cut) && c.depth <= cut.depth) {
                                set.retain(|c| {
                                    !(cut.dominates(c) && cut.depth <= c.depth)
                                });
                                set.push(cut);
                            }
                        }
                    }
                }
                // Rank and truncate.
                if cfg.sort_by_area {
                    set.sort_by(|x, y| {
                        (x.area_flow, x.depth, x.leaves.len())
                            .partial_cmp(&(y.area_flow, y.depth, y.leaves.len()))
                            .unwrap()
                    });
                } else {
                    set.sort_by(|x, y| {
                        (x.depth, x.area_flow, x.leaves.len())
                            .partial_cmp(&(y.depth, y.area_flow, y.leaves.len()))
                            .unwrap()
                    });
                }
                set.truncate(cfg.cuts_per_node.max(1));
                // Trivial cut last (keeps node itself representable as leaf
                // of upstream cuts).
                arrival[i] = set.first().map(|c| c.depth).unwrap_or(0);
                set.push(Cut {
                    leaves: vec![i as u32],
                    depth: arrival[i],
                    area_flow: flow_of(&set),
                });
                cuts[i] = set;
            }
        }
    }

    // --- extraction ---
    let mut netlist = LutNetlist::new(aig.num_inputs() as usize);
    // node -> already-emitted signal
    let mut emitted: HashMap<u32, Sig> = HashMap::new();

    // Map every output cone.
    let mut out_specs = Vec::new();
    for &o in aig.outputs() {
        let node = lit_node(o) as u32;
        let sig = emit_node(aig, node, &cuts, cfg, &mut emitted, &mut netlist);
        out_specs.push((sig, lit_inv(o)));
    }
    for (sig, inv) in out_specs {
        netlist.add_output(sig, inv);
    }
    let depth = netlist.depth();
    MapResult { netlist, depth }
}

fn flow_of(set: &[Cut]) -> f32 {
    set.first().map(|c| c.area_flow).unwrap_or(0.0)
}

/// Emit the LUT implementing `node` (choosing its best cut), recursively
/// emitting leaf nodes first. Inputs/consts are returned directly.
fn emit_node(
    aig: &Aig,
    node: u32,
    cuts: &[Vec<Cut>],
    cfg: &MapConfig,
    emitted: &mut HashMap<u32, Sig>,
    netlist: &mut LutNetlist,
) -> Sig {
    if let Some(s) = emitted.get(&node) {
        return *s;
    }
    let sig = match aig.node(node as usize) {
        Node::Const => Sig::Const(false),
        Node::Input(k) => Sig::Input(k),
        Node::And(..) => {
            // Best non-trivial cut (first in ranked order that isn't the
            // node itself).
            let cut = cuts[node as usize]
                .iter()
                .find(|c| c.leaves != [node])
                .expect("AND node must have a non-trivial cut")
                .clone();
            let leaf_sigs: Vec<Sig> = cut
                .leaves
                .iter()
                .map(|&l| emit_node(aig, l, cuts, cfg, emitted, netlist))
                .collect();
            let table = cone_truth_table(aig, node, &cut.leaves);
            netlist.add_lut(leaf_sigs, table)
        }
    };
    emitted.insert(node, sig);
    sig
}

/// Dense truth table of `node` as a function of `leaves` (≤ k inputs),
/// computed by simulating the cone with projection tables at the leaves.
pub fn cone_truth_table(aig: &Aig, node: u32, leaves: &[u32]) -> TruthTable {
    let k = leaves.len();
    let mut memo: HashMap<u32, TruthTable> = HashMap::new();
    for (i, &l) in leaves.iter().enumerate() {
        memo.insert(l, TruthTable::var(k, i));
    }
    fn rec(aig: &Aig, n: u32, memo: &mut HashMap<u32, TruthTable>, k: usize) -> TruthTable {
        if let Some(t) = memo.get(&n) {
            return t.clone();
        }
        let t = match aig.node(n as usize) {
            Node::Const => TruthTable::zeros(k),
            Node::Input(_) => {
                panic!("input {n} reached without being a leaf — bad cut")
            }
            Node::And(la, lb) => {
                let ta = rec(aig, lit_node(la) as u32, memo, k);
                let ta = if lit_inv(la) { ta.not() } else { ta };
                let tb = rec(aig, lit_node(lb) as u32, memo, k);
                let tb = if lit_inv(lb) { tb.not() } else { tb };
                ta.and(&tb)
            }
        };
        memo.insert(n, t.clone());
        t
    }
    rec(aig, node, &mut memo, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::aig::{lit_not, Lit};
    use crate::util::prng::Xoshiro256;

    /// Build a random AIG with `nin` inputs and `nops` random ops.
    fn random_aig(nin: usize, nops: usize, seed: u64) -> Aig {
        let mut rng = Xoshiro256::new(seed);
        let mut g = Aig::new();
        let mut pool: Vec<Lit> = (0..nin).map(|_| g.add_input()).collect();
        for _ in 0..nops {
            let a = pool[rng.below(pool.len() as u64) as usize];
            let b = pool[rng.below(pool.len() as u64) as usize];
            let l = match rng.below(3) {
                0 => g.and(a, b),
                1 => g.or(a, b),
                _ => g.xor(a, b),
            };
            pool.push(if rng.bernoulli(0.3) { lit_not(l) } else { l });
        }
        // a few outputs from the end of the pool
        for i in 0..3.min(pool.len()) {
            let l = pool[pool.len() - 1 - i];
            g.add_output(l);
        }
        g
    }

    #[test]
    fn maps_xor_chain_functionally() {
        let mut g = Aig::new();
        let ins: Vec<Lit> = (0..8).map(|_| g.add_input()).collect();
        let mut acc = ins[0];
        for &l in &ins[1..] {
            acc = g.xor(acc, l);
        }
        g.add_output(acc);
        let res = map_aig(&g, &MapConfig::default());
        for trial in 0..256u64 {
            assert_eq!(res.netlist.eval(trial)[0], g.eval(trial)[0], "m={trial}");
        }
        // 8-input XOR in 6-LUTs: ≥ 2 LUTs, depth 2.
        assert!(res.netlist.num_luts() <= 3);
        assert_eq!(res.depth, 2);
    }

    #[test]
    fn mapping_preserves_function_random() {
        for seed in 0..15u64 {
            let g = random_aig(6, 30, seed);
            let res = map_aig(&g, &MapConfig::default());
            assert!(res.netlist.max_arity() <= 6);
            for m in 0..64u64 {
                assert_eq!(res.netlist.eval(m), g.eval(m), "seed={seed} m={m}");
            }
        }
    }

    #[test]
    fn mapping_respects_k() {
        for k in 2..=6usize {
            let g = random_aig(8, 40, 99);
            let cfg = MapConfig { k, ..Default::default() };
            let res = map_aig(&g, &cfg);
            assert!(res.netlist.max_arity() <= k, "k={k}");
            for m in (0..256u64).step_by(7) {
                assert_eq!(res.netlist.eval(m), g.eval(m));
            }
        }
    }

    #[test]
    fn single_lut_when_function_fits() {
        // Any function of ≤6 inputs must map to exactly 1 LUT.
        let mut g = Aig::new();
        let ins: Vec<Lit> = (0..6).map(|_| g.add_input()).collect();
        let mut acc = ins[0];
        for &l in &ins[1..] {
            acc = g.xor(acc, l); // deep AIG, but 6 inputs total
        }
        g.add_output(acc);
        let res = map_aig(&g, &MapConfig::default());
        assert_eq!(res.netlist.num_luts(), 1);
        assert_eq!(res.depth, 1);
        for m in 0..64u64 {
            assert_eq!(res.netlist.eval(m)[0], g.eval(m)[0]);
        }
    }

    #[test]
    fn inverted_and_constant_outputs() {
        let mut g = Aig::new();
        let a = g.add_input();
        let b = g.add_input();
        let x = g.and(a, b);
        g.add_output(lit_not(x));
        g.add_output(crate::logic::aig::LIT_TRUE);
        g.add_output(a);
        let res = map_aig(&g, &MapConfig::default());
        for m in 0..4u64 {
            let e = res.netlist.eval(m);
            assert_eq!(e[0], !(m & 1 == 1 && m & 2 == 2));
            assert!(e[1]);
            assert_eq!(e[2], m & 1 == 1);
        }
    }

    #[test]
    fn area_mode_not_worse_than_depth_mode_area() {
        let g = random_aig(10, 80, 1234);
        let d = map_aig(&g, &MapConfig { sort_by_area: false, ..Default::default() });
        let a = map_aig(&g, &MapConfig { sort_by_area: true, ..Default::default() });
        // Area mode should not use more LUTs than depth mode on average;
        // allow slack of 1 LUT for this single instance but verify both map
        // correctly.
        for m in (0..1024u64).step_by(13) {
            assert_eq!(d.netlist.eval(m), g.eval(m));
            assert_eq!(a.netlist.eval(m), g.eval(m));
        }
        assert!(a.netlist.num_luts() <= d.netlist.num_luts() + 1);
    }

    #[test]
    fn shared_nodes_emitted_once() {
        // Two outputs sharing a subcone must not duplicate LUTs when the
        // shared node is a cut leaf of both.
        let mut g = Aig::new();
        let ins: Vec<Lit> = (0..7).map(|_| g.add_input()).collect();
        let mut acc = ins[0];
        for &l in &ins[1..] {
            acc = g.xor(acc, l);
        }
        g.add_output(acc);
        g.add_output(lit_not(acc));
        let res = map_aig(&g, &MapConfig::default());
        // second output reuses the first cone entirely
        for m in 0..128u64 {
            let e = res.netlist.eval(m);
            assert_eq!(e[0], g.eval(m)[0]);
            assert_eq!(e[1], !e[0]);
        }
        assert!(res.netlist.num_luts() <= 2);
    }
}
