//! Structural lint for netlists, pipelined circuits, and compiled streams.
//!
//! The circuit *is* the program, so a malformed netlist — a combinational
//! cycle, a dangling signal reference, a truth table narrower than its
//! fanin — does not crash, it silently miscomputes. This module is the
//! first tier of the verification ladder (see `rust/DESIGN.md`
//! §Verification tiers): cheap, total, and run everywhere a netlist enters
//! the system — inside [`crate::logic::sim::CompiledNetlist::compile`]
//! (debug builds), on every artifact load ([`crate::flow::artifact`]), and
//! before every [`crate::coordinator::registry::ModelRegistry`] install —
//! so spliced or hand-edited bundles are rejected with a typed error
//! instead of being served.

use std::fmt;

use crate::logic::netlist::{LutNetlist, PipelinedCircuit, Sig};

/// Typed structural-check failure, surfaced as `NnError::Check`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckError {
    /// Two netlists compared for equivalence have different I/O shapes.
    SignatureMismatch {
        /// Primary-input counts of the two sides.
        inputs: (usize, usize),
        /// Output counts of the two sides.
        outputs: (usize, usize),
    },
    /// An exhaustive comparison was asked to enumerate too wide an input
    /// space.
    TooManyInputs {
        /// Primary-input count of the offending netlist.
        num_inputs: usize,
        /// Enumeration limit.
        limit: usize,
    },
    /// A LUT input references a signal that does not exist (dangling).
    Undriven {
        /// Index of the reading LUT.
        lut: usize,
        /// Input position within that LUT.
        pos: usize,
        /// Description of the missing signal.
        signal: String,
    },
    /// A LUT reads itself or a later LUT — a combinational cycle in the
    /// topologically-indexed representation.
    Cycle {
        /// Index of the reading LUT.
        lut: usize,
        /// Input position within that LUT.
        pos: usize,
        /// Index of the referenced (not-yet-defined) LUT.
        referenced: usize,
    },
    /// LUT fanin exceeds the fabric bound.
    Arity {
        /// Index of the offending LUT.
        lut: usize,
        /// Its fanin.
        arity: usize,
        /// Maximum allowed fanin.
        max: usize,
    },
    /// Truth-table variable count does not match the LUT's fanin.
    TableWidth {
        /// Index of the offending LUT.
        lut: usize,
        /// Variables in the truth table.
        table_vars: usize,
        /// Declared fanin.
        fanin: usize,
    },
    /// A primary output references a missing signal.
    BadOutput {
        /// Output index.
        index: usize,
        /// Description of the missing signal.
        signal: String,
    },
    /// Pipeline stage assignment is malformed (length, range, or a
    /// backward edge).
    Stage(String),
    /// Compiled instruction stream violates schedule soundness (a LUT reads
    /// a slot written later, a slot is written twice, codes out of range).
    Schedule(String),
    /// The runtime lock-acquisition graph contains a cycle — two code paths
    /// acquire the named locks in opposite orders, so a concurrent schedule
    /// can deadlock. Reported by `nullanet check --locks` from the
    /// lock-order recorder in [`crate::util::sync`].
    LockOrder {
        /// The locks on the cycle, in acquisition order; the last entry
        /// closes the loop back to the first.
        cycle: Vec<String>,
    },
}

impl fmt::Display for CheckError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckError::SignatureMismatch { inputs, outputs } => write!(
                f,
                "netlist signatures differ: {} vs {} inputs, {} vs {} outputs",
                inputs.0, inputs.1, outputs.0, outputs.1
            ),
            CheckError::TooManyInputs { num_inputs, limit } => {
                write!(f, "{num_inputs} inputs exceed the exhaustive-check limit of {limit}")
            }
            CheckError::Undriven { lut, pos, signal } => {
                write!(f, "LUT {lut} input {pos} reads undriven signal {signal}")
            }
            CheckError::Cycle { lut, pos, referenced } => write!(
                f,
                "LUT {lut} input {pos} reads LUT {referenced} at or after its own position \
                 (combinational cycle)"
            ),
            CheckError::Arity { lut, arity, max } => {
                write!(f, "LUT {lut} has fanin {arity}, above the bound of {max}")
            }
            CheckError::TableWidth { lut, table_vars, fanin } => write!(
                f,
                "LUT {lut} truth table covers {table_vars} variables but the LUT has fanin {fanin}"
            ),
            CheckError::BadOutput { index, signal } => {
                write!(f, "output {index} reads undriven signal {signal}")
            }
            CheckError::Stage(msg) => write!(f, "stage assignment: {msg}"),
            CheckError::Schedule(msg) => write!(f, "compiled schedule: {msg}"),
            CheckError::LockOrder { cycle } => write!(
                f,
                "lock-order cycle (potential deadlock): {}",
                cycle.join(" -> ")
            ),
        }
    }
}

impl std::error::Error for CheckError {}

fn check_sig(sig: Sig, num_inputs: usize, defined_luts: usize) -> Result<(), String> {
    match sig {
        Sig::Const(_) => Ok(()),
        Sig::Input(i) => {
            if (i as usize) < num_inputs {
                Ok(())
            } else {
                Err(format!("input {i} (netlist has {num_inputs} inputs)"))
            }
        }
        Sig::Lut(j) => {
            if (j as usize) < defined_luts {
                Ok(())
            } else {
                Err(format!("LUT {j} (only {defined_luts} defined)"))
            }
        }
    }
}

/// Lint a netlist: every LUT reads only constants, primary inputs, or
/// strictly earlier LUTs (no combinational cycles, no dangling references),
/// fanin is at most `max_arity`, each truth table covers exactly its LUT's
/// fanin, and every output reads a driven signal.
///
/// `max_arity` is 6 for mapped/compiled fabrics; pre-mapping netlists may
/// pass [`crate::logic::truthtable::TruthTable::MAX_VARS`].
pub fn lint_netlist(nl: &LutNetlist, max_arity: usize) -> Result<(), CheckError> {
    for (j, lut) in nl.luts.iter().enumerate() {
        if lut.arity() > max_arity {
            return Err(CheckError::Arity { lut: j, arity: lut.arity(), max: max_arity });
        }
        if lut.table.nvars() != lut.arity() {
            return Err(CheckError::TableWidth {
                lut: j,
                table_vars: lut.table.nvars(),
                fanin: lut.arity(),
            });
        }
        for (pos, &sig) in lut.inputs.iter().enumerate() {
            if let Sig::Lut(i) = sig {
                // A reference to an existing-but-not-earlier LUT is a cycle;
                // anything past the end of the array is dangling.
                if (i as usize) >= j && (i as usize) < nl.luts.len() {
                    return Err(CheckError::Cycle { lut: j, pos, referenced: i as usize });
                }
            }
            if let Err(signal) = check_sig(sig, nl.num_inputs, nl.luts.len()) {
                return Err(CheckError::Undriven { lut: j, pos, signal });
            }
        }
    }
    for (index, &(sig, _inverted)) in nl.outputs.iter().enumerate() {
        if let Err(signal) = check_sig(sig, nl.num_inputs, nl.luts.len()) {
            return Err(CheckError::BadOutput { index, signal });
        }
    }
    Ok(())
}

/// Lint a pipelined circuit: the mapped netlist (6-LUT fabric) plus the
/// stage assignment — length, range, and edge monotonicity.
pub fn lint_circuit(c: &PipelinedCircuit) -> Result<(), CheckError> {
    lint_netlist(&c.netlist, 6)?;
    if c.num_stages == 0 {
        return Err(CheckError::Stage("circuit declares zero pipeline stages".into()));
    }
    c.check_stages().map_err(CheckError::Stage)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::netlist::Lut;
    use crate::logic::truthtable::TruthTable;

    fn and2() -> TruthTable {
        TruthTable::from_fn(2, |m| m == 3)
    }

    fn good_netlist() -> LutNetlist {
        let mut nl = LutNetlist::new(2);
        let a = nl.add_lut(vec![Sig::Input(0), Sig::Input(1)], and2());
        nl.add_output(a, false);
        nl
    }

    #[test]
    fn well_formed_netlist_passes() {
        assert_eq!(lint_netlist(&good_netlist(), 6), Ok(()));
    }

    #[test]
    fn self_reference_is_a_cycle() {
        let mut nl = good_netlist();
        nl.luts[0].inputs[1] = Sig::Lut(0);
        assert!(matches!(
            lint_netlist(&nl, 6),
            Err(CheckError::Cycle { lut: 0, pos: 1, referenced: 0 })
        ));
    }

    #[test]
    fn dangling_lut_reference_is_undriven() {
        let mut nl = good_netlist();
        nl.luts[0].inputs[0] = Sig::Lut(9);
        assert!(matches!(lint_netlist(&nl, 6), Err(CheckError::Undriven { lut: 0, pos: 0, .. })));
    }

    #[test]
    fn out_of_range_input_is_undriven() {
        let mut nl = good_netlist();
        nl.luts[0].inputs[0] = Sig::Input(7);
        assert!(matches!(lint_netlist(&nl, 6), Err(CheckError::Undriven { .. })));
    }

    #[test]
    fn arity_bound_is_enforced() {
        let mut nl = LutNetlist::new(8);
        let inputs: Vec<Sig> = (0..7).map(Sig::Input).collect();
        nl.luts.push(Lut { inputs, table: TruthTable::from_fn(7, |_| false) });
        nl.add_output(Sig::Lut(0), false);
        assert!(matches!(lint_netlist(&nl, 6), Err(CheckError::Arity { lut: 0, arity: 7, max: 6 })));
        assert_eq!(lint_netlist(&nl, 7), Ok(()));
    }

    #[test]
    fn table_width_mismatch_is_caught() {
        let mut nl = good_netlist();
        nl.luts[0].table = TruthTable::from_fn(3, |_| true);
        assert!(matches!(
            lint_netlist(&nl, 6),
            Err(CheckError::TableWidth { lut: 0, table_vars: 3, fanin: 2 })
        ));
    }

    #[test]
    fn bad_output_is_caught() {
        let mut nl = good_netlist();
        nl.outputs[0] = (Sig::Lut(4), true);
        assert!(matches!(lint_netlist(&nl, 6), Err(CheckError::BadOutput { index: 0, .. })));
    }

    #[test]
    fn circuit_lint_covers_stages() {
        let nl = good_netlist();
        let good = PipelinedCircuit::single_stage(nl.clone());
        assert_eq!(lint_circuit(&good), Ok(()));

        let short = PipelinedCircuit { netlist: nl.clone(), stage_of_lut: vec![], num_stages: 1 };
        assert!(matches!(lint_circuit(&short), Err(CheckError::Stage(_))));

        let zero = PipelinedCircuit { netlist: nl, stage_of_lut: vec![0], num_stages: 0 };
        assert!(matches!(lint_circuit(&zero), Err(CheckError::Stage(_))));
    }

    #[test]
    fn errors_display_cleanly() {
        let e = CheckError::Cycle { lut: 3, pos: 1, referenced: 5 };
        assert!(e.to_string().contains("combinational cycle"));
        let e = CheckError::SignatureMismatch { inputs: (2, 3), outputs: (1, 1) };
        assert!(e.to_string().contains("2 vs 3"));
    }
}
